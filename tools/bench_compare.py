#!/usr/bin/env python
"""Compare bench trajectories / envelopes against committed baselines.

Usage:
  python tools/bench_compare.py BENCH_kernel.json [BENCH_pack.json ...]
      [--baselines benchmarks/expected] [--seed]

The regression sentinel of DESIGN.md §11: each input file is either a
trajectory store (``BENCH_<suite>.json``, written by ``benchmarks/run.py
--bench-dir``) or a raw bench JSONL envelope (``--json`` output of a
single suite). For each, the newest rows are checked against the
committed baseline spec ``<baselines>/<suite>.json`` (see
``src/repro/obs/baseline.py`` for the spec format). Any violation —
a bounded metric out of tolerance, or a metric whose selector no longer
matches any row — prints one line and the exit status is 1, which is
what fails the CI ``bench-regression`` job.

``--seed`` instead rewrites each baseline spec's relative ``baseline``
values from the measured rows (the loosest honest baseline per
direction) — how the committed snapshots are (re)generated after an
intentional perf change.

Runs stdlib-only (CI gate jobs have no jax): ``repro.obs.baseline`` is
loaded by file path, never through the ``repro`` package.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINES = os.path.join(_REPO, "benchmarks", "expected")


def _load_baseline_mod():
    path = os.path.join(_REPO, "src", "repro", "obs", "baseline.py")
    spec = importlib.util.spec_from_file_location("obs_baseline", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def suite_of(path: str, rows_source: dict | None = None) -> str:
    """Suite name of an input file: the trajectory's own ``suite`` field,
    else derived from the filename (``BENCH_pack.json`` -> ``pack``,
    ``kernel_bench.json`` -> ``kernel``)."""
    if rows_source and rows_source.get("suite"):
        return str(rows_source["suite"])
    base = os.path.basename(path)
    for ext in (".jsonl", ".json"):
        if base.endswith(ext):
            base = base[: -len(ext)]
    if base.startswith("BENCH_"):
        base = base[len("BENCH_"):]
    if base.endswith("_bench"):
        base = base[: -len("_bench")]
    return base


def load_rows(path: str, bl) -> tuple[str, list[dict]]:
    """(suite, newest rows) of a trajectory store OR a bench envelope."""
    last_traj = None
    rows: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except ValueError:
                continue  # torn tail
            if not isinstance(obj, dict):
                continue
            kind = obj.get("kind")
            if kind == "trajectory":
                last_traj = obj
            elif kind == "row":
                rows.append(obj)
            elif kind == "manifest" and last_traj is None and not rows:
                # envelope manifests carry the suite name
                last_traj = {"suite": obj.get("suite"), "rows": None}
    if last_traj is not None and last_traj.get("rows") is not None:
        return suite_of(path, last_traj), list(last_traj["rows"])
    return suite_of(path, last_traj), rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+",
                    help="BENCH_<suite>.json trajectories or bench "
                         "envelope JSONs")
    ap.add_argument("--baselines", default=DEFAULT_BASELINES,
                    help="directory of committed <suite>.json baseline "
                         "specs")
    ap.add_argument("--seed", action="store_true",
                    help="rewrite the relative baselines from the "
                         "measured rows instead of comparing")
    args = ap.parse_args(argv)

    bl = _load_baseline_mod()
    failures = 0
    for path in args.files:
        suite, rows = load_rows(path, bl)
        spec_path = os.path.join(args.baselines, f"{suite}.json")
        if not os.path.exists(spec_path):
            print(f"{path}: no baseline spec {spec_path} — skipping "
                  f"(commit one to gate this suite)", file=sys.stderr)
            continue
        with open(spec_path) as f:
            spec = json.load(f)
        if args.seed:
            seeded = bl.seed_spec(rows, spec)
            with open(spec_path, "w") as f:
                json.dump(seeded, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"seeded {spec_path} from {len(rows)} rows of {path}")
            continue
        violations = bl.compare(rows, spec)
        for v in violations:
            print(f"REGRESSION {suite}: {v}", file=sys.stderr)
            failures += 1
        if not violations:
            n = len(spec.get("metrics", ()))
            print(f"ok: {suite} ({path}) — {n} metric(s) within bounds")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
