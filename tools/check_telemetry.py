#!/usr/bin/env python
"""Validate a ``repro.obs`` JSONL run log against the checked-in schema.

Usage:
  python tools/check_telemetry.py RUN.jsonl [RUN2.jsonl ...]
      [--schema tools/telemetry_schema.json]

The stream contract (DESIGN.md §11, src/repro/obs/sink.py):

* every line is one JSON object with a ``kind`` tag — ``manifest``
  (run identity), ``step`` (per-meta-step trainer telemetry), ``row``
  (free-form benchmark result), ``alert`` (obs.health watchdog event) or
  ``attribution`` (obs.profile measured-vs-modeled timing row);
* a manifest precedes the first step record (resume appends another
  manifest mid-stream — allowed anywhere);
* the manifest's ``schema_version`` major must be one the schema file
  lists in ``known_versions`` — a log written by a future incompatible
  envelope is rejected, not half-validated;
* step records carry the full core field set, plus the averaging-family
  fields when the governing manifest's ``algorithm`` is an averaging
  algorithm; UNKNOWN fields fail (a typo'd or renamed metric must not
  silently fork the schema — add it to telemetry_schema.json instead);
* ``meta_step`` is strictly increasing across the whole file, including
  across resume manifests (one run log = one monotone trajectory);
  alert/attribution records sit outside the trajectory (an alert repeats
  the step it fired on) and are field-checked but not ordered;
* ``robust`` records (core/trainer.py + repro.robust, schema v4) carry
  the per-mix clip/trim/anomaly-score telemetry; like alerts they sit
  beside the step row of the same meta_step, outside the trajectory;
* ``fault`` / ``recovery`` records (core/supervisor.py, schema v3) mark
  supervised auto-recovery transitions. A ``recovery`` record RESETS the
  monotonicity tracker: it documents a legitimate rollback of the
  trajectory to a verified checkpoint, after which meta_step restarts
  from the resume point. A rewind WITHOUT a recovery record is still a
  violation.

Exit status 0 = valid; non-zero prints one line per violation.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# mirror of repro.configs.base.AVERAGING_ALGOS — this tool runs without
# PYTHONPATH=src (CI validates artifacts with a bare python invocation)
AVERAGING_ALGOS = ("mavg", "kavg", "sync", "mavg_mlocal")

DEFAULT_SCHEMA = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "telemetry_schema.json"
)

KINDS = ("manifest", "step", "row", "alert", "attribution", "fault",
         "recovery", "robust")


def load_schema(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _major(version) -> int | None:
    """Major component of a schema version (int versions ARE the major;
    a future "2.1"-style string splits on the dot)."""
    if isinstance(version, int):
        return version
    if isinstance(version, float):
        return int(version)
    if isinstance(version, str):
        head = version.split(".", 1)[0]
        if head.isdigit():
            return int(head)
    return None


def check_stream(lines, schema, *, name: str = "<stream>") -> list[str]:
    """All schema violations in one pass (empty list = valid)."""
    errs: list[str] = []
    step_req = set(schema["step_required"])
    step_avg = set(schema["step_required_averaging"])
    step_known = step_req | step_avg | set(schema["step_optional"])
    man_req = set(schema["manifest_required"])
    man_trainer = set(schema["manifest_required_trainer"])
    alert_req = set(schema.get("alert_required", ()))
    attr_req = set(schema.get("attribution_required", ()))
    fault_req = set(schema.get("fault_required", ()))
    recovery_req = set(schema.get("recovery_required", ()))
    robust_req = set(schema.get("robust_required", ()))
    known_majors = {
        _major(v) for v in schema.get(
            "known_versions", [schema["schema_version"]]
        )
    }

    n_manifests = 0
    algorithm = None
    last_step = None
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        where = f"{name}:{i}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errs.append(f"{where}: not valid JSON ({e})")
            continue
        if not isinstance(rec, dict):
            errs.append(f"{where}: not a JSON object")
            continue
        kind = rec.get("kind")
        if kind not in KINDS:
            errs.append(f"{where}: unknown kind {kind!r} (want one of {KINDS})")
            continue
        if kind == "manifest":
            n_manifests += 1
            missing = man_req - set(rec)
            # bench manifests (suite set) carry environment only; trainer
            # manifests also carry the run config / topology identity
            if "suite" not in rec:
                missing |= man_trainer - set(rec)
                algorithm = rec.get("algorithm")
            if missing:
                errs.append(
                    f"{where}: manifest missing fields {sorted(missing)}"
                )
            mj = _major(rec.get("schema_version"))
            if "schema_version" in rec and mj not in known_majors:
                errs.append(
                    f"{where}: manifest schema_version "
                    f"{rec['schema_version']!r} has unknown major {mj} "
                    f"(this validator knows majors "
                    f"{sorted(m for m in known_majors if m is not None)}) — "
                    f"the log was written by an incompatible envelope"
                )
        elif kind == "step":
            if n_manifests == 0:
                errs.append(f"{where}: step record before any manifest")
            req = set(step_req)
            if algorithm in AVERAGING_ALGOS:
                req |= step_avg
            missing = req - set(rec)
            if missing:
                errs.append(f"{where}: step missing fields {sorted(missing)}")
            unknown = set(rec) - step_known
            if unknown:
                errs.append(
                    f"{where}: step has unknown fields {sorted(unknown)} — "
                    f"extend tools/telemetry_schema.json if intentional"
                )
            s = rec.get("meta_step")
            if isinstance(s, (int, float)):
                if last_step is not None and s <= last_step:
                    errs.append(
                        f"{where}: meta_step {s} not > previous {last_step} "
                        f"(one run log must be one monotone trajectory)"
                    )
                last_step = s
        elif kind == "alert":
            if n_manifests == 0:
                errs.append(f"{where}: alert record before any manifest")
            missing = alert_req - set(rec)
            if missing:
                errs.append(f"{where}: alert missing fields {sorted(missing)}")
            if rec.get("severity") not in ("warn", "fatal"):
                errs.append(
                    f"{where}: alert severity {rec.get('severity')!r} not "
                    f"one of ('warn', 'fatal')"
                )
            if not isinstance(rec.get("halt"), bool):
                errs.append(f"{where}: alert halt must be a boolean")
        elif kind == "attribution":
            missing = attr_req - set(rec)
            if missing:
                errs.append(
                    f"{where}: attribution missing fields {sorted(missing)}"
                )
        elif kind == "fault":
            if n_manifests == 0:
                errs.append(f"{where}: fault record before any manifest")
            missing = fault_req - set(rec)
            if missing:
                errs.append(f"{where}: fault missing fields {sorted(missing)}")
        elif kind == "recovery":
            if n_manifests == 0:
                errs.append(f"{where}: recovery record before any manifest")
            missing = recovery_req - set(rec)
            if missing:
                errs.append(
                    f"{where}: recovery missing fields {sorted(missing)}"
                )
            # the supervisor rolled the run back to a verified snapshot:
            # the trajectory legitimately rewinds here
            last_step = None
        elif kind == "robust":
            # schema v4: per-mix robust-aggregation telemetry (repro.robust)
            # — sits beside the step row of the same meta_step, outside the
            # monotone trajectory (like alerts, it repeats a step's index)
            if n_manifests == 0:
                errs.append(f"{where}: robust record before any manifest")
            missing = robust_req - set(rec)
            if missing:
                errs.append(
                    f"{where}: robust missing fields {sorted(missing)}"
                )
        # kind == "row": bench rows are suite-specific, not field-checked
    if n_manifests == 0:
        errs.append(f"{name}: no manifest record in stream")
    return errs


def check_file(path: str, schema: dict) -> list[str]:
    with open(path) as f:
        return check_stream(f, schema, name=path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", help="JSONL run logs to validate")
    ap.add_argument("--schema", default=DEFAULT_SCHEMA)
    args = ap.parse_args(argv)

    schema = load_schema(args.schema)
    errs: list[str] = []
    for path in args.files:
        errs += check_file(path, schema)
    for e in errs:
        print(e, file=sys.stderr)
    if not errs:
        print(f"ok: {len(args.files)} file(s) valid "
              f"(schema_version {schema['schema_version']})")
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
