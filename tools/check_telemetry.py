#!/usr/bin/env python
"""Validate a ``repro.obs`` JSONL run log against the checked-in schema.

Usage:
  python tools/check_telemetry.py RUN.jsonl [RUN2.jsonl ...]
      [--schema tools/telemetry_schema.json]

The stream contract (DESIGN.md §11, src/repro/obs/sink.py):

* every line is one JSON object with a ``kind`` tag — ``manifest``
  (run identity), ``step`` (per-meta-step trainer telemetry) or ``row``
  (free-form benchmark result);
* a manifest precedes the first step record (resume appends another
  manifest mid-stream — allowed anywhere);
* step records carry the full core field set, plus the averaging-family
  fields when the governing manifest's ``algorithm`` is an averaging
  algorithm; UNKNOWN fields fail (a typo'd or renamed metric must not
  silently fork the schema — add it to telemetry_schema.json instead);
* ``meta_step`` is strictly increasing across the whole file, including
  across resume manifests (one run log = one monotone trajectory).

Exit status 0 = valid; non-zero prints one line per violation.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# mirror of repro.configs.base.AVERAGING_ALGOS — this tool runs without
# PYTHONPATH=src (CI validates artifacts with a bare python invocation)
AVERAGING_ALGOS = ("mavg", "kavg", "sync", "mavg_mlocal")

DEFAULT_SCHEMA = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "telemetry_schema.json"
)

KINDS = ("manifest", "step", "row")


def load_schema(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_stream(lines, schema, *, name: str = "<stream>") -> list[str]:
    """All schema violations in one pass (empty list = valid)."""
    errs: list[str] = []
    step_req = set(schema["step_required"])
    step_avg = set(schema["step_required_averaging"])
    step_known = step_req | step_avg | set(schema["step_optional"])
    man_req = set(schema["manifest_required"])
    man_trainer = set(schema["manifest_required_trainer"])

    n_manifests = 0
    algorithm = None
    last_step = None
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        where = f"{name}:{i}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errs.append(f"{where}: not valid JSON ({e})")
            continue
        if not isinstance(rec, dict):
            errs.append(f"{where}: not a JSON object")
            continue
        kind = rec.get("kind")
        if kind not in KINDS:
            errs.append(f"{where}: unknown kind {kind!r} (want one of {KINDS})")
            continue
        if kind == "manifest":
            n_manifests += 1
            missing = man_req - set(rec)
            # bench manifests (suite set) carry environment only; trainer
            # manifests also carry the run config / topology identity
            if "suite" not in rec:
                missing |= man_trainer - set(rec)
                algorithm = rec.get("algorithm")
            if missing:
                errs.append(
                    f"{where}: manifest missing fields {sorted(missing)}"
                )
        elif kind == "step":
            if n_manifests == 0:
                errs.append(f"{where}: step record before any manifest")
            req = set(step_req)
            if algorithm in AVERAGING_ALGOS:
                req |= step_avg
            missing = req - set(rec)
            if missing:
                errs.append(f"{where}: step missing fields {sorted(missing)}")
            unknown = set(rec) - step_known
            if unknown:
                errs.append(
                    f"{where}: step has unknown fields {sorted(unknown)} — "
                    f"extend tools/telemetry_schema.json if intentional"
                )
            s = rec.get("meta_step")
            if isinstance(s, (int, float)):
                if last_step is not None and s <= last_step:
                    errs.append(
                        f"{where}: meta_step {s} not > previous {last_step} "
                        f"(one run log must be one monotone trajectory)"
                    )
                last_step = s
        # kind == "row": bench rows are suite-specific, not field-checked
    if n_manifests == 0:
        errs.append(f"{name}: no manifest record in stream")
    return errs


def check_file(path: str, schema: dict) -> list[str]:
    with open(path) as f:
        return check_stream(f, schema, name=path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", help="JSONL run logs to validate")
    ap.add_argument("--schema", default=DEFAULT_SCHEMA)
    args = ap.parse_args(argv)

    schema = load_schema(args.schema)
    errs: list[str] = []
    for path in args.files:
        errs += check_file(path, schema)
    for e in errs:
        print(e, file=sys.stderr)
    if not errs:
        print(f"ok: {len(args.files)} file(s) valid "
              f"(schema_version {schema['schema_version']})")
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
