"""Roofline/HLO-parser unit tests against hand-written HLO snippets and a
real compiled module."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import collective_bytes, compute_terms, model_flops
from repro.roofline.hlo_cost import hlo_cost, parse_module

HLO = """\
cond_comp (p: (s32[], f32[8,128])) -> pred[] {
  %c = s32[] constant(5)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

body_comp (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%ni, %ar)
}

ENTRY main (a: f32[8,128], b: f32[128,64]) -> f32[8,64] {
  %a = f32[8,128]{1,0} parameter(0)
  %b = f32[128,64]{1,0} parameter(1)
  %init = (s32[], f32[8,128]) tuple(%zero, %a)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond_comp, body=%body_comp
  %x = f32[8,128]{1,0} get-tuple-element(%w), index=1
  %ag = f32[8,128]{1,0} all-gather(%x), dimensions={0}
  ROOT %d = f32[8,64]{1,0} dot(%x, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_collective_trip_counts():
    res = collective_bytes(HLO)
    # all-reduce inside the while body: 8*128*4 bytes x 5 trips
    assert res["by_type"]["all-reduce"] == 8 * 128 * 4 * 5
    assert res["by_type"]["all-gather"] == 8 * 128 * 4


def test_dot_flops():
    cost = hlo_cost(HLO)
    assert cost.flops == 2 * 8 * 64 * 128


def test_parse_module_entry():
    comps, entry = parse_module(HLO)
    assert entry == "main"
    assert "body_comp" in comps and "cond_comp" in comps


def test_real_compiled_module_flops():
    """Parsed FLOPs of a real jitted matmul match the analytic count."""
    m, k, n = 64, 128, 32

    def f(a, b):
        return a @ b

    compiled = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
        )
        .compile()
    )
    cost = hlo_cost(compiled.as_text())
    assert cost.flops == 2 * m * k * n


def test_real_scan_trip_count():
    """lax.scan of T matmuls parses to T x single-matmul FLOPs."""
    T, m = 7, 32

    def f(x, ws):
        def step(x, w):
            return jnp.tanh(x @ w), None

        y, _ = jax.lax.scan(step, x, ws)
        return y

    compiled = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((m, m), jnp.float32),
            jax.ShapeDtypeStruct((T, m, m), jnp.float32),
        )
        .compile()
    )
    cost = hlo_cost(compiled.as_text())
    assert cost.flops == T * 2 * m * m * m


def test_terms_bottleneck():
    from repro.configs.base import INPUT_SHAPES, get_config

    cfg = get_config("qwen3-1.7b")
    shape = INPUT_SHAPES["train_4k"]
    t = compute_terms(
        arch="qwen3-1.7b", shape=shape, mesh_name="single", chips=256,
        hlo_flops=1e14, hlo_bytes=1e12, collective_bytes=1e9, cfg=cfg,
        k_steps=2,
    )
    assert t.bottleneck == "memory"
    assert t.compute_s > 0 and t.collective_s > 0
    assert 0 < t.useful_ratio


def test_model_flops_moe_active_only():
    from repro.configs.base import INPUT_SHAPES, get_config

    cfg = get_config("kimi-k2-1t-a32b")
    shape = INPUT_SHAPES["train_4k"]
    mf = model_flops(cfg, shape, k_steps=1)
    dense_equiv = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert mf < 0.15 * dense_equiv  # top-8 of 384 experts
