"""Forward-vs-decode consistency for the remaining decode-capable archs
(test_decode_consistency.py covers one representative per family; this
covers the rest, plus window-decode correctness past the window edge)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api as model_api

RNG = jax.random.PRNGKey(11)

REMAINING = ["llama3-405b", "qwen1.5-110b", "kimi-k2-1t-a32b"]


@pytest.mark.parametrize("arch", REMAINING)
def test_forward_vs_decode(arch):
    # capacity_factor high enough that no token is dropped: capacity
    # dropping is batch-dependent (train-time approximation), so the
    # batched forward and the one-token decode only agree without drops.
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              capacity_factor=8.0)
    params = model_api.init_params(RNG, cfg)
    B, S = 2, 16
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size, jnp.int32)
    logits_fwd, _ = jax.jit(
        lambda p, b: model_api.forward(p, cfg, b)
    )(params, {"tokens": toks})
    cache = model_api.init_cache(cfg, B, S + 2, dtype="float32")
    decode = jax.jit(lambda p, c, t: model_api.decode_step(p, cfg, c, t))
    for i in range(S):
        logits_dec, cache = decode(params, cache, toks[:, i])
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_fwd[:, i]),
            rtol=3e-3, atol=3e-3, err_msg=f"{arch} pos {i}",
        )


def test_vlm_decode_after_prefill():
    """internvl2: prefill with patches+tokens, then decode continues."""
    cfg = dataclasses.replace(get_config("internvl2-76b").reduced(),
                              dtype="float32")
    params = model_api.init_params(RNG, cfg)
    B, S = 2, 8
    batch = {
        "patches": jax.random.normal(
            RNG, (B, cfg.num_patches, cfg.d_model)) * 0.02,
        "tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size,
                                     jnp.int32),
    }
    cache_len = cfg.num_patches + S + 4
    logits_pf, cache = jax.jit(
        lambda p, b: model_api.prefill(p, cfg, b, cache_len)
    )(params, batch)
    # teacher-forcing check: prefill last-position logits match forward
    logits_fwd, _ = jax.jit(
        lambda p, b: model_api.forward(p, cfg, b)
    )(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_pf), np.asarray(logits_fwd[:, -1]),
        rtol=3e-3, atol=3e-3,
    )
    nxt = jnp.argmax(logits_pf, -1).astype(jnp.int32)
    logits_dec, cache = jax.jit(
        lambda p, c, t: model_api.decode_step(p, cfg, c, t)
    )(params, cache, nxt)
    assert logits_dec.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits_dec).all()


def test_window_decode_past_window_edge():
    """Sliding-window serve variant: decoding far past the window must
    match the full training forward under the same window mask."""
    base = get_config("qwen3-1.7b").reduced()
    W = 8
    cfg = dataclasses.replace(base, dtype="float32", sliding_window=W)
    params = model_api.init_params(RNG, cfg)
    B, S = 2, 24  # 3x the window
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size, jnp.int32)
    logits_fwd, _ = jax.jit(
        lambda p, b: model_api.forward(p, cfg, b)
    )(params, {"tokens": toks})
    cache = model_api.init_cache(cfg, B, S, dtype="float32")
    assert cache["k"].shape[2] == W  # O(window) cache, not O(seq)
    decode = jax.jit(lambda p, c, t: model_api.decode_step(p, cfg, c, t))
    for i in range(S):
        logits_dec, cache = decode(params, cache, toks[:, i])
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_fwd[:, i]),
            rtol=3e-3, atol=3e-3, err_msg=f"pos {i}",
        )
