"""The manual shard_map expert-parallel path must produce the same
numbers as the GSPMD gather/scatter path (serving correctness).

Runs in a subprocess with 8 forced host devices (the main test process
must keep seeing 1 CPU device)."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.models import moe

cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                          dtype="float32")
p = moe.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
out_ref, aux_ref = moe.moe_layer(x, p, cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
moe.set_expert_axis("model", mesh)
with mesh:
    out_sm, aux_sm = jax.jit(lambda x, p: moe.moe_layer(x, p, cfg))(x, p)
moe.set_expert_axis(None, None)
np.testing.assert_allclose(np.asarray(out_sm), np.asarray(out_ref),
                           rtol=2e-4, atol=2e-4)
assert abs(float(aux_sm) - float(aux_ref)) < 1e-6
print(json.dumps({"ok": True}))
"""


def test_shard_map_moe_matches_gspmd(tmp_path):
    script = tmp_path / "sm_moe.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, str(script)], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
