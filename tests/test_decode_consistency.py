"""Teacher-forcing consistency: decoding token-by-token through the cache
must reproduce the training forward pass logits.

This is the strongest correctness test for the serving path: it catches
cache indexing, RoPE-position, rolling-window, SSM-state and GQA bugs.
Run in float32 to keep tolerances tight.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api as model_api

RNG = jax.random.PRNGKey(7)

# one representative per decode-capable family + the window variant
CASES = [
    "qwen3-1.7b",      # dense, qk_norm, tied embeddings
    "qwen2-7b",        # dense, qkv bias, non-divisible heads
    "deepseek-moe-16b",  # moe with shared experts + leading dense layer
    "xlstm-350m",      # ssm recurrent state
    "hymba-1.5b",      # hybrid: window KV + meta tokens + mamba state
]


@pytest.mark.parametrize("arch", CASES)
def test_forward_vs_decode(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = model_api.init_params(RNG, cfg)
    B, S = 2, 24
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size, jnp.int32)

    logits_fwd, _ = jax.jit(
        lambda p, b: model_api.forward(p, cfg, b)
    )(params, {"tokens": toks})
    # strip prefix (meta tokens) positions
    prefix = cfg.meta_tokens
    logits_fwd = logits_fwd[:, prefix:]

    cache = model_api.init_cache(cfg, B, S + 4, dtype="float32")
    # hymba decode expects meta KV prefilled; build it with a 1-token prefill
    if cfg.family == "hybrid":
        _, cache = jax.jit(
            lambda p, b: model_api.prefill(p, cfg, b, S + 4)
        )(params, {"tokens": toks[:, :1]})
        start = 1
    else:
        start = 0

    decode = jax.jit(lambda p, c, t: model_api.decode_step(p, cfg, c, t))
    for i in range(start, S):
        logits_dec, cache = decode(params, cache, toks[:, i])
        np.testing.assert_allclose(
            np.asarray(logits_dec),
            np.asarray(logits_fwd[:, i]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} position {i}",
        )


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "xlstm-350m", "hymba-1.5b"])
def test_prefill_vs_decode(arch):
    """prefill(prompt) must land in the same state as stepwise decode."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = model_api.init_params(RNG, cfg)
    B, S0 = 2, 12
    toks = jax.random.randint(RNG, (B, S0), 0, cfg.vocab_size, jnp.int32)
    cache_len = S0 + 6

    logits_pf, cache_pf = jax.jit(
        lambda p, b: model_api.prefill(p, cfg, b, cache_len)
    )(params, {"tokens": toks})

    decode = jax.jit(lambda p, c, t: model_api.decode_step(p, cfg, c, t))
    nxt = jnp.argmax(logits_pf, -1).astype(jnp.int32)
    logits_a, _ = decode(params, cache_pf, nxt)

    # stepwise path
    cache = model_api.init_cache(cfg, B, cache_len, dtype="float32")
    if cfg.family == "hybrid":
        _, cache = jax.jit(
            lambda p, b: model_api.prefill(p, cfg, b, cache_len)
        )(params, {"tokens": toks[:, :1]})
        rng_range = range(1, S0)
    else:
        rng_range = range(S0)
    logits = None
    for i in rng_range:
        logits, cache = decode(params, cache, toks[:, i])
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_pf), rtol=2e-3, atol=2e-3
    )
    logits_b, _ = decode(params, cache, nxt)
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=2e-3, atol=2e-3
    )
