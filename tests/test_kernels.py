"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
sweeping shapes and dtypes per the spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.RandomState(42)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# block momentum (the paper's fused meta update)
# ---------------------------------------------------------------------------

BM_SHAPES = [(8, 128), (1000,), (33, 7), (513, 130), (3,), (4096,), (2, 3, 5, 7)]


@pytest.mark.parametrize("shape", BM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nesterov", [False, True])
def test_block_momentum(shape, dtype, nesterov):
    w = jnp.asarray(RNG.randn(*shape), dtype)
    v = jnp.asarray(RNG.randn(*shape), dtype)
    a = jnp.asarray(RNG.randn(*shape), dtype)
    w1, v1 = ops.block_momentum(w, v, a, mu=0.7, eta=1.3, nesterov=nesterov)
    w2, v2 = ref.block_momentum_ref(w, v, a, 0.7, 1.3, nesterov=nesterov)
    np.testing.assert_allclose(
        np.asarray(w1, np.float32), np.asarray(w2, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(v1, np.float32), np.asarray(v2, np.float32), **_tol(dtype)
    )


def test_block_momentum_mu_zero_is_kavg():
    """mu=0 reduces to plain averaging: w' = a (Remark 2 of the paper)."""
    w = jnp.asarray(RNG.randn(257), jnp.float32)
    v = jnp.asarray(RNG.randn(257), jnp.float32)
    a = jnp.asarray(RNG.randn(257), jnp.float32)
    w1, v1 = ops.block_momentum(w, v, a, mu=0.0, eta=1.0)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(a), rtol=1e-6, atol=1e-6)


def test_block_momentum_tree():
    tree = {
        "a": jnp.asarray(RNG.randn(17, 5), jnp.float32),
        "b": {"c": jnp.asarray(RNG.randn(300), jnp.float32)},
    }
    v = jax.tree.map(jnp.zeros_like, tree)
    avg = jax.tree.map(lambda x: x + 1.0, tree)
    w1, v1 = ops.block_momentum_tree(tree, v, avg, mu=0.5, eta=1.0)
    for leaf_w, leaf_orig in zip(jax.tree.leaves(w1), jax.tree.leaves(tree)):
        np.testing.assert_allclose(
            np.asarray(leaf_w), np.asarray(leaf_orig) + 1.0, rtol=1e-6
        )


# ---------------------------------------------------------------------------
# fused local SGD apply
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(777,), (16, 128), (5, 7, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sgd_apply(shape, dtype):
    w = jnp.asarray(RNG.randn(*shape), dtype)
    g = jnp.asarray(RNG.randn(*shape), dtype)
    out = ops.sgd_apply(w, g, 0.37)
    expect = ref.sgd_apply_ref(w, g, 0.37)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, S, H, KV, D)
    (2, 128, 4, 2, 64),
    (1, 256, 8, 8, 128),
    (2, 64, 4, 1, 80),   # padded head_dim (hubert-style)
    (1, 96, 5, 5, 64),   # non-pow2 seq, odd heads (hymba-style)
    (1, 128, 4, 4, 256), # wide head (xlstm-style)
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_basic(case, causal):
    B, S, H, KV, D = case
    q = jnp.asarray(RNG.randn(B, S, H, D), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(B, S, KV, D), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(B, S, KV, D), jnp.float32) * 0.3
    out = ops.flash_attention(q, k, v, causal=causal)
    oracle = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(oracle), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("window,prefix", [(32, 0), (32, 8), (16, 4)])
def test_flash_attention_window_prefix(window, prefix):
    B, S, H, KV, D = 2, 128, 4, 2, 64
    q = jnp.asarray(RNG.randn(B, S, H, D), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(B, S, KV, D), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(B, S, KV, D), jnp.float32) * 0.3
    out = ops.flash_attention(
        q, k, v, causal=True, sliding_window=window, prefix_global=prefix
    )
    oracle = ref.flash_attention_ref(
        q, k, v, causal=True, sliding_window=window, prefix_global=prefix
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(oracle), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    B, S, H, KV, D = 1, 128, 4, 2, 64
    q = jnp.asarray(RNG.randn(B, S, H, D), dtype) * 0.3
    k = jnp.asarray(RNG.randn(B, S, KV, D), dtype) * 0.3
    v = jnp.asarray(RNG.randn(B, S, KV, D), dtype) * 0.3
    out = ops.flash_attention(q, k, v, causal=True)
    oracle = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(oracle, np.float32),
        rtol=3e-2, atol=3e-2,
    )
