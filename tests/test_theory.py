"""Theorem 1 sanity: on a quadratic problem with known constants, the
empirical average squared gradient norm stays below the paper's bound
g(mu, N, eta; P, B, K) (eq. 3), and the bound's structure behaves as the
paper says (mu=0 recovers K-AVG's bound; the first term shrinks with
(1 - mu)).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.pack import unpack_params
from repro.utils import tree_norm

DIM = 16
A = jnp.diag(jnp.linspace(0.2, 1.0, DIM))  # L = 1.0, F* = 0
SIGMA = 0.05


def quad_loss(params, batch):
    w = params["w"]
    # stochastic gradient = A w + noise; realise as loss with noise term
    noise = batch["noise"]  # (B, DIM)
    per = 0.5 * jnp.einsum("d,dd,d->", w, A, w) + jnp.mean(noise @ w)
    return per, {}


def paper_bound(mu, N, eta, P, B, K, L, sigma, M, F0, delta=0.5):
    t1 = 2 * (1 - mu) * F0 / (N * (K - 1 + delta) * eta)
    t2 = (L**2 * eta**2 * sigma**2 * (2 * K - 1) * K * (K - 1)
          / (6 * (K - 1 + delta) * B * (1 - mu) ** 2))
    t3 = (2 * L * K**2 * sigma**2 * eta / (P * B * (K - 1 + delta) * (1 - mu))
          * (1 + mu**2 / (2 * (1 - mu) ** 2)))
    t4 = L * eta * mu**2 * K**2 * M / ((K - 1 + delta) * (1 - mu) ** 3)
    return t1 + t2 + t3 + t4


@pytest.mark.parametrize("mu", [0.0, 0.3, 0.6])
def test_grad_norm_below_bound(mu):
    P, K, B, eta, N = 4, 3, 8, 0.05, 40
    cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=K,
                     learner_lr=eta, momentum=mu)
    w0 = jnp.ones((DIM,)) * 1.0
    params = {"w": w0}
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(quad_loss, cfg))

    sq_norms, max_g = [], 0.0
    for i in range(N):
        noise = SIGMA * jax.random.normal(
            jax.random.PRNGKey(i), (P, K, B, DIM)
        )
        g_true = A @ unpack_params(state)["w"]
        sq_norms.append(float(g_true @ g_true))
        max_g = max(max_g, float(g_true @ g_true))
        state, _ = step(state, {"noise": noise})

    emp = float(np.mean(sq_norms))
    F0 = float(0.5 * w0 @ A @ w0)
    bound = paper_bound(mu, N, eta, P, B, K, L=1.0, sigma=SIGMA * np.sqrt(DIM),
                        M=max_g, F0=F0)
    assert emp <= bound, (mu, emp, bound)


def test_bound_structure():
    """Theorem 1 structure: (a) the optimisation term scales with (1 - mu)
    — momentum accelerates; (b) the extra momentum-variance term vanishes
    at mu = 0 (Remark 2: K-AVG recovered) and grows with mu — momentum
    'hurts accuracy'; (c) for small N the bound is lower at moderate mu
    than at mu=0 (Lemma 3: optimal mu > 0) while for huge N (optimisation
    term gone) mu=0 wins."""
    kw = dict(eta=0.05, P=4, B=8, K=4, L=1.0, sigma=0.1, M=1.0, F0=1.0)
    delta = 0.5

    def t1(mu, N):
        return 2 * (1 - mu) * kw["F0"] / (N * (kw["K"] - 1 + delta) * kw["eta"])

    def t4(mu):
        return (kw["L"] * kw["eta"] * mu**2 * kw["K"] ** 2 * kw["M"]
                / ((kw["K"] - 1 + delta) * (1 - mu) ** 3))

    # (a) exact (1-mu) scaling of the optimisation term
    assert t1(0.5, 100) == pytest.approx(0.5 * t1(0.0, 100))
    # (b) momentum-variance term: zero at mu=0, increasing
    assert t4(0.0) == 0.0
    assert t4(0.6) > t4(0.3) > t4(0.1) > 0
    # (c) optimal mu > 0 in the small-N regime (Lemma 3)
    small_n = {mu: paper_bound(mu, 20, **kw) for mu in (0.0, 0.3)}
    assert small_n[0.3] < small_n[0.0]
    large_n = {mu: paper_bound(mu, 10**7, **kw) for mu in (0.0, 0.3)}
    assert large_n[0.0] < large_n[0.3]


def test_convergence_with_decreasing_eta():
    """epsilon-optimality: smaller eta -> smaller stationary residual."""
    results = {}
    for eta in (0.1, 0.02):
        cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                         learner_lr=eta, momentum=0.5)
        state = init_state({"w": jnp.ones((DIM,))}, cfg)
        step = jax.jit(make_meta_step(quad_loss, cfg))
        for i in range(300):
            noise = SIGMA * jax.random.normal(
                jax.random.PRNGKey(1000 + i), (2, 2, 8, DIM)
            )
            state, _ = step(state, {"noise": noise})
        g = A @ unpack_params(state)["w"]
        results[eta] = float(g @ g)
    assert results[0.02] < results[0.1]
