"""Telemetry acceptance tests (repro.obs, DESIGN.md §11).

Invariants:
  OBS1  ring fidelity: MetricsBuffer.flush() decodes bitwise what
        per-step host reads of the same metric scalars would have seen
        (f32 ring, one bulk transfer — no precision or ordering drift).
  OBS2  donation transparency: history and sink records are identical
        under donate=True and donate=False (excluding the host wall-clock
        throughput fields) — telemetry is step output, never a read of a
        donated input.
  OBS3  sync discipline: the number of device->host transfers equals the
        number of log_every-boundary flushes plus the final flush —
        telemetry adds NO host syncs between boundaries.
  OBS4  resume: restoring a checkpoint and rerunning with the same
        run_dir APPENDS to the same run log; meta_step stays strictly
        increasing across the resume manifest, and the stream validates
        against tools/telemetry_schema.json.
  OBS5  health metrics: flat/hier emit consensus_dist, gossip emits
        mixing_spectral_gap (validated against numpy eigenvalues), every
        averaging run emits loss_spread and comm byte counters.
  OBS6  the schema checker: accepts the logs this repo writes, rejects
        unknown fields, missing fields, and non-monotone meta_step.
  OBS7  exception-safe tracing: a crash mid-span still yields a loadable
        Chrome trace containing the interrupted span.
  OBS8  torn-tail repair: a JSONL sink resumed onto a log whose final
        line was cut mid-write truncates exactly the torn bytes; every
        surviving line parses.
  OBS9  schema versioning: the checker accepts every known_versions
        major and rejects an unknown-major manifest.
"""
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    CommConfig,
    MAvgConfig,
    ObsConfig,
    TopologyConfig,
    TrainConfig,
)
from repro.core.trainer import Trainer
from repro.models.simple import mlp_init, mlp_loss
from repro.obs import MetricsBuffer, metric_keys, write_row

D, C, H = 8, 4, 16
L, K, B = 4, 2, 4

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# host-side wall-clock fields — legitimately differ between runs
TIME_KEYS = ("meta_steps_per_sec", "samples_per_sec", "elapsed_s")


def _check_telemetry():
    spec = importlib.util.spec_from_file_location(
        "check_telemetry", os.path.join(_ROOT, "tools", "check_telemetry.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _batch_fn(rng, step):
    kx, ky = jax.random.split(rng)
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _trainer(tmp_path=None, *, donate=True, sink="memory", topology=None,
             log_every=2, checkpoint=False, run_dir=None, **obs_kw):
    mcfg = MAvgConfig(
        algorithm="mavg", num_learners=L, k_steps=K, learner_lr=0.1,
        momentum=0.6, donate=donate,
        **({"topology": topology} if topology else {}),
    )
    if run_dir is None and sink in ("jsonl", "csv"):
        run_dir = str(tmp_path / "run")
    cfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=B, meta_steps=8,
        log_every=log_every,
        checkpoint_dir=str(tmp_path / "ckpt") if checkpoint else None,
        checkpoint_every=2 if checkpoint else 0,
        obs=ObsConfig(sink=sink, run_dir=run_dir, **obs_kw),
    )
    return Trainer(
        cfg, mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D, H, C),
        batch_fn=_batch_fn,
    )


# ---------------------------------------------------------------------------
# OBS1: flush decodes bitwise what per-step reads would have seen
# ---------------------------------------------------------------------------


def test_obs1_ring_flush_bitwise_vs_per_step_reads():
    rng = np.random.RandomState(3)
    rows = [
        {"loss": jnp.float32(rng.randn()), "gnorm": jnp.float32(rng.randn())}
        for _ in range(5)
    ]
    keys = metric_keys(rows[0])
    mb = MetricsBuffer(keys, capacity=5)
    for i, m in enumerate(rows):
        mb.append(m, step=i)
    flushed = mb.flush()
    assert mb.host_syncs == 1
    assert [r["meta_step"] for r in flushed] == list(range(5))
    for rec, m in zip(flushed, rows):
        for k in keys:
            # the per-step read oracle: f32 on device -> python float
            assert rec[k] == float(jnp.float32(m[k])), k


def test_obs1_write_row_in_jit_matches_append():
    keys = ("a", "b")
    mb1 = MetricsBuffer(keys, capacity=3)
    mb2 = MetricsBuffer(keys, capacity=3)
    fn = jax.jit(lambda buf, row, a, b: write_row(
        buf, row, {"a": a, "b": b}, keys))
    for i in range(3):
        a, b = jnp.float32(i + 0.5), jnp.float32(-i)
        mb1.note(i, fn(mb1.buf, mb1.row_index(), a, b))
        mb2.append({"a": a, "b": b}, step=i)
    r1, r2 = mb1.flush(), mb2.flush()
    assert r1 == r2


def test_obs1_overflow_guard():
    mb = MetricsBuffer(("a",), capacity=2)
    mb.append({"a": jnp.float32(1)}, step=0)
    mb.append({"a": jnp.float32(2)}, step=1)
    with pytest.raises(RuntimeError):
        mb.append({"a": jnp.float32(3)}, step=2)
    assert len(mb.flush()) == 2


# ---------------------------------------------------------------------------
# OBS2: donate=True == donate=False history and sink records
# ---------------------------------------------------------------------------


def test_obs2_history_and_sink_parity_across_donation(tmp_path):
    hists, sinks = {}, {}
    for donate in (False, True):
        tr = _trainer(tmp_path / str(donate), donate=donate, sink="memory")
        hists[donate] = tr.run(8, log=None)
        sinks[donate] = tr._sink.records

    def strip(recs):
        return [{k: v for k, v in r.items() if k not in TIME_KEYS}
                for r in recs]

    assert strip(hists[False]) == strip(hists[True])
    assert strip(sinks[False]) == strip(sinks[True])
    # same records through both paths (sink sees what history sees)
    assert strip(hists[True]) == strip(sinks[True])


# ---------------------------------------------------------------------------
# OBS3: host syncs == boundary flushes + the final flush, nothing else
# ---------------------------------------------------------------------------


def test_obs3_sync_count_with_logging(tmp_path):
    tr = _trainer(tmp_path, sink="memory", log_every=4)
    tr.run(8, log=lambda *_: None)
    # boundaries at steps 0 and 4 + the finally flush of steps 5..7
    assert tr._mb.host_syncs == 3
    assert len(tr.history) == 8


def test_obs3_sync_count_silent_run(tmp_path):
    # log=None: only ring-capacity flushes + the final flush ever sync
    tr = _trainer(tmp_path, sink="memory", log_every=4)
    tr.run(8, log=None)
    # capacity = log_every = 4: forced flush when full at step 4, final
    # flush of steps 4..7 -> exactly 2 transfers for 8 steps
    assert tr._mb.host_syncs == 2
    assert [r["meta_step"] for r in tr.history] == list(range(8))


def test_obs3_throughput_fields(tmp_path):
    tr = _trainer(tmp_path, sink="memory", log_every=2)
    hist = tr.run(4, log=lambda *_: None)
    for r in hist:
        assert r["meta_steps_per_sec"] > 0
        assert r["samples_per_sec"] == pytest.approx(
            r["meta_steps_per_sec"] * L * K * B)
        assert r["elapsed_s"] > 0
        assert r["samples"] == (r["meta_step"] + 1) * L * K * B


# ---------------------------------------------------------------------------
# OBS4: resume appends to the same run log, monotone meta_step
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_obs4_resume_appends_same_run_log(tmp_path):
    run_dir = str(tmp_path / "run")
    tr = _trainer(tmp_path, sink="jsonl", run_dir=run_dir, checkpoint=True)
    tr.run(4, log=None)
    tr.close()

    from repro.checkpoint import latest_checkpoint

    tr2 = _trainer(tmp_path, sink="jsonl", run_dir=run_dir, checkpoint=True)
    tr2.restore(latest_checkpoint(str(tmp_path / "ckpt")))
    tr2.run(4, log=None)
    tr2.close()

    path = os.path.join(run_dir, "run.jsonl")
    recs = [json.loads(l) for l in open(path)]
    manifests = [r for r in recs if r["kind"] == "manifest"]
    steps = [r["meta_step"] for r in recs if r["kind"] == "step"]
    assert len(manifests) == 2  # one per (re)open
    assert steps == sorted(steps) and len(set(steps)) == len(steps)
    assert steps[0] == 0 and steps[-1] == 7
    # checkpoint directory carries the manifest sidecar
    assert os.path.exists(tmp_path / "ckpt" / "manifest.json")

    ct = _check_telemetry()
    schema = ct.load_schema(os.path.join(_ROOT, "tools",
                                         "telemetry_schema.json"))
    assert ct.check_file(path, schema) == []


# ---------------------------------------------------------------------------
# OBS5: topology health metrics
# ---------------------------------------------------------------------------


def test_obs5_flat_metrics_present(tmp_path):
    tr = _trainer(tmp_path, sink="memory")
    hist = tr.run(2, log=None)
    for key in ("loss", "grad_norm", "loss_spread", "consensus_dist",
                "displacement_norm", "v_norm", "comm_bytes",
                "comm_bytes_dense", "comm_compression"):
        assert key in hist[0], key
    assert hist[0]["loss_spread"] >= 0
    assert hist[0]["consensus_dist"] > 0  # K local steps drove them apart
    assert hist[0]["comm_compression"] == pytest.approx(1.0)  # dense


def test_obs5_hierarchical_consensus(tmp_path):
    topo = TopologyConfig(kind="hierarchical", groups=2, outer_every=2)
    tr = _trainer(tmp_path, sink="memory", topology=topo)
    hist = tr.run(2, log=None)
    assert "consensus_dist" in hist[0]
    assert "comm_bytes_inter" in hist[0] and "comm_bytes_intra" in hist[0]


def test_obs5_gossip_spectral_gap_matches_numpy(tmp_path):
    topo = TopologyConfig(kind="gossip", graph="ring")
    tr = _trainer(tmp_path, sink="memory", topology=topo)
    hist = tr.run(2, log=None)
    from repro.topology.gossip import mixing_matrix

    W = np.asarray(mixing_matrix("ring", L, 0))
    lam = np.sort(np.linalg.eigvalsh(W))
    expect = 1.0 - lam[-2]
    assert hist[0]["mixing_spectral_gap"] == pytest.approx(expect, rel=1e-5)


def test_obs5_spectral_gap_masked_identity_rows():
    from repro.topology.elastic import mask_mixing_matrix
    from repro.topology.gossip import mixing_matrix, spectral_gap

    W = mixing_matrix("complete", 4, 0)
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    Wm = mask_mixing_matrix(W, mask)
    # absent learner -> identity row; undeflated, eigenvalue 1 has
    # multiplicity 2 and the gap would always read 0 under churn
    gap = float(spectral_gap(Wm, mask))
    # numpy oracle: the gap of the present 3x3 mixing block (the masked
    # matrix keeps original edge weights, removed mass on the diagonal)
    present = np.ix_([0, 1, 3], [0, 1, 3])
    lam = np.sort(np.linalg.eigvalsh(np.asarray(Wm)[present]))
    assert lam[-1] == pytest.approx(1.0, abs=1e-6)  # doubly stochastic
    assert gap == pytest.approx(1.0 - lam[-2], abs=1e-5)
    # undeflated gap over the full masked matrix reads 0 — the failure
    # mode the deflation exists to avoid
    assert float(spectral_gap(Wm)) == pytest.approx(0.0, abs=1e-5)


# ---------------------------------------------------------------------------
# OBS6: the schema checker itself
# ---------------------------------------------------------------------------


def _valid_lines(tmp_path):
    run_dir = str(tmp_path / "run")
    tr = _trainer(tmp_path, sink="jsonl", run_dir=run_dir)
    tr.run(3, log=None)
    tr.close()
    return open(os.path.join(run_dir, "run.jsonl")).read().splitlines()


def test_obs6_checker_accepts_and_rejects(tmp_path):
    ct = _check_telemetry()
    schema = ct.load_schema(os.path.join(_ROOT, "tools",
                                         "telemetry_schema.json"))
    lines = _valid_lines(tmp_path)
    assert ct.check_stream(lines, schema) == []

    # unknown field fails
    bad = json.loads(lines[1])
    bad["totally_new_metric"] = 1.0
    errs = ct.check_stream([lines[0], json.dumps(bad)], schema)
    assert any("unknown" in e for e in errs)

    # missing required field fails
    bad = json.loads(lines[1])
    del bad["loss"]
    errs = ct.check_stream([lines[0], json.dumps(bad)], schema)
    assert any("missing" in e for e in errs)

    # non-monotone meta_step fails
    errs = ct.check_stream([lines[0], lines[2], lines[1]], schema)
    assert any("monotone" in e for e in errs)

    # step before manifest fails
    errs = ct.check_stream([lines[1]], schema)
    assert any("before any manifest" in e for e in errs)


def test_obs6_csv_sink(tmp_path):
    run_dir = str(tmp_path / "run")
    tr = _trainer(tmp_path, sink="csv", run_dir=run_dir)
    tr.run(3, log=None)
    tr.close()
    import csv

    path = os.path.join(run_dir, "run.csv")
    rows = list(csv.DictReader(open(path)))
    assert len(rows) == 3
    assert "loss" in rows[0]
    assert os.path.exists(path + ".manifest.json")


# ---------------------------------------------------------------------------
# OBS7: exception-safe tracing
# ---------------------------------------------------------------------------


def test_obs7_session_exports_trace_on_crash(tmp_path):
    from repro.obs import Tracer

    tr = Tracer(enabled=True)
    path = str(tmp_path / "trace.json")
    with pytest.raises(RuntimeError, match="boom"):
        with tr.session(export_path=path):
            with tr.span("obs.dispatch"):
                pass  # a completed span before the crash
            with tr.span("phase.that.crashes"):
                raise RuntimeError("boom")
    # the crash unwound through span()'s finally AND session's cleanup:
    # the trace file exists, loads, and contains both spans
    events = json.load(open(path))["traceEvents"]
    names = [e["name"] for e in events]
    assert "obs.dispatch" in names and "phase.that.crashes" in names
    assert all(e["dur"] >= 0 for e in events)
    assert tr._open == []  # nothing left dangling


def test_obs7_close_open_spans_finalizes_orphans():
    from repro.obs import Tracer

    tr = Tracer(enabled=True)
    # a generator suspended inside a span and never resumed — the
    # abnormal unwind span()'s finally can't see
    gen = tr.span("orphan").__enter__ and None  # noqa: F841
    cm = tr.span("orphan")
    cm.__enter__()
    assert len(tr._open) == 1
    closed = tr.close_open_spans()
    assert closed == ["orphan"]
    assert tr.interrupted == ["orphan"]
    assert [n for n, _, _ in tr.events] == ["orphan"]
    assert tr.close_open_spans() == []  # idempotent


def test_obs7_trainer_crash_still_writes_trace(tmp_path):
    run_dir = str(tmp_path / "run")
    tr = _trainer(tmp_path, sink="jsonl", run_dir=run_dir, trace=True)

    def bomb(*a, **k):
        raise KeyboardInterrupt

    tr.run(2, log=None)  # builds obs, records real spans
    tr.batch_fn = bomb
    with pytest.raises(KeyboardInterrupt):
        tr.run(2, log=None)
    tr.close()
    path = os.path.join(run_dir, "trace.json")
    events = json.load(open(path))["traceEvents"]
    assert any(e["name"] == "obs.dispatch" for e in events)


# ---------------------------------------------------------------------------
# OBS8: torn-tail repair on resume
# ---------------------------------------------------------------------------


def test_obs8_resume_truncates_torn_tail(tmp_path):
    from repro.obs import JsonlSink

    path = str(tmp_path / "run.jsonl")
    sink = JsonlSink(path)
    sink.open_run({"kind": "manifest", "schema_version": 2})
    sink.append({"kind": "step", "meta_step": 0, "loss": 1.0})
    sink.close()
    whole = open(path, "rb").read()
    # cut the last record mid-write (no newline, invalid json)
    with open(path, "wb") as f:
        f.write(whole + b'{"kind": "step", "meta_step": 1, "lo')

    sink2 = JsonlSink(path, resume=True)
    assert sink2.repaired_bytes == len(b'{"kind": "step", "meta_step": 1, "lo')
    sink2.open_run({"kind": "manifest", "schema_version": 2})
    sink2.append({"kind": "step", "meta_step": 1, "loss": 0.9})
    sink2.close()
    recs = [json.loads(l) for l in open(path)]  # every line parses again
    assert [r["kind"] for r in recs] == ["manifest", "step", "manifest",
                                        "step"]
    assert recs[-1]["meta_step"] == 1


def test_obs8_repair_walks_back_over_corrupt_complete_lines(tmp_path):
    from repro.obs.sink import _repair_torn_tail

    path = str(tmp_path / "run.jsonl")
    good = b'{"kind": "manifest"}\n{"kind": "step", "meta_step": 0}\n'
    with open(path, "wb") as f:
        f.write(good + b'garbage not json\n{"kind": "st')
    dropped = _repair_torn_tail(path)
    assert dropped == len(b'garbage not json\n{"kind": "st')
    assert open(path, "rb").read() == good


def test_obs8_repair_noop_on_clean_and_empty_files(tmp_path):
    from repro.obs.sink import _repair_torn_tail

    clean = tmp_path / "clean.jsonl"
    clean.write_text('{"kind": "manifest"}\n')
    assert _repair_torn_tail(str(clean)) == 0
    assert clean.read_text() == '{"kind": "manifest"}\n'
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert _repair_torn_tail(str(empty)) == 0


# ---------------------------------------------------------------------------
# OBS9: schema versioning
# ---------------------------------------------------------------------------


def test_obs9_version_gate(tmp_path):
    ct = _check_telemetry()
    schema = ct.load_schema(os.path.join(_ROOT, "tools",
                                         "telemetry_schema.json"))
    lines = _valid_lines(tmp_path)
    man = json.loads(lines[0])
    assert man["schema_version"] == schema["schema_version"]

    # every known major is accepted (v1 logs predate alert/attribution)
    for v in schema["known_versions"]:
        man2 = dict(man, schema_version=v)
        assert ct.check_stream([json.dumps(man2)] + lines[1:], schema) == []

    # an unknown (future) major is rejected with an actionable message
    man99 = dict(man, schema_version=99)
    errs = ct.check_stream([json.dumps(man99)] + lines[1:], schema)
    assert any("unknown major" in e for e in errs)

    # minor drift within a known major passes ("2.1" -> major 2)
    man21 = dict(man, schema_version="2.1")
    assert ct.check_stream([json.dumps(man21)] + lines[1:], schema) == []


def test_obs9_alert_records_validate(tmp_path):
    ct = _check_telemetry()
    schema = ct.load_schema(os.path.join(_ROOT, "tools",
                                         "telemetry_schema.json"))
    man = _valid_lines(tmp_path)[0]
    ok = {"kind": "alert", "rule": "nonfinite_loss", "metric": "loss",
          "value": None, "severity": "fatal", "halt": True, "meta_step": 3}
    assert ct.check_stream([man, json.dumps(ok)], schema) == []
    # missing field / bad severity / non-bool halt all fail
    bad = dict(ok)
    del bad["rule"]
    assert ct.check_stream([man, json.dumps(bad)], schema)
    assert ct.check_stream(
        [man, json.dumps(dict(ok, severity="panic"))], schema)
    assert ct.check_stream([man, json.dumps(dict(ok, halt="yes"))], schema)
    # alert before any manifest fails
    assert ct.check_stream([json.dumps(ok)], schema)
