"""Hypothesis property tests for the MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.moe import _capacity, _route, init_moe, moe_layer

BASE = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                           dtype="float32")
PARAMS = init_moe(jax.random.PRNGKey(0), BASE)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), T=st.integers(4, 48),
       cf=st.floats(0.05, 2.0))
def test_capacity_invariants(seed, T, cf):
    """Per-expert load never exceeds capacity; kept slots route uniquely."""
    cfg = dataclasses.replace(BASE, capacity_factor=cf)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, T, cfg.d_model))
    xt = x.reshape(T, cfg.d_model)
    gates, slot_expert, pos, keep, aux, C = _route(xt, PARAMS, cfg)
    assert C == _capacity(T, cfg)
    se = np.asarray(slot_expert)
    kp = np.asarray(keep)
    ps = np.asarray(pos)
    # kept slots per expert <= C
    for e in range(cfg.num_experts):
        assert kp[se == e].sum() <= C
    # kept (expert, position) pairs are unique (no slot collision)
    pairs = set()
    for i in np.where(kp)[0]:
        key = (int(se[i]), int(ps[i]))
        assert key not in pairs, key
        pairs.add(key)
    # gates are a normalised distribution over the top-k
    g = np.asarray(gates)
    np.testing.assert_allclose(g.sum(-1), 1.0, rtol=1e-4)
    assert (g >= 0).all()
    assert np.isfinite(float(aux))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_moe_output_finite_and_shaped(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, BASE.d_model))
    out, aux = moe_layer(x, PARAMS, BASE)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    assert float(aux) >= 0


def test_capacity_zero_factor_still_defined():
    """Degenerate capacity floors at 8 slots; output stays finite."""
    cfg = dataclasses.replace(BASE, capacity_factor=1e-6)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model))
    out, _ = moe_layer(x, PARAMS, cfg)
    assert jnp.isfinite(out).all()
