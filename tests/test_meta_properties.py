"""Property-based tests (hypothesis) for the meta-optimizer invariants.

System invariants tested:
  I1  mavg with mu=0 is exactly kavg (Remark 2).
  I2  sync == mavg with K=1 (alias identity).
  I3  P identical learners == 1 learner (averaging identity).
  I4  meta update matches the closed form v<-mu v+d, w<-w+v.
  I5  kavg with K=1, P=1 == plain SGD.
  I6  downpour applies nothing during the first tau warmup rounds.
  I7  block-momentum Pallas kernel == jnp path inside the full meta step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.models.simple import mlp_init, mlp_loss
from repro.pack import make_pack_spec
from repro.utils import tree_axpy, tree_norm, tree_sub

D, C, H = 8, 4, 16
PARAMS = mlp_init(jax.random.PRNGKey(0), D, H, C)
# the states below ride the packed flat meta-plane (MAvgConfig.packed
# default); closed-form comparisons against PARAMS happen in packed space
SPEC = make_pack_spec(PARAMS)
PARAMS_PACKED = SPEC.pack(PARAMS)


def _batches(seed, L, K, B=4):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (L, K, B, D))
    y = jax.random.randint(ky, (L, K, B), 0, C)
    return {"x": x, "y": y}


def _run(cfg, batches, n_steps=2, params=PARAMS):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(n_steps):
        state, metrics = step(state, jax.tree.map(lambda a: a + 0 * i, batches))
    return state


def _close(a, b, tol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=tol,
                                   atol=tol)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), k=st.integers(1, 4), lr=st.floats(0.01, 0.3))
def test_i1_mu0_is_kavg(seed, k, lr):
    b = _batches(seed, 2, k)
    s1 = _run(MAvgConfig(algorithm="mavg", num_learners=2, k_steps=k,
                         learner_lr=lr, momentum=0.0), b)
    s2 = _run(MAvgConfig(algorithm="kavg", num_learners=2, k_steps=k,
                         learner_lr=lr, momentum=0.9), b)  # mu ignored by kavg
    _close(s1.global_params, s2.global_params)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), mu=st.floats(0.0, 0.9))
def test_i2_sync_is_k1(seed, mu):
    b = _batches(seed, 2, 1)
    s1 = _run(MAvgConfig(algorithm="sync", num_learners=2, k_steps=1,
                         learner_lr=0.1, momentum=mu), b)
    s2 = _run(MAvgConfig(algorithm="mavg", num_learners=2, k_steps=1,
                         learner_lr=0.1, momentum=mu), b)
    _close(s1.global_params, s2.global_params)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), k=st.integers(1, 3))
def test_i3_identical_learners_collapse(seed, k):
    """If every learner sees the same data, P learners == 1 learner."""
    b1 = _batches(seed, 1, k)
    b4 = jax.tree.map(lambda a: jnp.broadcast_to(a, (4,) + a.shape[1:]), b1)
    s1 = _run(MAvgConfig(algorithm="mavg", num_learners=1, k_steps=k,
                         learner_lr=0.1, momentum=0.5), b1)
    s4 = _run(MAvgConfig(algorithm="mavg", num_learners=4, k_steps=k,
                         learner_lr=0.1, momentum=0.5), b4)
    _close(s1.global_params, s4.global_params)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), mu=st.floats(0.0, 0.9),
       eta=st.floats(0.5, 1.5))
def test_i4_block_momentum_closed_form(seed, mu, eta):
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=mu, meta_lr=eta)
    b = _batches(seed, 2, 2)
    state0 = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state1, _ = step(state0, b)
    # recompute: run only the local phase via kavg displacement
    cfg0 = MAvgConfig(algorithm="kavg", num_learners=2, k_steps=2,
                      learner_lr=0.1, meta_lr=1.0)
    s_kavg, _ = jax.jit(make_meta_step(mlp_loss, cfg0))(
        init_state(PARAMS, cfg0), b
    )
    d = tree_sub(s_kavg.global_params, PARAMS_PACKED)  # kavg: w' = w + d
    v_expect = jax.tree.map(lambda di: eta * di, d)  # v0 = 0
    w_expect = tree_axpy(1.0, v_expect, PARAMS_PACKED)
    _close(state1.momentum, v_expect)
    _close(state1.global_params, w_expect)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), lr=st.floats(0.01, 0.2))
def test_i5_k1_p1_is_sgd(seed, lr):
    b = _batches(seed, 1, 1)
    s = _run(MAvgConfig(algorithm="kavg", num_learners=1, k_steps=1,
                        learner_lr=lr), b, n_steps=1)
    (_, _), g = jax.value_and_grad(mlp_loss, has_aux=True)(
        PARAMS, jax.tree.map(lambda a: a[0, 0], b)
    )
    expect = SPEC.pack(tree_axpy(-lr, g, PARAMS))
    _close(s.global_params, expect)


def test_i6_downpour_warmup():
    cfg = MAvgConfig(algorithm="downpour", num_learners=2, k_steps=2,
                     learner_lr=0.1, staleness=3)
    state = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(3):
        state, _ = step(state, _batches(i, 2, 2))
        # global params frozen until the staleness queue warms up
        if i < 2:
            _close(state.global_params, PARAMS_PACKED)
    state, _ = step(state, _batches(99, 2, 2))
    delta = float(tree_norm(tree_sub(state.global_params, PARAMS_PACKED)))
    assert delta > 1e-6  # updates flow after warmup


def test_i7_pallas_meta_step_matches_jnp():
    b = _batches(123, 2, 2)
    base = dict(algorithm="mavg", num_learners=2, k_steps=2,
                learner_lr=0.1, momentum=0.6)
    s_jnp = _run(MAvgConfig(**base, use_pallas=False), b)
    s_pl = _run(MAvgConfig(**base, use_pallas=True), b)
    _close(s_jnp.global_params, s_pl.global_params, tol=1e-4)
    _close(s_jnp.momentum, s_pl.momentum, tol=1e-4)


def test_nesterov_differs_but_converges():
    b = _batches(5, 2, 2)
    s_hb = _run(MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                           learner_lr=0.1, momentum=0.6), b, n_steps=3)
    s_nv = _run(MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                           learner_lr=0.1, momentum=0.6, nesterov=True), b,
                n_steps=3)
    diff = float(tree_norm(tree_sub(s_hb.global_params, s_nv.global_params)))
    assert diff > 1e-7
    for leaf in jax.tree.leaves(s_nv.global_params):
        assert jnp.isfinite(leaf).all()
