"""Zero-copy meta phase acceptance tests (DESIGN.md §10).

Invariants:
  ZC1  donation parity: the donated meta step (jax.jit donate_argnums on
       the MetaState) is bitwise the non-donated step over 10
       meta-iterations, for flat / hierarchical / gossip x dense /
       int8+EF — donation is pure buffer aliasing, never numerics.
  ZC2  donation contract: a donated input state is dead after the call
       (re-use raises), and make_jit_meta_step gates donation on
       MAvgConfig.donate.
  ZC3  fused momentum->broadcast: the oracle route is bit-identical to
       the unfused two-step path it replaces (block_momentum_update then
       cast + tree_broadcast_learners); the Pallas kernel matches the
       oracle at the repo's kernel tolerance (CPU FMA contraction differs
       between separately compiled programs, same as block_momentum); and
       within each route the learner plane is exactly the cast broadcast
       of the new meta params.
  ZC4  compress-only kernel: pack_compress == pack_update on a zero gp
       plane, bitwise, on both the kernel and oracle routes; the EF
       residual it emits equals the separate tree_sub(delta, c) pass it
       replaces, bitwise (CompressedReducer._compress_residual).
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CommConfig, MAvgConfig, TopologyConfig
from repro.core.meta import STATE_ARGNUM, init_state, make_jit_meta_step, make_meta_step
from repro.kernels import ops, ref
from repro.models.simple import mlp_init, mlp_loss

D, C, H = 8, 4, 16
PARAMS = mlp_init(jax.random.PRNGKey(0), D, H, C)
RNG = np.random.RandomState(11)


def _batches(seed, L, K, B=4):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _cfg(topo_kind: str, scheme: str) -> MAvgConfig:
    comm = CommConfig(scheme=scheme, error_feedback=(scheme != "dense"))
    if topo_kind == "flat":
        return MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                          learner_lr=0.1, momentum=0.6, comm=comm)
    topo = (TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                           outer_momentum=0.3, inner_comm=comm)
            if topo_kind == "hierarchical"
            else TopologyConfig(kind="gossip", graph="exponential",
                                momentum_tracking=True, inner_comm=comm))
    return MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                      learner_lr=0.1, momentum=0.6, topology=topo)


# ---------------------------------------------------------------------------
# ZC1: donated == non-donated, bitwise, 10 meta-iterations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo_kind", ["flat", "hierarchical", "gossip"])
@pytest.mark.parametrize("scheme", ["dense", "int8"])
def test_zc1_donation_parity_bitwise(topo_kind, scheme):
    cfg = _cfg(topo_kind, scheme)
    finals = {}
    for donate in (False, True):
        state = init_state(PARAMS, cfg)
        step = make_jit_meta_step(mlp_loss, cfg, donate=donate)
        for i in range(10):
            state, metrics = step(
                state, _batches(i, cfg.num_learners, cfg.k_steps)
            )
        finals[donate] = (state, metrics)
    _bitwise(finals[False][0], finals[True][0])
    _bitwise(finals[False][1], finals[True][1])


def test_zc1_donation_parity_per_leaf_path():
    """Donation is orthogonal to packing: the legacy per-leaf state
    donates leaf-wise with the same bitwise guarantee."""
    cfg = dc.replace(_cfg("flat", "dense"), packed=False)
    finals = {}
    for donate in (False, True):
        state = init_state(PARAMS, cfg)
        step = make_jit_meta_step(mlp_loss, cfg, donate=donate)
        for i in range(10):
            state, _ = step(state, _batches(i, cfg.num_learners, cfg.k_steps))
        finals[donate] = state
    _bitwise(finals[False], finals[True])


# ---------------------------------------------------------------------------
# ZC2: the donation contract
# ---------------------------------------------------------------------------


def test_zc2_donated_input_is_dead():
    cfg = _cfg("flat", "dense")
    state = init_state(PARAMS, cfg)
    step = make_jit_meta_step(mlp_loss, cfg)  # cfg.donate defaults on
    new_state, _ = step(state, _batches(0, cfg.num_learners, cfg.k_steps))
    with pytest.raises((RuntimeError, ValueError), match="deleted|donated"):
        np.asarray(state.global_params)
    # the returned state is live and steps again
    new_state, _ = step(new_state, _batches(1, cfg.num_learners, cfg.k_steps))
    assert np.isfinite(np.asarray(new_state.global_params)).all()


def test_zc2_donate_gated_on_config():
    cfg = dc.replace(_cfg("flat", "dense"), donate=False)
    state = init_state(PARAMS, cfg)
    step = make_jit_meta_step(mlp_loss, cfg)
    step(state, _batches(0, cfg.num_learners, cfg.k_steps))
    # donate=False: the input state survives the call
    assert np.isfinite(np.asarray(state.global_params)).all()
    assert STATE_ARGNUM == 0


def test_zc2_trainer_checkpoints_returned_state(tmp_path):
    """The Trainer under donation: runs, checkpoints mid-run (off the
    returned state), and the checkpoint restores into a resumed run."""
    from repro.checkpoint import latest_checkpoint, load_state
    from repro.configs.base import TrainConfig
    from repro.core.trainer import Trainer

    mcfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2)
    assert mcfg.donate
    tcfg = TrainConfig(model=None, mavg=mcfg, meta_steps=4, log_every=10,
                       checkpoint_dir=str(tmp_path), checkpoint_every=2)

    def bf(rng, step):
        kx, ky = jax.random.split(rng)
        return {"x": jax.random.normal(kx, (2, 2, 4, D)),
                "y": jax.random.randint(ky, (2, 2, 4), 0, C)}

    tr = Trainer(tcfg, mlp_loss, lambda r: mlp_init(r, D, H, C), bf)
    hist = tr.run(log=None)
    assert len(hist) == 4
    path = latest_checkpoint(str(tmp_path))
    assert path is not None and path.endswith("step_00000004.npz")
    restored = load_state(path, jax.eval_shape(lambda: tr.state))
    _bitwise(restored, jax.tree.map(lambda x: x, tr.state))


# ---------------------------------------------------------------------------
# ZC3: fused momentum -> broadcast
# ---------------------------------------------------------------------------


def _wva(rows=24):
    return (jnp.asarray(RNG.randn(rows, 128), jnp.float32)
            for _ in range(3))


@pytest.mark.parametrize("nesterov", [False, True])
@pytest.mark.parametrize("ldtype", [jnp.float32, jnp.bfloat16])
def test_zc3_oracle_route_bitwise_vs_unfused(nesterov, ldtype):
    from repro.topology.base import block_momentum_update
    from repro.utils import tree_broadcast_learners, tree_cast

    w, v, a = _wva()
    L = 5

    def fused(w, v, a):
        return ops.fused_momentum_broadcast(
            w, v, a, mu=0.7, eta=0.9, num_learners=L, ldtype=ldtype,
            nesterov=nesterov, use_pallas=False,
        )

    def unfused(w, v, a):
        gp, vv = block_momentum_update(w, v, a, mu=0.7, eta=0.9,
                                       nesterov=nesterov)
        return gp, vv, tree_broadcast_learners(tree_cast(gp, ldtype), L)

    _bitwise(jax.jit(fused)(w, v, a), jax.jit(unfused)(w, v, a))


@pytest.mark.parametrize("nesterov", [False, True])
def test_zc3_kernel_matches_oracle(nesterov):
    w, v, a = _wva(rows=40)
    L = 3
    out_k = ops.fused_momentum_broadcast(
        w, v, a, mu=0.7, eta=1.3, num_learners=L, ldtype=jnp.bfloat16,
        nesterov=nesterov, use_pallas=True, interpret=True,
    )
    out_r = ref.fused_momentum_broadcast_ref(
        w, v, a, 0.7, 1.3, L, jnp.bfloat16, nesterov=nesterov
    )
    for x, y in zip(out_k, out_r):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=1e-5, atol=1e-6,
        )
    # shapes/dtypes: (rows,128) f32 x2 + (L, rows, 128) learner dtype
    assert out_k[2].shape == (L, 40, 128) and out_k[2].dtype == jnp.bfloat16


def test_zc3_learner_plane_is_cast_broadcast():
    """Within each route the emitted learner plane is EXACTLY the cast
    broadcast of that route's new meta params — no drift between what the
    meta plane holds and what the learners restart from."""
    w, v, a = _wva()
    for use_pallas in (False, True):
        gp2, _v2, lrn = ops.fused_momentum_broadcast(
            w, v, a, mu=0.6, eta=1.0, num_learners=4, ldtype=jnp.bfloat16,
            use_pallas=use_pallas, interpret=True,
        )
        want = np.broadcast_to(
            np.asarray(gp2.astype(jnp.bfloat16), np.float32)[None],
            (4, 24, 128),
        )
        np.testing.assert_array_equal(np.asarray(lrn, np.float32), want)


def test_zc3_flat_fused_trajectory_matches_pr4_path():
    """The FlatAllReduce wiring through the fused kernel keeps the packed
    dense trajectory bitwise on the per-leaf (PR 4 oracle) trajectory."""
    cfg = _cfg("flat", "dense")
    state_p = init_state(PARAMS, cfg)
    state_l = init_state(PARAMS, dc.replace(cfg, packed=False))
    step_p = jax.jit(make_meta_step(mlp_loss, cfg))
    step_l = jax.jit(make_meta_step(mlp_loss, dc.replace(cfg, packed=False)))
    for i in range(5):
        b = _batches(i, cfg.num_learners, cfg.k_steps)
        state_p, _ = step_p(state_p, b)
        state_l, _ = step_l(state_l, b)
    _bitwise(state_p.global_params, state_p.spec.pack(state_l.global_params))
    _bitwise(state_p.momentum, state_p.spec.pack(state_l.momentum))


# ---------------------------------------------------------------------------
# ZC4: compress-only kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("L,rows,block", [(2, 8, 8), (4, 64, None),
                                          (3, 24, 8)])
def test_zc4_compress_only_matches_pack_update_zero_gp(use_pallas, L, rows,
                                                       block):
    d = jnp.asarray(RNG.randn(L, rows, 128) * 0.05, jnp.float32)
    u = jnp.asarray(RNG.rand(L, rows, 128), jnp.float32)
    co = ops.pack_compress(d, u, block=block, use_pallas=use_pallas,
                           interpret=True)
    pu = ops.pack_update(d, jnp.zeros((rows, 128), jnp.float32), None, u,
                         block=block, use_pallas=use_pallas, interpret=True)
    for name, x, y in zip(("c", "err", "scales"), co, pu):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=name)
    # non-EF route: the err plane is never produced (with_err=False — a
    # pallas_call output can't be DCE'd), c/scales stay bitwise
    c2, err2, s2 = ops.pack_compress(d, u, block=block, with_err=False,
                                     use_pallas=use_pallas, interpret=True)
    assert err2 is None
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(co[0]))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(co[2]))


def test_zc4_compress_residual_matches_two_pass():
    """QuantReducer._compress_residual (the err the kernel computed
    in-register) is bitwise the fallback _compress + tree_sub pass, on
    the packed plane and on a per-leaf pytree."""
    from repro.comm import QuantReducer
    from repro.utils import tree_sub

    red = QuantReducer(dtype="int8")
    step = jnp.int32(3)
    # packed plane
    delta = jnp.asarray(RNG.randn(4, 16, 128) * 0.1, jnp.float32)
    c1, wire1 = red._compress(delta, step)
    c2, err2, wire2 = red._compress_residual(delta, step)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(
        np.asarray(tree_sub(delta, c1)), np.asarray(err2)
    )
    assert wire1 == wire2
    # per-leaf pytree falls back to the generic two-pass route
    tree = {"a": jnp.asarray(RNG.randn(4, 37) * 0.1, jnp.float32),
            "b": jnp.asarray(RNG.randn(4, 5, 9) * 0.1, jnp.float32)}
    c3, err3, wire3 = red._compress_residual(tree, step)
    c4, wire4 = red._compress(tree, step)
    _bitwise(c3, c4)
    _bitwise(err3, tree_sub(tree, c3))
    assert wire3 == wire4


def test_zc4_gossip_ef_trajectory_matches_pr4_route():
    """The gossip int8+EF mix through the compress-only kernel stays
    bitwise on what the PR 4 route (pack_update with a synthesized zero
    gp plane + tree_sub residual) produced."""
    from repro.comm import ErrorFeedback, QuantReducer
    from repro.topology.gossip import compress_stack
    from repro.utils import tree_sub

    red = ErrorFeedback(QuantReducer(dtype="int8"))
    delta = jnp.asarray(RNG.randn(4, 16, 128) * 0.1, jnp.float32)
    res = jnp.asarray(RNG.randn(4, 16, 128) * 1e-3, jnp.float32)
    learners = jnp.asarray(RNG.randn(4, 16, 128), jnp.float32)
    step = jnp.int32(5)
    c, new_res, wire = compress_stack(red, delta, res, step=step,
                                      learners=learners)
    # PR 4 route, reproduced inline
    d_ef = delta + res
    c_old, wire_old = red.inner._compress(d_ef, step)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_old))
    np.testing.assert_array_equal(
        np.asarray(new_res), np.asarray(tree_sub(d_ef, c_old))
    )
    assert wire == wire_old
