"""Regression-sentinel acceptance tests (obs.baseline + tools/bench_compare).

Invariants:
  BCH1  trajectory stores: append-only JSONL, one point per run, newest
        rows recoverable; a torn final line is skipped, not fatal.
  BCH2  compare: values inside the acceptance interval pass; an injected
        >=10% regression in measured kernel time OR peak-state bytes
        fails against a tol_rel < 0.10 baseline (the acceptance pin of
        PR 7); a metric whose selector matches no row is a violation
        (vanished measurement); NaN is a violation.
  BCH3  seed_spec fills relative baselines with the loosest honest value
        per direction and leaves absolute bounds alone.
  BCH4  the bench_compare CLI exits 0 on a healthy trajectory and 1 on a
        regressed one, loading specs from the baselines directory; it is
        importable and runnable without jax on the path.
  BCH5  the committed benchmarks/expected/ specs select rows the suites
        actually emit (field/selector spelling can't silently rot).
"""
import importlib.util
import json
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load(name, *parts):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_ROOT, *parts))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bl = _load("obs_baseline", "src", "repro", "obs", "baseline.py")
bc = _load("bench_compare", "tools", "bench_compare.py")


ROWS = [
    {"kind": "row", "row_kind": "attribution", "op": "pack_update",
     "median_us": 1000.0, "achieved_gbps": 20.0},
    {"kind": "row", "row_kind": "hbm_peak_state", "arch": "llama3-405b",
     "peak_donated_bytes": 1.0e12, "ratio": 0.55},
]

SPEC = {
    "suite": "pack",
    "metrics": [
        {"name": "kernel time", "field": "median_us",
         "select": {"row_kind": "attribution", "op": "pack_update"},
         "baseline": 1000.0, "tol_rel": 0.05, "direction": "min"},
        {"name": "peak state bytes", "field": "peak_donated_bytes",
         "select": {"row_kind": "hbm_peak_state"},
         "baseline": 1.0e12, "tol_rel": 0.05, "direction": "min"},
        {"name": "peak ratio", "field": "ratio",
         "select": {"row_kind": "hbm_peak_state"}, "max": 0.6},
    ],
}


def _mutate(rows, row_kind, field, factor):
    out = []
    for r in rows:
        r = dict(r)
        if r.get("row_kind") == row_kind:
            r[field] = r[field] * factor
        out.append(r)
    return out


# ---------------------------------------------------------------------------
# BCH1: trajectory stores
# ---------------------------------------------------------------------------


def test_bch1_append_and_load_roundtrip(tmp_path):
    path = bl.trajectory_path(str(tmp_path), "pack")
    assert path.endswith("BENCH_pack.json")
    bl.append_trajectory(path, "pack", ROWS, manifest={"backend": "cpu"},
                         created_unix=100.0)
    bl.append_trajectory(path, "pack",
                         _mutate(ROWS, "attribution", "median_us", 2.0),
                         manifest={"backend": "cpu"}, created_unix=200.0)
    pts = bl.load_trajectory(path)
    assert len(pts) == 2
    assert [p["created_unix"] for p in pts] == [100.0, 200.0]
    assert pts[0]["manifest"] == {"backend": "cpu"}
    latest = bl.latest_rows(path, suite="pack")
    assert latest[0]["median_us"] == 2000.0  # newest point wins
    assert bl.latest_rows(path, suite="other") == []


def test_bch1_torn_tail_is_skipped(tmp_path):
    path = bl.trajectory_path(str(tmp_path), "pack")
    bl.append_trajectory(path, "pack", ROWS, manifest={})
    with open(path, "a") as f:
        f.write('{"kind": "trajectory", "suite": "pack", "rows": [{"tr')
    pts = bl.load_trajectory(path)
    assert len(pts) == 1
    assert bl.latest_rows(path)[0]["row_kind"] == "attribution"


# ---------------------------------------------------------------------------
# BCH2: compare — the acceptance pin
# ---------------------------------------------------------------------------


def test_bch2_healthy_rows_pass():
    assert bl.compare(ROWS, SPEC) == []
    # 4% over a 5% tolerance still passes
    assert bl.compare(_mutate(ROWS, "attribution", "median_us", 1.04),
                      SPEC) == []


def test_bch2_ten_pct_kernel_time_regression_fails():
    rows = _mutate(ROWS, "attribution", "median_us", 1.10)
    v = bl.compare(rows, SPEC)
    assert len(v) == 1 and "kernel time" in v[0]


def test_bch2_ten_pct_peak_state_regression_fails():
    rows = _mutate(ROWS, "hbm_peak_state", "peak_donated_bytes", 1.10)
    v = bl.compare(rows, SPEC)
    assert len(v) == 1 and "peak state bytes" in v[0]


def test_bch2_absolute_bound_and_direction_max():
    rows = _mutate(ROWS, "hbm_peak_state", "ratio", 1.2)  # 0.66 > 0.6
    assert any("peak ratio" in v for v in bl.compare(rows, SPEC))
    spec = {"metrics": [{"name": "bw", "field": "achieved_gbps",
                         "select": {"row_kind": "attribution"},
                         "baseline": 20.0, "tol_rel": 0.2,
                         "direction": "max"}]}
    assert bl.compare(ROWS, spec) == []  # 20 >= 16
    assert bl.compare(_mutate(ROWS, "attribution", "achieved_gbps", 0.5),
                      spec)  # 10 < 16: higher-is-better regressed


def test_bch2_vanished_measurement_is_a_violation():
    rows = [r for r in ROWS if r["row_kind"] != "attribution"]
    v = bl.compare(rows, SPEC)
    assert any("vanished" in s for s in v)


def test_bch2_nan_is_a_violation():
    rows = _mutate(ROWS, "attribution", "median_us", float("nan"))
    assert any("NaN" in s for s in bl.compare(rows, SPEC))


# ---------------------------------------------------------------------------
# BCH3: seeding
# ---------------------------------------------------------------------------


def test_bch3_seed_spec_takes_worst_value_per_direction():
    rows = ROWS + _mutate(ROWS, "attribution", "median_us", 1.5)
    seeded = bl.seed_spec(rows, SPEC)
    by_name = {m["name"]: m for m in seeded["metrics"]}
    assert by_name["kernel time"]["baseline"] == 1500.0  # max of min-dir
    assert by_name["peak state bytes"]["baseline"] == 1.0e12
    assert "baseline" not in by_name["peak ratio"]  # absolute untouched
    # seeded spec accepts the rows it was seeded from
    assert bl.compare(rows, seeded) == []


# ---------------------------------------------------------------------------
# BCH4: the CLI
# ---------------------------------------------------------------------------


def _cli_fixture(tmp_path, rows):
    bench = tmp_path / "bench_out"
    base = tmp_path / "expected"
    base.mkdir(parents=True)
    path = bl.trajectory_path(str(bench), "pack")
    bl.append_trajectory(path, "pack", rows, manifest={})
    (base / "pack.json").write_text(json.dumps(SPEC))
    return path, str(base)


def test_bch4_cli_passes_then_fails_on_regression(tmp_path, capsys):
    path, base = _cli_fixture(tmp_path, ROWS)
    assert bc.main([path, "--baselines", base]) == 0
    assert "ok: pack" in capsys.readouterr().out

    path2, base2 = _cli_fixture(
        tmp_path / "bad", _mutate(ROWS, "attribution", "median_us", 1.10))
    assert bc.main([path2, "--baselines", base2]) == 1
    assert "REGRESSION pack" in capsys.readouterr().err


def test_bch4_missing_spec_skips_not_fails(tmp_path):
    bench = tmp_path / "bench_out"
    path = bl.trajectory_path(str(bench), "mystery_suite")
    bl.append_trajectory(path, "mystery_suite", ROWS, manifest={})
    assert bc.main([path, "--baselines", str(tmp_path / "none")]) == 0


def test_bch4_suite_name_resolution(tmp_path):
    assert bc.suite_of("/x/BENCH_pack.json") == "pack"
    assert bc.suite_of("/x/kernel_bench.json") == "kernel"
    assert bc.suite_of("/x/whatever.jsonl", {"suite": "topology"}) \
        == "topology"


def test_bch4_seed_mode_rewrites_spec(tmp_path):
    path, base = _cli_fixture(
        tmp_path, _mutate(ROWS, "attribution", "median_us", 3.0))
    assert bc.main([path, "--baselines", base, "--seed"]) == 0
    spec = json.loads((tmp_path / "expected" / "pack.json").read_text())
    by_name = {m["name"]: m for m in spec["metrics"]}
    assert by_name["kernel time"]["baseline"] == 3000.0
    assert bc.main([path, "--baselines", base]) == 0  # now passes


# ---------------------------------------------------------------------------
# BCH5: the committed specs match what the suites emit
# ---------------------------------------------------------------------------


def test_bch5_committed_specs_are_wellformed():
    exp = os.path.join(_ROOT, "benchmarks", "expected")
    suites = sorted(os.listdir(exp))
    assert {"kernel.json", "pack.json", "topology.json"} <= set(suites)
    for fname in suites:
        spec = json.load(open(os.path.join(exp, fname)))
        assert spec["suite"] == fname[:-len(".json")]
        assert spec["metrics"], fname
        for m in spec["metrics"]:
            assert "field" in m and "select" in m and "name" in m
            relative = any(k in m for k in ("baseline", "tol_rel"))
            absolute = any(k in m for k in ("min", "max"))
            assert relative or absolute, m["name"]
            if "baseline" in m:
                # committed relative baselines must be seeded numbers,
                # not the null placeholders of a fresh spec
                assert isinstance(m["baseline"], (int, float)), m["name"]
