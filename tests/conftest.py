import jax

# Tests run on the single CPU device; the dry-run (and only the dry-run)
# sets the 512-device host platform in its own process.
jax.config.update("jax_enable_x64", False)
