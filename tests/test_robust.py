"""repro.robust acceptance tests (DESIGN.md §14).

The contract, pinned here:

  R1  robust-reduce kernel: trim=0 is bitwise the plain mean; the Pallas
      kernel (interpret) agrees bit-for-bit with the jnp oracle for every
      trim; median_trim resolves the coordinate-wise median.
  R2  plumbing no-op: RobustConfig(estimator='mean', clip off, score off)
      is bitwise identical to robust=None on every topology — the robust
      hooks themselves never perturb a run they don't act on.
  R3  the trimmed mean bounds a corrupt learner: one poisoned learner
      moves the plain-mean consensus by O(magnitude / L) while the
      trimmed consensus stays within the benign spread.
  R4  rejection, not deferral: a clipped mix is bitwise identical —
      global params AND error-feedback residual — to a robust-off mix fed
      the pre-clipped learner stack, so the clipped-away mass never
      enters the EF residual and is never replayed.
  R5  the trailing-median clip budget: no clipping during warmup, the
      over-budget learner (and only it) is clipped after, and the
      unclipped learners pass through bit-identical.
  R6  Krum-style anomaly scores single out the corrupted learner.
  R7  the ring state rides MetaState.topo through jit on every clipping
      topology, and the full robust stack runs end to end on all four.
  R8  robust telemetry: Trainer repackages the robust_* metrics into
      schema-v4 ``robust`` records, step rows stay on the step schema,
      and tools/check_telemetry.py validates the stream.
  R9  inline quarantine: a persistently-anomalous learner is masked out
      of the elastic membership mid-run — no HealthHalt, no rollback —
      and the run completes its target steps.
  R10 finite faults (chaos): finite_scale / finite_bitflip corrupt the
      payload with values the finite guard CANNOT see (nonfinite_learners
      stays 0) — the threat model repro.robust exists for.
  R11 config validation: impossible trims and flat-topology quarantine
      are rejected up front.
"""
import dataclasses as dc
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chaos import ChaosConfig, FaultSchedule, FaultSpec, PayloadCorruptor
from repro.configs.base import (
    AsyncConfig,
    CommConfig,
    ElasticConfig,
    MAvgConfig,
    ObsConfig,
    RobustConfig,
    TopologyConfig,
    TrainConfig,
)
from repro.core import Trainer
from repro.core.meta import init_state, make_meta_step
from repro.data import classif_batch_fn
from repro.kernels import ops
from repro.kernels.robust_reduce import median_trim, robust_reduce_3d
from repro.models.simple import mlp_init, mlp_loss
from repro.robust import (
    RobustAggregator,
    anomaly_scores,
    make_robust,
    robust_ring_buffers,
)
from repro.topology import make_topology

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D, C, H = 8, 4, 16
PARAMS = mlp_init(jax.random.PRNGKey(0), D, H, C)


def _batches(seed, L, K, B=4):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _leaves_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _run(cfg, n_steps=3, params=PARAMS):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    metrics = {}
    for i in range(n_steps):
        state, metrics = step(
            state, _batches(i, cfg.num_learners, cfg.k_steps)
        )
    return state, metrics


# ---------------------------------------------------------------------------
# R1: kernel parity
# ---------------------------------------------------------------------------


def test_r1_trim0_is_bitwise_mean():
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 16, 128), jnp.float32)
    out = robust_reduce_3d(x, trim=0, interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(jnp.mean(x, axis=0)))
    # the ops router takes the same kernel path for a packed-shaped stack
    out2 = ops.robust_reduce(x, trim=0, use_pallas=True, interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(out2))


@pytest.mark.parametrize("trim", [0, 1, 2])
def test_r1_kernel_matches_oracle(trim):
    from repro.kernels import ref

    x = jax.random.normal(jax.random.PRNGKey(2), (6, 16, 128), jnp.float32)
    k = robust_reduce_3d(x, trim=trim, interpret=True)
    # compare under jit — the only way either runs in production; the
    # EAGER oracle may reassociate the L-sum differently for odd L
    r = jax.jit(lambda y: ref.robust_reduce_ref(y, trim))(x)
    assert np.array_equal(np.asarray(k), np.asarray(r))


def test_r1_median_trim_is_the_median():
    assert median_trim(5) == 2 and median_trim(4) == 1 and median_trim(2) == 0
    for L in (5, 6):
        x = jax.random.normal(jax.random.PRNGKey(3), (L, 8, 128), jnp.float32)
        m = robust_reduce_3d(x, trim=median_trim(L), interpret=True)
        np.testing.assert_allclose(
            np.asarray(m), np.median(np.asarray(x), axis=0), atol=1e-6
        )


# ---------------------------------------------------------------------------
# R2: inert robust config == robust=None, bitwise, on every topology
# ---------------------------------------------------------------------------

_INERT = RobustConfig(estimator="mean", clip_mult=0.0, score=False)

_TOPOS = {
    "flat": {},
    "hier": dict(topology=TopologyConfig(kind="hierarchical", groups=2)),
    "gossip": dict(topology=TopologyConfig(kind="gossip", graph="ring")),
    "async": dict(topology=TopologyConfig(
        kind="async", server=AsyncConfig(staleness=2))),
}


@pytest.mark.parametrize("kind", sorted(_TOPOS))
def test_r2_inert_robust_is_bitwise_off(kind):
    base = dict(algorithm="mavg", num_learners=4, k_steps=2,
                learner_lr=0.1, momentum=0.6, **_TOPOS[kind])
    s_off, _ = _run(MAvgConfig(**base))
    s_on, m_on = _run(MAvgConfig(**base, robust=_INERT))
    assert _leaves_equal(s_off.global_params, s_on.global_params)
    assert _leaves_equal(s_off.momentum, s_on.momentum)
    assert _leaves_equal(s_off.learners, s_on.learners)
    assert not any(k.startswith("robust_clip") for k in m_on)


def test_r2_inert_robust_is_bitwise_off_unpacked():
    base = dict(algorithm="mavg", num_learners=4, k_steps=2,
                learner_lr=0.1, momentum=0.6, packed=False)
    s_off, _ = _run(MAvgConfig(**base))
    s_on, _ = _run(MAvgConfig(**base, robust=_INERT))
    assert _leaves_equal(s_off.global_params, s_on.global_params)
    assert _leaves_equal(s_off.learners, s_on.learners)


# ---------------------------------------------------------------------------
# R3: the trimmed mean bounds a corrupt learner
# ---------------------------------------------------------------------------


def _flat_mix_once(cfg, learners, gp, v, res, topo):
    topo_obj = make_topology(cfg)
    return topo_obj.mix(learners, gp, v, res, topo, step=0)


def test_r3_trimmed_bounds_corrupt_learner():
    L = 6
    base = dict(algorithm="mavg", num_learners=L, k_steps=2,
                learner_lr=0.1, momentum=0.0)
    cfg_mean = MAvgConfig(**base)
    cfg_trim = MAvgConfig(**base, robust=RobustConfig(
        estimator="trimmed", trim=1, score=False))
    state = init_state(PARAMS, cfg_mean)
    gp, v = state.global_params, state.momentum
    noise = jax.tree.map(
        lambda w: w + 1e-3 * jax.random.normal(
            jax.random.PRNGKey(4), w.shape, jnp.float32).astype(w.dtype),
        state.learners,
    )
    poisoned = jax.tree.map(lambda w: w.at[0].add(1e6), noise)

    def gp_after(cfg, learners):
        res = make_topology(cfg).init_buffers(gp, cfg)[0]
        out, *_ = _flat_mix_once(cfg, learners, gp, v, res, None)
        return out

    def dist(a, b):
        return float(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)
                               - y.astype(jnp.float32)))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        )) ** 0.5

    clean_mean = gp_after(cfg_mean, noise)
    clean_trim = gp_after(cfg_trim, noise)
    dirty_mean = gp_after(cfg_mean, poisoned)
    dirty_trim = gp_after(cfg_trim, poisoned)
    # the plain mean swallows magnitude/L of the poison ...
    assert dist(dirty_mean, clean_mean) > 1e4
    # ... the trimmed mean stays within the benign noise spread
    assert dist(dirty_trim, clean_trim) < 1.0


# ---------------------------------------------------------------------------
# R4: clip rejection — the EF residual never sees the clipped-away mass
# ---------------------------------------------------------------------------


def test_r4_clip_is_rejection_not_deferral():
    base = dict(algorithm="mavg", num_learners=4, k_steps=2,
                learner_lr=0.1, momentum=0.6,
                comm=CommConfig(scheme="int8", error_feedback=True))
    rcfg = RobustConfig(estimator="mean", clip_mult=1.5, clip_window=1,
                        score=False)
    cfg_a = MAvgConfig(**base, robust=rcfg)
    cfg_b = MAvgConfig(**base)
    topo_a, topo_b = make_topology(cfg_a), make_topology(cfg_b)
    state = init_state(PARAMS, cfg_a, topology=topo_a)
    gp, v = state.global_params, state.momentum
    res_a = state.comm_residual
    res_b = topo_b.init_buffers(gp, cfg_b)[0]
    ring = {k: state.topo[k] for k in ("robust_ring", "robust_count")}

    benign = jax.tree.map(
        lambda w: w + 0.01 * jax.random.normal(
            jax.random.PRNGKey(5), w.shape, jnp.float32).astype(w.dtype),
        state.learners,
    )
    # warmup mix (ring not yet full): both sides must agree bitwise
    gp_a, v_a, _, res_a, ring, m_a = topo_a.mix(
        benign, gp, v, res_a, ring, step=0)
    gp_b, v_b, _, res_b, _, _ = topo_b.mix(
        benign, gp, v, res_b, None, step=0)
    assert float(m_a["robust_clipped_learners"]) == 0.0
    assert _leaves_equal(gp_a, gp_b) and _leaves_equal(res_a, res_b)

    # learner 3 blows up; the clip fires on side A
    corrupt = jax.tree.map(lambda w: w.at[3].add(50.0), benign)
    gp_a2, _, _, res_a2, ring2, m_a2 = topo_a.mix(
        corrupt, gp_a, v_a, res_a, ring, step=1)
    assert float(m_a2["robust_clipped_learners"]) == 1.0
    assert int(ring2["robust_count"]) == 2

    # side B (no robust) fed the PRE-CLIPPED stack lands on the same
    # global params AND the same EF residual, bit for bit — the clipped
    # -away mass was rejected before the compressor, not deferred into
    # the residual for replay
    clipped, _, _ = topo_a.robust.clip_learners(corrupt, gp_a, dict(ring))
    gp_b2, _, _, res_b2, _, _ = topo_b.mix(
        clipped, gp_b, v_b, res_b, None, step=1)
    assert _leaves_equal(gp_a2, gp_b2)
    assert _leaves_equal(res_a2, res_b2)


# ---------------------------------------------------------------------------
# R5: trailing-median clip budget (warmup, firing, bit-identity)
# ---------------------------------------------------------------------------


def test_r5_clip_budget_warmup_then_fires():
    rcfg = RobustConfig(estimator="mean", clip_mult=2.0, clip_window=2,
                        score=False)
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     learner_lr=0.1, robust=rcfg)
    ra = make_robust(cfg)
    assert isinstance(ra, RobustAggregator) and ra.has_clip
    gp = {"w": jnp.zeros((32,), jnp.float32)}
    ben = {"w": 0.1 * jax.random.normal(jax.random.PRNGKey(6), (4, 32))}
    big = {"w": ben["w"].at[0].add(1000.0)}

    # warmup: even a blown-up learner passes through untouched
    topo = robust_ring_buffers(rcfg)
    out, _, m = ra.clip_learners(big, gp, topo)
    assert float(m["robust_clipped_learners"]) == 0.0
    assert _leaves_equal(out, big)

    # fill the ring with benign steps, then the budget fires
    topo = robust_ring_buffers(rcfg)
    for _ in range(rcfg.clip_window):
        _, topo, _ = ra.clip_learners(ben, gp, topo)
    out, _, m = ra.clip_learners(big, gp, topo)
    assert float(m["robust_clipped_learners"]) == 1.0
    budget = float(m["robust_clip_budget"])
    assert budget > 0.0
    clipped_norm = float(jnp.linalg.norm(out["w"][0]))
    assert clipped_norm <= budget * (1 + 1e-5)
    # the unclipped learners are bit-identical, not merely close
    assert np.array_equal(np.asarray(out["w"][1:]), np.asarray(big["w"][1:]))


# ---------------------------------------------------------------------------
# R6: anomaly scores
# ---------------------------------------------------------------------------


def test_r6_anomaly_score_singles_out_corrupt_learner():
    delta = {"w": 0.1 * jax.random.normal(jax.random.PRNGKey(7), (6, 64))}
    delta = jax.tree.map(lambda d: d.at[2].add(50.0), delta)
    s = np.asarray(anomaly_scores(delta))
    assert s.shape == (6,)
    assert int(np.argmax(s)) == 2
    peers = np.delete(s, 2)
    assert s[2] > 10.0 * peers.max()


# ---------------------------------------------------------------------------
# R7: ring rides MetaState.topo under jit; full stack on every topology
# ---------------------------------------------------------------------------

_FULL = RobustConfig(estimator="trimmed", trim=1, clip_mult=3.0,
                     clip_window=2, score=True)


@pytest.mark.parametrize("kind", sorted(_TOPOS))
def test_r7_full_robust_stack_end_to_end(kind):
    base = dict(algorithm="mavg", num_learners=4, k_steps=2,
                learner_lr=0.1, momentum=0.6, **_TOPOS[kind])
    # width 2 per hierarchical group cannot trim — the estimator stays
    # 'mean' there; the clip + scores are the robust leg under test
    rcfg = (dc.replace(_FULL, estimator="mean")
            if kind == "hier" else _FULL)
    cfg = MAvgConfig(**base, robust=rcfg)
    state = init_state(PARAMS, cfg)
    assert state.topo["robust_ring"].shape == (rcfg.clip_window,)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(3):
        state, metrics = step(state, _batches(i, 4, 2))
    assert int(state.topo["robust_count"]) == 3
    assert float(np.asarray(state.topo["robust_ring"]).max()) > 0.0
    assert "robust_anomaly_score" in metrics
    assert "robust_clipped_learners" in metrics
    for x in jax.tree.leaves((state.global_params, state.learners)):
        assert np.isfinite(np.asarray(x)).all()


# ---------------------------------------------------------------------------
# R8: trainer telemetry — robust records, schema v4
# ---------------------------------------------------------------------------


def _check_telemetry():
    spec = importlib.util.spec_from_file_location(
        "check_telemetry", os.path.join(_ROOT, "tools", "check_telemetry.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_r8_robust_records_stream_schema_valid(tmp_path):
    L, K, B = 4, 2, 4
    mcfg = MAvgConfig(algorithm="mavg", num_learners=L, k_steps=K,
                      learner_lr=0.1, momentum=0.6, robust=_FULL)
    run_dir = str(tmp_path / "run")
    tcfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=B, meta_steps=4, seed=0,
        log_every=1, obs=ObsConfig(sink="jsonl", run_dir=run_dir),
    )
    trainer = Trainer(
        tcfg, mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D, H, C),
        batch_fn=classif_batch_fn(D, C, L, K, B),
    )
    trainer.run(log=None)
    trainer.close()

    assert len(trainer.robust_records) == 4
    for rb in trainer.robust_records:
        assert rb["kind"] == "robust"
        for k in ("meta_step", "clipped_learners", "trim_fraction",
                  "anomaly_score"):
            assert k in rb
        assert len(rb["scores"]) == L
    assert trainer.robust_records[0]["trim_fraction"] == pytest.approx(0.5)
    # the robust_* scalars were POPPED out of the step rows
    for rec in trainer.history:
        assert not any(k.startswith("robust_") for k in rec)

    ct = _check_telemetry()
    schema = ct.load_schema(
        os.path.join(_ROOT, "tools", "telemetry_schema.json"))
    with open(os.path.join(run_dir, "run.jsonl")) as f:
        lines = list(f)
    assert ct.check_stream(lines, schema) == []
    import json

    kinds = [json.loads(ln)["kind"] for ln in lines if ln.strip()]
    assert kinds.count("robust") == 4


# ---------------------------------------------------------------------------
# R9: inline quarantine — graceful degradation without a rollback
# ---------------------------------------------------------------------------


def test_r9_inline_quarantine_masks_anomalous_learner(tmp_path):
    L, K, B, steps = 4, 2, 4, 6
    rcfg = RobustConfig(estimator="mean", score=True, quarantine_after=2,
                        score_ratio=4.0)
    mcfg = MAvgConfig(
        algorithm="mavg", num_learners=L, k_steps=K, learner_lr=0.05,
        momentum=0.6, robust=rcfg,
        topology=TopologyConfig(
            kind="gossip", graph="ring",
            elastic=ElasticConfig(period=steps, drop_frac=0.0)),
    )
    # sticky finite corruption: learner 3's payload is scaled x100 every
    # step — huge but finite, invisible to the finite guard
    chaos = ChaosConfig(seed=0, horizon=steps, faults=(
        FaultSpec("finite_scale", step=0, learner=3, duration=steps,
                  magnitude=100.0, sticky=True),
    ))
    tcfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=B, meta_steps=steps,
        seed=0, log_every=1, chaos=chaos, obs=ObsConfig(sink="none"),
    )
    trainer = Trainer(
        tcfg, mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D, H, C),
        batch_fn=classif_batch_fn(D, C, L, K, B),
    )
    history = trainer.run(log=None)
    trainer.close()

    # the run COMPLETED — no HealthHalt, no supervisor, no rollback —
    # and the anomalous learner was quarantined inline after 2 windows
    assert len(history) == steps
    assert 3 in trainer.quarantined
    assert trainer.quarantined[3] <= 2
    m = np.asarray(trainer.state.topo["membership"])
    assert (m[:, 3] == 0.0).all()
    assert (m[:, :3] == 1.0).all()
    assert (m.sum(axis=1) >= 1.0).all()
    quarantined_rows = [
        rb for rb in trainer.robust_records if "quarantined" in rb
    ]
    assert quarantined_rows and quarantined_rows[0]["quarantined"] == [3]


# ---------------------------------------------------------------------------
# R10: finite chaos faults — the finite guard cannot see them
# ---------------------------------------------------------------------------


def test_r10_finite_fault_validation():
    with pytest.raises(AssertionError):
        FaultSpec("finite_scale", step=0, learner=0,
                  magnitude=float("inf"))
    with pytest.raises(AssertionError):
        FaultSpec("finite_scale", step=0, learner=0, magnitude=0.0)
    with pytest.raises(AssertionError):
        FaultSpec("finite_scale", step=0, learner=0, magnitude=2.0 ** 41)
    # the exponent-top bit is masked: flipping it would create the
    # inf/NaN the finite guard DOES catch, which defeats the point
    f = FaultSpec("finite_bitflip", step=0, learner=0, bit=31)
    assert f.bit == 29


@pytest.mark.parametrize("fault", [
    FaultSpec("finite_scale", step=0, learner=1, magnitude=64.0),
    FaultSpec("finite_bitflip", step=0, learner=1, bit=29),
])
def test_r10_finite_guard_is_blind_to_finite_corruption(fault):
    L, K = 2, 2
    mcfg = MAvgConfig(algorithm="mavg", num_learners=L, k_steps=K,
                      learner_lr=0.1, momentum=0.6, finite_guard=True)
    cor = PayloadCorruptor(
        FaultSchedule(ChaosConfig(seed=0, horizon=4, faults=(fault,)), L))
    assert cor.active
    plain = jax.jit(make_meta_step(mlp_loss, mcfg))
    dirty = jax.jit(make_meta_step(mlp_loss, mcfg, chaos=cor))
    s0 = init_state(PARAMS, mcfg)
    sp, _ = plain(s0, _batches(0, L, K))
    sd, md = dirty(s0, _batches(0, L, K))
    # the corruption LANDED (trajectory changed) and stayed finite, so
    # the finite guard saw nothing — zero learners reset
    assert not _leaves_equal(sp.global_params, sd.global_params)
    assert float(md["nonfinite_learners"]) == 0.0
    for x in jax.tree.leaves((sd.global_params, sd.learners)):
        assert np.isfinite(np.asarray(x)).all()


# ---------------------------------------------------------------------------
# R11: config validation
# ---------------------------------------------------------------------------


def test_r11_config_validation():
    with pytest.raises(ValueError, match="trim"):
        MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                   robust=RobustConfig(estimator="trimmed", trim=2))
    with pytest.raises(ValueError, match="trim"):
        MAvgConfig(algorithm="mavg", num_learners=8, k_steps=2,
                   topology=TopologyConfig(kind="hierarchical", groups=2),
                   robust=RobustConfig(estimator="trimmed", trim=2))
    with pytest.raises(ValueError, match="quarantine"):
        MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                   robust=RobustConfig(quarantine_after=2))
    with pytest.raises(AssertionError):
        RobustConfig(estimator="mode")
    with pytest.raises(AssertionError):
        RobustConfig(score_ratio=1.0)
    # the degenerate estimator is valid and inert
    assert make_robust(
        MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2)
    ) is None
