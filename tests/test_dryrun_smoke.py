"""Launch-path integration smoke: the dryrun machinery (state shardings,
input specs, lower+compile) works end-to-end on a small mesh in a
subprocess — covers the code path of deliverable (e) without the
512-device cost."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess lower+compile on an 8-dev mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax
from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, InputShape
from repro.launch import dryrun_lib as D
from repro.launch import mesh as meshlib

# shrink the production mesh for the smoke (8 host devices: 4 x 2)
meshlib.SINGLE_POD_SHAPE = (4, 2)
shape = InputShape("train_4k", 64, 8, "train")
INPUT_SHAPES["train_4k"] = shape

cfg = get_config("qwen3-1.7b")
# reduced but model-axis-divisible dims
cfg = dataclasses.replace(
    cfg.reduced(), d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=256,
)
import repro.configs.base as B
orig = B.get_config
B.get_config = lambda a: cfg
import repro.launch.dryrun_lib as DL
DL.get_config = lambda a: cfg

res = DL.run_one("qwen3-1.7b", "train_4k", "single")
assert not res.get("skipped")
assert res["roofline"]["hlo_flops"] > 0
assert res["collectives"]["total"] > 0  # the meta average must appear
print(json.dumps({"ok": True,
                  "bottleneck": res["roofline"]["bottleneck"],
                  "coll": res["collectives"]["total"]}))
"""


def test_dryrun_small_mesh(tmp_path):
    script = tmp_path / "dr.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, str(script)], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"] and payload["coll"] > 0
