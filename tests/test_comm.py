"""repro.comm acceptance tests: Pallas quant kernels (interpret mode) vs
jnp oracles, reducer semantics, the error-feedback invariant, bytes-on-
wire accounting, and the int8+EF convergence criterion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    DenseReducer,
    ErrorFeedback,
    QuantReducer,
    TopKReducer,
    make_reducer,
)
from repro.configs.base import CommConfig, MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.kernels import ops, ref
from repro.kernels import quantize as qk
from repro.models.simple import mlp_init, mlp_loss
from repro.utils import tree_add, tree_sub

RNG = np.random.RandomState(7)
D, C, H = 8, 16, 4  # mlp dims for the training tests


# ---------------------------------------------------------------------------
# kernels: Pallas (interpret) vs jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,block", [(8, 8), (64, 16), (192, 64), (256, 256)])
def test_quantize_kernel_matches_ref(rows, block):
    x = jnp.asarray(RNG.randn(rows, 128) * 0.03, jnp.float32)
    u = jnp.asarray(RNG.rand(rows, 128), jnp.float32)
    q_k, s_k = qk.quantize_2d(x, u, qmax=127, block=block, interpret=True)
    q_r, s_r = ref.quantize_ref(x, u, 127, block)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-7)
    dq_k = qk.dequantize_2d(q_k, s_k, interpret=True)
    dq_r = ref.dequantize_ref(q_r, s_r)
    np.testing.assert_allclose(np.asarray(dq_k), np.asarray(dq_r), rtol=1e-7)


@pytest.mark.parametrize("shape", [(1000,), (33, 7), (3,), (2, 3, 5, 7), (513, 130)])
@pytest.mark.parametrize("dtype", ["int8", "int4", "fp8"])
def test_quant_dequant_error_bound(shape, dtype):
    """Round-trip error is below one wire-grid step per chunk."""
    x = jnp.asarray(RNG.randn(*shape), jnp.float32)
    dq, nchunks = ops.quant_dequant(x, jax.random.PRNGKey(0), dtype=dtype,
                                    use_pallas=True, interpret=True)
    assert dq.shape == x.shape and nchunks >= 1
    # fp8 e4m3: 3 mantissa bits -> half-ulp at the binade top is amax/28
    qmax = {"int8": 127, "int4": 7, "fp8": 28}[dtype]
    bound = float(jnp.max(jnp.abs(x))) / qmax
    assert float(jnp.max(jnp.abs(dq - x))) <= bound * 1.0001


@pytest.mark.slow  # 4096-sample dither sweep, ~80s
def test_stochastic_rounding_unbiased():
    """E[dequant(quant(x))] = x: the property EF + Theorem 1 rely on."""
    x = jnp.asarray(RNG.randn(8, 128) * 0.01, jnp.float32)
    acc = np.zeros(x.shape, np.float64)
    n = 300
    for i in range(n):
        dq, _ = ops.quant_dequant(x, jax.random.PRNGKey(i), dtype="int8",
                                  use_pallas=True, interpret=True)
        acc += np.asarray(dq, np.float64)
    scale = float(jnp.max(jnp.abs(x))) / 127
    # per-element sd of stochastic floor is at most scale/2, so the mean
    # of n draws has sd <= scale/(2 sqrt n); allow 6 sigma over 1024 cells
    tol = 6 * scale / (2 * np.sqrt(n))
    np.testing.assert_allclose(acc / n, np.asarray(x), atol=tol)


def test_masked_zeros_survive_quantization():
    x = jnp.asarray(RNG.randn(16, 128), jnp.float32)
    x = x.at[:8].set(0.0)
    dq, _ = ops.quant_dequant(x, jax.random.PRNGKey(3), dtype="int8",
                              use_pallas=True, interpret=True)
    assert float(jnp.max(jnp.abs(dq[:8]))) == 0.0


# ---------------------------------------------------------------------------
# reducers
# ---------------------------------------------------------------------------


def _learner_stack(seed, L=4):
    gp = mlp_init(jax.random.PRNGKey(seed), D, H, C)
    learners = jax.tree.map(
        lambda x: x[None] + 0.01 * jax.random.normal(
            jax.random.PRNGKey(seed + 1), (L,) + x.shape
        ),
        gp,
    )
    return gp, learners


def test_dense_reducer_is_plain_mean():
    gp, learners = _learner_stack(0)
    avg, res, m = DenseReducer().reduce(learners, gp, None, step=0)
    want = jax.tree.map(lambda x: jnp.mean(x, 0), learners)
    for a, w in zip(jax.tree.leaves(avg), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), rtol=1e-7)
    assert res is None and m["comm_compression"] == 1.0


def test_error_feedback_invariant():
    """delta + e = C(delta + e) + e' holds exactly, leaf by leaf."""
    gp, learners = _learner_stack(1)
    red = ErrorFeedback(TopKReducer(k_frac=0.1, quant_dtype="int8",
                                    use_pallas=True))
    e0 = red.init_residual(gp, 4)
    avg, e1, m = red.reduce(learners, gp, e0, step=jnp.int32(0))
    delta = jax.tree.map(
        lambda w, g: w.astype(jnp.float32) - g[None], learners, gp
    )
    total = tree_add(delta, e0)
    # reconstruct C(total) from avg: C_mean = avg - gp; C = total - e1
    c = tree_sub(total, e1)
    for ci, ti, e1i in zip(jax.tree.leaves(c), jax.tree.leaves(total),
                           jax.tree.leaves(e1)):
        np.testing.assert_allclose(np.asarray(ci + e1i), np.asarray(ti),
                                   rtol=1e-6, atol=1e-7)
    # and avg really is gp + mean_j C_j
    want = jax.tree.map(lambda g, ci: g + jnp.mean(ci, 0), gp, c)
    for a, w in zip(jax.tree.leaves(avg), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), rtol=1e-6,
                                   atol=1e-7)


def test_residual_only_with_error_feedback():
    gp = mlp_init(jax.random.PRNGKey(0), D, H, C)
    for scheme, ef, expect in [("dense", True, False), ("int8", False, False),
                               ("int8", True, True), ("int8_topk", True, True)]:
        cfg = MAvgConfig(num_learners=3,
                         comm=CommConfig(scheme=scheme, error_feedback=ef))
        state = init_state(gp, cfg)
        assert (state.comm_residual is not None) == expect, (scheme, ef)
        if expect:
            assert all(
                x.shape[0] == 3 for x in jax.tree.leaves(state.comm_residual)
            )


def test_topk_mostly_zero_leaf_stays_sparse():
    """thresh == 0 (ties at zero) must not disable sparsification."""
    gp = {"w": jnp.zeros((8, 16))}
    learners = {"w": jnp.zeros((2, 8, 16)).at[:, 0, 0].set(1.0)}
    avg, _, m = TopKReducer(k_frac=0.1).reduce(learners, gp, None, step=0)
    assert int(jnp.sum(avg["w"] != 0)) == 1  # only the real nonzero survives


def test_config_validation():
    with pytest.raises(ValueError):
        MAvgConfig(algorithm="eamsgd", comm=CommConfig(scheme="int8"))
    with pytest.raises(AssertionError):
        CommConfig(scheme="deflate")


def test_injected_ef_reducer():
    """An injected reducer gets its residual via init_state(reducer=...);
    a mismatched init (no reducer) fails loudly instead of silently
    running without error feedback."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2)  # dense cfg
    red = ErrorFeedback(QuantReducer(dtype="int8"))
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    state = init_state(params, cfg, reducer=red)
    assert state.comm_residual is not None
    step = jax.jit(make_meta_step(mlp_loss, cfg, reducer=red))
    state2, m = step(state, _batches(0, 2, 2))
    assert "comm_error_norm" in m
    assert state2.comm_residual is not None

    bad = init_state(params, cfg)  # forgot reducer= -> residual is None
    with pytest.raises(ValueError, match="residual"):
        make_meta_step(mlp_loss, cfg, reducer=red)(bad, _batches(0, 2, 2))


def test_int8_topk_wire_bytes_at_least_4x():
    """Acceptance: >=4x bytes-on-wire reduction vs dense."""
    gp, learners = _learner_stack(2)
    red = make_reducer(MAvgConfig(
        comm=CommConfig(scheme="int8_topk", error_feedback=False)
    ))
    _, _, m = red.reduce(learners, gp, None, step=jnp.int32(0))
    assert m["comm_bytes_dense"] / m["comm_bytes"] >= 4.0
    # int8 alone is ~3.9x; top-k alone 5x at k_frac=0.1
    red8 = make_reducer(MAvgConfig(comm=CommConfig(scheme="int8",
                                                   error_feedback=False)))
    _, _, m8 = red8.reduce(learners, gp, None, step=jnp.int32(0))
    assert 3.5 <= m8["comm_bytes_dense"] / m8["comm_bytes"] <= 4.0


# ---------------------------------------------------------------------------
# end-to-end: mavg + int8 EF converges like dense mavg
# ---------------------------------------------------------------------------


def _batches(seed, L, K, B=8):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _train(comm, steps=40, L=2, K=2):
    cfg = MAvgConfig(algorithm="mavg", num_learners=L, k_steps=K,
                     learner_lr=0.1, momentum=0.7, comm=comm)
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    loss = None
    for i in range(steps):
        state, m = step(state, _batches(i, L, K))
    return float(m["loss"])


def test_int8_ef_matches_dense_convergence():
    """Acceptance: mavg + QuantReducer(int8) + ErrorFeedback reaches final
    loss within 5% of dense mavg at equal meta-iterations, with the Pallas
    kernels active (interpret mode on CPU)."""
    dense = _train(CommConfig(scheme="dense"))
    quant = _train(CommConfig(scheme="int8", error_feedback=True,
                              use_pallas=True))
    assert abs(quant - dense) / dense < 0.05, (quant, dense)


def test_meta_step_metrics_include_comm():
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     comm=CommConfig(scheme="topk", error_feedback=True))
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state2, m = step(state, _batches(0, 2, 2))
    for key in ("comm_bytes", "comm_bytes_dense", "comm_compression",
                "comm_error_norm"):
        assert key in m, key
    # residual structure is stable across steps (jit donation-safe)
    assert jax.tree_util.tree_structure(state.comm_residual) == \
        jax.tree_util.tree_structure(state2.comm_residual)
