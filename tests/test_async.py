"""Async bounded-staleness meta server acceptance tests (DESIGN.md §12).

Invariants:
  A1  tau=0 (uniform all-ones profile) async == synchronous FlatAllReduce
      *bit-for-bit* — packed and per-leaf (the PK3-style parity pin that
      makes the synchronizer refactor a provable no-op for sync runs).
  A2  applied staleness never exceeds the configured bound tau, including
      the de-phased startup window.
  A3  the clock schedule is deterministic and checkpoint-resumable: a run
      halted mid-staleness-window continues bit-identically (the topo
      roundtrip itself lives in test_checkpoint).
  A4  downpour alias: center frozen for the legacy warmup window, stale
      displacements applied at full weight (decay 1.0) afterwards.
  A5  elastic membership composes: an absent learner cannot fire — drop
      is just unbounded lag on the same clock axis.
  A6  config validation: profiles that cannot honor the staleness bound,
      non-dense comm, and length mismatches are rejected eagerly.
  A7  work_completed matches the on-device fired_count accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    AsyncConfig,
    CommConfig,
    ElasticConfig,
    MAvgConfig,
    TopologyConfig,
)
from repro.core.meta import init_state, make_meta_step
from repro.models.simple import mlp_init, mlp_loss
from repro.topology import make_topology, step_time_profile

D, C, H = 8, 4, 16
PARAMS = mlp_init(jax.random.PRNGKey(0), D, H, C)


def _batches(seed, L, K, B=4):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {"x": jax.random.normal(kx, (L, K, B, D)),
            "y": jax.random.randint(ky, (L, K, B), 0, C)}


def _run(cfg, n_steps=4, params=PARAMS):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    metrics = []
    for i in range(n_steps):
        state, m = step(state, _batches(i, cfg.num_learners, cfg.k_steps))
        metrics.append(m)
    return state, metrics


def _bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# A1: tau=0 degenerate case == synchronous flat, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("packed", [True, False])
def test_a1_uniform_async_is_flat_bitwise(packed):
    base = dict(algorithm="mavg", num_learners=4, k_steps=3,
                learner_lr=0.1, momentum=0.6, packed=packed)
    s_flat, m_flat = _run(MAvgConfig(**base))
    s_async, m_async = _run(MAvgConfig(
        **base, topology=TopologyConfig(kind="async", server=AsyncConfig())))
    _bitwise(s_flat.global_params, s_async.global_params)
    _bitwise(s_flat.momentum, s_async.momentum)
    _bitwise(s_flat.learners, s_async.learners)
    np.testing.assert_array_equal(
        np.asarray(m_flat[-1]["loss"]), np.asarray(m_async[-1]["loss"]))
    # the degenerate case still reports the async bookkeeping
    assert float(m_async[-1]["staleness_max"]) == 0.0
    assert float(m_async[-1]["fired_count"]) == 4.0


def test_a1_eamsgd_alias_matches_legacy_update():
    """eamsgd routed through the async server (uniform profile, elastic
    update) applies the closed-form EASGD step: v' = mu v + alpha
    sum_j (w_j - w~); w~' = w~ + v'; learners relax by alpha toward w~'."""
    cfg = MAvgConfig(algorithm="eamsgd", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.5, elastic_alpha=0.1)
    state = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    prev = state
    state, _ = step(state, _batches(0, 2, 2))
    # reconstruct from the previous state's learners after one local phase
    # is circular; instead pin the update identity on the second step
    # using the recorded state: w~' - w~ == v'
    prev = state
    state, _ = step(state, _batches(1, 2, 2))
    dw = jax.tree.map(lambda a, b: np.asarray(a) - np.asarray(b),
                      state.global_params, prev.global_params)
    for d, v in zip(jax.tree.leaves(dw), jax.tree.leaves(state.momentum)):
        np.testing.assert_allclose(d, np.asarray(v), rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# A2: bounded staleness, including the startup window
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("profile,tau", [((1, 1, 2, 4), 3),
                                         ((1, 3, 3, 5), 4)])
def test_a2_applied_staleness_bounded(profile, tau):
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     momentum=0.5,
                     topology=TopologyConfig(kind="async", server=AsyncConfig(
                         staleness=tau, step_time=profile)))
    _, metrics = _run(cfg, n_steps=3 * max(profile) + 2)
    worst = max(float(m["staleness_max"]) for m in metrics)
    assert worst <= tau, (worst, tau)
    # the skewed profile does produce *some* staleness
    assert any(float(m["staleness_max"]) > 0 for m in metrics)


# ---------------------------------------------------------------------------
# A3: deterministic trajectory across a halt/resume boundary
# ---------------------------------------------------------------------------


def test_a3_resume_mid_window_identical_trajectory():
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     momentum=0.5,
                     topology=TopologyConfig(kind="async", server=AsyncConfig(
                         staleness=3, step_time=(1, 2, 3, 4))))
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    live = init_state(PARAMS, cfg)
    for i in range(7):
        live, _ = step(live, _batches(i, 4, 2))
    # replay from scratch with an identical schedule: same trajectory —
    # the clocks are state, not host-side mutable context
    replay = init_state(PARAMS, cfg)
    for i in range(7):
        replay, _ = step(replay, _batches(i, 4, 2))
    _bitwise(live, replay)


# ---------------------------------------------------------------------------
# A4: downpour alias regression (legacy warmup + stale application)
# ---------------------------------------------------------------------------


def test_a4_downpour_alias_warmup_and_stale_norm():
    cfg = MAvgConfig(algorithm="downpour", num_learners=2, k_steps=2,
                     learner_lr=0.1, staleness=3)
    spec_params = init_state(PARAMS, cfg).global_params
    state = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    moved = []
    for i in range(6):
        state, m = step(state, _batches(i, 2, 2))
        delta = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(state.global_params),
            jax.tree.leaves(spec_params)))
        moved.append(delta > 1e-7)
        assert "stale_norm" in m  # legacy metric name flows on
    # frozen through the warmup window, moving afterwards
    assert not any(moved[:3]) and all(moved[3:])


# ---------------------------------------------------------------------------
# A5: elastic membership composes (drop = lag on the same axis)
# ---------------------------------------------------------------------------


def test_a5_absent_learner_never_fires():
    cfg = MAvgConfig(
        algorithm="mavg", num_learners=4, k_steps=2, momentum=0.5,
        topology=TopologyConfig(
            kind="async",
            server=AsyncConfig(staleness=2, step_time=(1, 1, 2, 2)),
            elastic=ElasticConfig(period=3, drop_frac=0.25, seed=1)))
    topo = make_topology(cfg)
    state = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    sched = np.asarray(state.topo["membership"])
    for i in range(9):
        fire = np.asarray(topo.fire_mask(state.topo, jnp.int32(i)))
        absent = sched[i % 3] == 0
        assert not (fire & absent).any()
        prev = state
        state, _ = step(state, _batches(i, 4, 2))
        # absent learners are fully frozen this tick
        for a, b in zip(jax.tree.leaves(prev.learners),
                        jax.tree.leaves(state.learners)):
            np.testing.assert_array_equal(
                np.asarray(a)[absent], np.asarray(b)[absent])


# ---------------------------------------------------------------------------
# A6: eager config validation
# ---------------------------------------------------------------------------


def test_a6_validation():
    # a 5-tick straggler cannot honor a tau=2 bound
    with pytest.raises(ValueError, match="staleness"):
        AsyncConfig(staleness=2, step_time=(1, 1, 5))
    # the async server ships dense displacement planes
    with pytest.raises(ValueError, match="dense"):
        MAvgConfig(num_learners=2, k_steps=2,
                   comm=CommConfig(scheme="int8"),
                   topology=TopologyConfig(kind="async"))
    # profile length must match the learner count
    with pytest.raises(ValueError, match="step_time"):
        MAvgConfig(num_learners=4, k_steps=2,
                   topology=TopologyConfig(kind="async", server=AsyncConfig(
                       staleness=1, step_time=(1, 2))))
    # seeded skew profile: deterministic, spans 1..skew
    prof = step_time_profile(8, AsyncConfig(staleness=3, skew=4))
    np.testing.assert_array_equal(
        prof, step_time_profile(8, AsyncConfig(staleness=3, skew=4)))
    assert prof.min() == 1 and prof.max() == 4


# ---------------------------------------------------------------------------
# A7: host-side work accounting matches the device fire counts
# ---------------------------------------------------------------------------


def test_a7_work_completed_matches_fired_counts():
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     momentum=0.5,
                     topology=TopologyConfig(kind="async", server=AsyncConfig(
                         staleness=3, step_time=(1, 1, 2, 4))))
    topo = make_topology(cfg)
    state = init_state(PARAMS, cfg, topology=topo)
    step = jax.jit(make_meta_step(mlp_loss, cfg, topology=topo))
    fired = 0.0
    for i in range(10):
        state, m = step(state, _batches(i, 4, 2))
        fired += float(m["fired_count"])
        assert topo.work_completed(i) == int(fired)
    # a synchronous topology completes L blocks per tick
    flat = make_topology(MAvgConfig(num_learners=4, k_steps=2))
    assert flat.work_completed(9) == 40
