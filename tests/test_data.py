"""Data-pipeline tests: determinism, learner-disjointness, learnability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import (
    bigram_table,
    classif_batch_fn,
    classif_eval_set,
    lm_batch_fn,
    sample_lm,
)


def test_bigram_table_stochastic():
    t = bigram_table(3, 64)
    np.testing.assert_allclose(np.asarray(t.sum(-1)), 1.0, rtol=1e-5)


def test_lm_batches_deterministic():
    cfg = get_config("qwen3-1.7b").reduced()
    bf = lm_batch_fn(cfg, 2, 2, 4, 16)
    rng = jax.random.PRNGKey(0)
    a = bf(rng, 0)
    b = bf(rng, 0)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_lm_learners_disjoint():
    cfg = get_config("qwen3-1.7b").reduced()
    bf = lm_batch_fn(cfg, 2, 1, 8, 32)
    b = bf(jax.random.PRNGKey(0), 0)
    assert not np.array_equal(
        np.asarray(b["tokens"][0]), np.asarray(b["tokens"][1])
    )


def test_bigram_is_learnable():
    """An oracle using the true bigram table beats uniform by a wide margin
    — i.e. the stream carries learnable signal for convergence benches."""
    v = 64
    table = bigram_table(5, v)
    toks = sample_lm(jax.random.PRNGKey(1), table, 16, 64)
    nxt_prob = np.asarray(table)[np.asarray(toks[:, :-1])]
    ll = np.log(nxt_prob[np.arange(16)[:, None],
                         np.arange(63)[None, :],
                         np.asarray(toks[:, 1:])] + 1e-9).mean()
    uniform = np.log(1.0 / v)
    assert ll > uniform + 1.0


def test_classif_eval_fixed():
    e1 = classif_eval_set(8, 4)
    e2 = classif_eval_set(8, 4)
    np.testing.assert_array_equal(np.asarray(e1["x"]), np.asarray(e2["x"]))
    # all classes present
    assert len(np.unique(np.asarray(e1["y"]))) == 4


def test_classif_batch_shapes():
    bf = classif_batch_fn(8, 4, 3, 2, 5)
    b = bf(jax.random.PRNGKey(0), 0)
    assert b["x"].shape == (3, 2, 5, 8)
    assert b["y"].shape == (3, 2, 5)
