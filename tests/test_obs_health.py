"""Run-health watchdog acceptance tests (repro.obs.health, DESIGN.md §11).

Invariants:
  HLT1  rule semantics: nonfinite / max / min fire exactly on their
        condition; rel_max / rel_min compare against the strictly
        TRAILING window median (the current value never contaminates its
        own reference), stay silent below min_history, and non-finite
        values are never pushed into the history.
  HLT2  alert records are schema-valid structured events carrying the
        rule identity, value, severity and halt decision.
  HLT3  an injected NaN-loss run raises HealthHalt at the next flush
        boundary with a RESUMABLE checkpoint written first, fatal alert
        in the run log, and the log still validates against
        tools/telemetry_schema.json.
  HLT4  an all-healthy run is bitwise unaffected by enabling the
        watchdogs (observation happens strictly after the one bulk
        transfer that was happening anyway).
  HLT5  ObsConfig.health_halt=False demotes fatal rules to warn: the
        sick run completes, alerts are still recorded.
"""
import importlib.util
import json
import math
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MAvgConfig, ObsConfig, TrainConfig
from repro.core.trainer import Trainer
from repro.models.simple import mlp_init, mlp_loss
from repro.obs import (
    DEFAULT_RULES,
    HealthHalt,
    HealthMonitor,
    HealthRule,
    make_monitor,
)

D, C, H = 8, 4, 16
L, K, B = 4, 2, 4

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TIME_KEYS = ("meta_steps_per_sec", "samples_per_sec", "elapsed_s")


# ---------------------------------------------------------------------------
# HLT1: rule semantics
# ---------------------------------------------------------------------------


def _recs(metric, values, start=0):
    return [{"meta_step": start + i, metric: v} for i, v in enumerate(values)]


def test_hlt1_rule_validation():
    with pytest.raises(AssertionError):
        HealthRule("x", "loss", "bogus_kind")
    with pytest.raises(AssertionError):
        HealthRule("x", "loss", "max", severity="panic")


def test_hlt1_nonfinite_fires_on_nan_and_inf_only():
    mon = HealthMonitor([HealthRule("nf", "loss", "nonfinite",
                                    severity="fatal")])
    assert mon.observe(_recs("loss", [1.0, 0.5])) == []
    fired = mon.observe(_recs("loss", [float("nan")], start=2))
    assert len(fired) == 1 and fired[0]["rule"] == "nf"
    fired = mon.observe(_recs("loss", [float("inf")], start=3))
    assert len(fired) == 1
    assert mon.halt_requested
    assert mon.halt_alert["meta_step"] == 2  # the FIRST fatal alert


def test_hlt1_absolute_bounds():
    mon = HealthMonitor([
        HealthRule("too_big", "consensus_dist", "max", threshold=5.0),
        HealthRule("too_small", "mixing_spectral_gap", "min", threshold=1e-4),
    ])
    assert mon.observe([{"meta_step": 0, "consensus_dist": 5.0,
                         "mixing_spectral_gap": 1e-4}]) == []
    fired = mon.observe([{"meta_step": 1, "consensus_dist": 5.1,
                          "mixing_spectral_gap": 1e-5}])
    assert sorted(a["rule"] for a in fired) == ["too_big", "too_small"]
    assert not mon.halt_requested  # warn severity


def test_hlt1_rel_max_trailing_median():
    mon = HealthMonitor([HealthRule("div", "loss", "rel_max", threshold=10.0,
                                    window=8, min_history=4)])
    # below min_history: silent even on a huge jump
    assert mon.observe(_recs("loss", [1.0, 1.0, 1.0, 500.0])) == []
    # the 500 DID enter the history; median of [1,1,1,500] = 1.0 -> a
    # value of 11 (> 10x median) fires, 9.9 does not
    assert mon.observe(_recs("loss", [9.9], start=4)) == []
    fired = mon.observe(_recs("loss", [11.0], start=5))
    assert len(fired) == 1
    assert fired[0]["reference"] == pytest.approx(1.0)


def test_hlt1_rel_min_and_skipped_metric():
    mon = HealthMonitor([HealthRule("slow", "meta_steps_per_sec", "rel_min",
                                    threshold=0.1, min_history=4)])
    mon.observe(_recs("meta_steps_per_sec", [10.0, 10.0, 10.0, 10.0]))
    # records missing the metric are skipped entirely
    assert mon.observe([{"meta_step": 4, "loss": 1.0}]) == []
    assert mon.observe(_recs("meta_steps_per_sec", [2.0], start=5)) == []
    fired = mon.observe(_recs("meta_steps_per_sec", [0.9], start=6))
    assert len(fired) == 1 and fired[0]["rule"] == "slow"


def test_hlt1_nonfinite_never_enters_history():
    mon = HealthMonitor([
        HealthRule("nf", "loss", "nonfinite"),
        HealthRule("div", "loss", "rel_max", threshold=10.0, min_history=4),
    ])
    mon.observe(_recs("loss", [1.0, 1.0, float("nan"), 1.0, 1.0]))
    # history is [1,1,1,1] (NaN skipped): median 1.0, so 11 fires with
    # reference 1.0 — a poisoned median would have been NaN
    fired = mon.observe(_recs("loss", [11.0], start=5))
    assert [a["rule"] for a in fired] == ["div"]
    assert fired[0]["reference"] == pytest.approx(1.0)


def test_hlt2_alert_record_shape():
    mon = HealthMonitor([HealthRule("nf", "loss", "nonfinite",
                                    severity="fatal")])
    (alert,) = mon.observe(_recs("loss", [math.inf]))
    for key in ("kind", "rule", "metric", "value", "severity", "halt",
                "meta_step", "rule_kind", "threshold", "window"):
        assert key in alert, key
    assert alert["kind"] == "alert"
    assert alert["severity"] == "fatal" and alert["halt"] is True
    json.dumps(alert)  # JSONL-serializable


def test_hlt1_make_monitor_demotes_fatal():
    mon = make_monitor(halt=False)
    assert all(r.severity == "warn" for r in mon.rules)
    assert {r.name for r in mon.rules} == {r.name for r in DEFAULT_RULES}
    mon.observe(_recs("loss", [float("nan")]))
    assert mon.alerts and not mon.halt_requested


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------


def _check_telemetry():
    spec = importlib.util.spec_from_file_location(
        "check_telemetry", os.path.join(_ROOT, "tools", "check_telemetry.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _batch_fn(nan_after=None):
    def fn(rng, step):
        kx, ky = jax.random.split(rng)
        x = jax.random.normal(kx, (L, K, B, D))
        if nan_after is not None and step >= nan_after:
            x = x * jnp.float32(float("nan"))
        return {"x": x, "y": jax.random.randint(ky, (L, K, B), 0, C)}
    return fn


def _trainer(tmp_path, *, nan_after=None, run_dir=None, sink="jsonl",
             **obs_kw):
    mcfg = MAvgConfig(algorithm="mavg", num_learners=L, k_steps=K,
                      learner_lr=0.1, momentum=0.6)
    if run_dir is None and sink in ("jsonl", "csv"):
        run_dir = str(tmp_path / "run")
    cfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=B, meta_steps=8,
        log_every=2, obs=ObsConfig(sink=sink, run_dir=run_dir, **obs_kw),
    )
    return Trainer(cfg, mlp_loss,
                   init_params_fn=lambda rng: mlp_init(rng, D, H, C),
                   batch_fn=_batch_fn(nan_after))


@pytest.mark.slow
def test_hlt3_nan_loss_halts_with_resumable_checkpoint(tmp_path):
    run_dir = str(tmp_path / "run")
    tr = _trainer(tmp_path, nan_after=2, run_dir=run_dir, health=True)
    with pytest.raises(HealthHalt) as ei:
        tr.run(8, log=lambda *_: None)
    tr.close()
    halt = ei.value
    assert halt.alert["rule"] == "nonfinite_loss"
    assert halt.alert["severity"] == "fatal"
    # checkpoint written before the raise, resumable
    assert halt.checkpoint_path and os.path.exists(halt.checkpoint_path)
    assert os.path.dirname(halt.checkpoint_path).endswith("halt_ckpt")
    tr2 = _trainer(tmp_path, run_dir=str(tmp_path / "run2"))
    tr2.restore(halt.checkpoint_path)
    assert int(tr2.state.step) >= 2
    # the fatal alert landed in the run log next to its step records,
    # and the stream still validates against the telemetry schema
    path = os.path.join(run_dir, "run.jsonl")
    recs = [json.loads(l) for l in open(path)]
    alerts = [r for r in recs if r["kind"] == "alert"]
    assert any(a["rule"] == "nonfinite_loss" and a["halt"] for a in alerts)
    ct = _check_telemetry()
    schema = ct.load_schema(os.path.join(_ROOT, "tools",
                                         "telemetry_schema.json"))
    assert ct.check_file(path, schema) == []


@pytest.mark.slow
def test_hlt4_healthy_run_bitwise_unaffected_by_watchdogs(tmp_path):
    hists = {}
    for health in (False, True):
        tr = _trainer(tmp_path / str(health), sink="memory", health=health)
        hists[health] = tr.run(8, log=None)
        if health:
            assert tr._monitor is not None and tr._monitor.alerts == []

    def strip(recs):
        return [{k: v for k, v in r.items() if k not in TIME_KEYS}
                for r in recs]

    assert strip(hists[False]) == strip(hists[True])


@pytest.mark.slow
def test_hlt5_health_halt_off_records_but_never_stops(tmp_path):
    tr = _trainer(tmp_path, nan_after=2, sink="memory", health=True,
                  health_halt=False)
    hist = tr.run(8, log=None)  # completes — no HealthHalt
    assert len(hist) == 8
    assert tr._monitor.alerts and not tr._monitor.halt_requested
    assert all(a["severity"] == "warn" for a in tr._monitor.alerts)
    nf = [a for a in tr._monitor.alerts if a["rule"] == "nonfinite_loss"]
    assert nf and nf[0]["halt"] is False
