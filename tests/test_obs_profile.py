"""Profiler-attribution acceptance tests (repro.obs.profile, DESIGN.md §11).

Invariants:
  PRF1  steady_timeit: warmup calls are untimed, every timed call blocks
        on its outputs, the reported statistic is a median with IQR over
        exactly ``iters`` repeats.
  PRF2  attribution_row joins a Timing against a modeled cost with the
        documented arithmetic: achieved_gbps = modeled bytes / median
        second, pct_of_bound = 100 * achieved / peak.
  PRF3  profile_fn produces the full row from one jittable callable
        (AOT-modeled bytes > 0, measured median > 0).
  PRF4  profile_phases covers phase:step, phase:local and (for averaging
        algorithms) phase:meta_mix — through functional, non-donated
        step instances, leaving the passed state intact.
  PRF5  measured_peak_gbps is measured once per size and cached.
"""
import types

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MAvgConfig
from repro.core.meta import init_state
from repro.models.simple import mlp_init, mlp_loss
from repro.obs import measured_peak_gbps, profile_fn, profile_phases
from repro.obs.profile import Timing, _quantile, attribution_row, steady_timeit


# ---------------------------------------------------------------------------
# PRF1: the timing harness
# ---------------------------------------------------------------------------


def test_prf1_quantile_interpolation():
    assert _quantile([5.0], 0.5) == 5.0
    assert _quantile([1.0, 2.0, 3.0], 0.5) == 2.0
    assert _quantile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.5
    assert _quantile([0.0, 10.0], 0.25) == 2.5


def test_prf1_steady_timeit_counts_calls():
    calls = []

    def fn(x):
        calls.append(1)
        return x + 1.0

    t = steady_timeit(fn, jnp.float32(1.0), iters=7, warmup=3)
    assert len(calls) == 10  # warmup + iters, nothing more
    assert t.n == 7 and t.warmup == 3 and len(t.times_s) == 7
    assert t.median_s > 0 and t.iqr_s >= 0
    assert t.median_us == pytest.approx(t.median_s * 1e6)
    # the median of the actual samples, not of something else
    assert min(t.times_s) <= t.median_s <= max(t.times_s)


def test_prf1_validates_arguments():
    with pytest.raises(AssertionError):
        steady_timeit(lambda: 0, iters=0)


# ---------------------------------------------------------------------------
# PRF2: the attribution join
# ---------------------------------------------------------------------------


def test_prf2_attribution_arithmetic():
    timing = Timing(median_s=2e-3, iqr_s=1e-4, n=5, warmup=2,
                    times_s=(2e-3,) * 5)
    cost = types.SimpleNamespace(hbm_bytes=40_000_000, flops=1_000_000)
    row = attribution_row("op_x", timing, cost, peak_gbps=100.0,
                          extra={"rows": 7})
    assert row["kind"] == "attribution" and row["op"] == "op_x"
    assert row["median_us"] == pytest.approx(2000.0)
    assert row["modeled_hbm_bytes"] == 40_000_000.0
    # 40 MB in 2 ms = 20 GB/s; 20 of 100 peak = 20%
    assert row["achieved_gbps"] == pytest.approx(20.0)
    assert row["pct_of_bound"] == pytest.approx(20.0)
    assert row["rows"] == 7
    assert row["backend"] == jax.default_backend()


def test_prf2_no_cost_no_bandwidth_fields():
    timing = Timing(median_s=1e-3, iqr_s=0.0, n=1, warmup=0, times_s=(1e-3,))
    row = attribution_row("op_y", timing)
    assert "achieved_gbps" not in row and "pct_of_bound" not in row
    assert row["median_us"] == pytest.approx(1000.0)


# ---------------------------------------------------------------------------
# PRF3/PRF5: profile_fn and the measured peak
# ---------------------------------------------------------------------------


def test_prf3_profile_fn_end_to_end():
    x = jnp.ones((4096,), jnp.float32)
    row = profile_fn("saxpy", lambda x: x * 2.0 + 1.0, x,
                     iters=3, warmup=1, peak_gbps=10.0)
    assert row["op"] == "saxpy" and row["iters"] == 3
    assert row["median_us"] > 0
    # the compiled program moves at least the input + output bytes
    assert row["modeled_hbm_bytes"] >= 2 * x.nbytes
    assert row["achieved_gbps"] > 0 and row["pct_of_bound"] > 0


def test_prf5_peak_is_cached_per_size():
    a = measured_peak_gbps(1 << 16, iters=2, warmup=1)
    b = measured_peak_gbps(1 << 16, iters=2, warmup=1)
    assert a == b and a > 0


# ---------------------------------------------------------------------------
# PRF4: training-phase attribution
# ---------------------------------------------------------------------------

D, C, H = 8, 4, 16
L, K, B = 4, 2, 4


def _batches(seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {"x": jax.random.normal(kx, (L, K, B, D)),
            "y": jax.random.randint(ky, (L, K, B), 0, C)}


@pytest.mark.slow
def test_prf4_profile_phases_covers_step_local_mix():
    cfg = MAvgConfig(algorithm="mavg", num_learners=L, k_steps=K,
                     learner_lr=0.1, momentum=0.6)
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    state = init_state(params, cfg)
    before = jax.tree_util.tree_map(lambda x: x.copy(), state.learners)
    rows = profile_phases(mlp_loss, cfg, state, _batches(), iters=2,
                          warmup=1, peak_gbps=10.0)
    assert [r["op"] for r in rows] == [
        "phase:step", "phase:local", "phase:meta_mix"]
    for r in rows:
        assert r["kind"] == "attribution"
        assert r["median_us"] > 0 and r["achieved_gbps"] > 0
        assert r["algorithm"] == "mavg" and r["topology"] == "flat"
    # functional profiling: the passed state was never donated/mutated
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(state.learners)):
        assert (a == b).all()


@pytest.mark.slow
def test_prf4_aliased_algorithm_attributes_meta_mix():
    """downpour is an alias onto the async server (one Topology protocol
    for every algorithm), so its meta phase is attributable too."""
    cfg = MAvgConfig(algorithm="downpour", num_learners=L, k_steps=K,
                     learner_lr=0.1, momentum=0.6)
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    state = init_state(params, cfg)
    rows = profile_phases(mlp_loss, cfg, state, _batches(), iters=2,
                          warmup=1)
    assert [r["op"] for r in rows] == [
        "phase:step", "phase:local", "phase:meta_mix"]
