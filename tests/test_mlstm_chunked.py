"""Chunkwise-parallel mLSTM == step-recurrent mLSTM (perf iteration for
xlstm-350m, EXPERIMENTS.md section Perf). Exactness matters: the chunked
form is used for training, the recurrent form for decode, and they must
agree or train/serve diverge."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import xlstm

CFG = dataclasses.replace(get_config("xlstm-350m").reduced(), dtype="float32")
RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def block():
    return xlstm._init_mlstm_block(RNG, CFG)


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("seq", [64, 128])
def test_chunked_matches_recurrent(block, chunk, seq):
    if seq % chunk:
        pytest.skip("chunk must divide seq")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, CFG.d_model)) * 0.5
    out_r, st_r = xlstm.mlstm_seq(block, CFG, x)
    out_c, st_c = xlstm.mlstm_chunked(block, CFG, x, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=3e-4, atol=3e-4)
    for a, b, nm in zip(st_c[:3], st_r[:3], ("C", "n", "m")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-3, err_msg=nm)


def test_chunked_continuation(block):
    """State handoff across calls (train-time TBPTT / decode warm start)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, CFG.d_model)) * 0.5
    _, st = xlstm.mlstm_seq(block, CFG, x)
    out_r, _ = xlstm.mlstm_seq(block, CFG, x, st)
    out_c, _ = xlstm.mlstm_chunked(block, CFG, x, st, chunk=16)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=3e-4, atol=3e-4)


def test_full_model_with_chunking(block):
    """End-to-end forward equality with the module-level switch."""
    params = xlstm.init(RNG, CFG)
    toks = jax.random.randint(RNG, (2, 32), 0, CFG.vocab_size, jnp.int32)
    logits_rec, _ = xlstm.forward(params, CFG, {"tokens": toks})
    xlstm.set_mlstm_chunk(8)
    try:
        logits_chk, _ = xlstm.forward(params, CFG, {"tokens": toks})
    finally:
        xlstm.set_mlstm_chunk(0)
    np.testing.assert_allclose(np.asarray(logits_chk), np.asarray(logits_rec),
                               rtol=3e-3, atol=3e-3)
