"""End-to-end behaviour tests for the full system.

* M-AVG trains a real (reduced) transformer on learnable bigram data and
  the loss drops; M-AVG reaches a lower loss than K-AVG at equal samples
  (the paper's headline claim, Figures 1-6).
* The jitted meta-step runs unchanged under a real multi-device mesh with
  the learner axis sharded (subprocess with 8 host devices) and produces
  the same losses as the single-device run — the SPMD-correctness
  integration test backing the dry-run.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # end-to-end training + subprocess mesh, ~90s

from repro.configs import get_config
from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.data import lm_batch_fn
from repro.models import api as model_api

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(algo, mu, steps=20, seed=0):
    cfg = get_config("qwen3-1.7b").reduced()
    mcfg = MAvgConfig(algorithm=algo, num_learners=4, k_steps=2,
                      learner_lr=0.5, momentum=mu)
    params = model_api.init_params(jax.random.PRNGKey(seed), cfg)
    state = init_state(params, mcfg)
    step = jax.jit(make_meta_step(
        lambda p, b: model_api.loss_fn(p, cfg, b), mcfg))
    bf = lm_batch_fn(cfg, 4, 2, 8, 32)
    losses = []
    for i in range(steps):
        b = bf(jax.random.fold_in(jax.random.PRNGKey(123), i), i)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return losses


def test_mavg_trains_transformer():
    losses = _train("mavg", 0.6)
    assert losses[-1] < losses[0] - 0.5, losses


def test_mavg_beats_kavg_same_samples():
    """The paper's core claim at system level (same data, same steps)."""
    m = _train("mavg", 0.6, steps=25)
    k = _train("kavg", 0.0, steps=25)
    # compare average of last 5 losses (noise tolerance)
    m_tail = sum(m[-5:]) / 5
    k_tail = sum(k[-5:]) / 5
    assert m_tail < k_tail, (m_tail, k_tail)


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.data import lm_batch_fn
from repro.models import api as model_api
from repro.launch import specs as S

use_mesh = sys.argv[1] == "mesh"
cfg = get_config("qwen3-1.7b").reduced()
mcfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                  learner_lr=0.5, momentum=0.6)
params = model_api.init_params(jax.random.PRNGKey(0), cfg)
state = init_state(params, mcfg)
loss_fn = lambda p, b: model_api.loss_fn(p, cfg, b)
step_fn = make_meta_step(loss_fn, mcfg)
if use_mesh:
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh:
        sh = S.state_shardings(cfg, mcfg, mesh)
        bsh = {k: NamedSharding(mesh, P("data")) for k in ("tokens", "labels")}
        step = jax.jit(step_fn, in_shardings=(sh, bsh), out_shardings=(sh, None))
        bf = lm_batch_fn(cfg, 4, 2, 8, 32)
        losses = []
        for i in range(4):
            b = bf(jax.random.fold_in(jax.random.PRNGKey(123), i), i)
            state, m = step(state, b)
            losses.append(float(m["loss"]))
else:
    step = jax.jit(step_fn)
    bf = lm_batch_fn(cfg, 4, 2, 8, 32)
    losses = []
    for i in range(4):
        b = bf(jax.random.fold_in(jax.random.PRNGKey(123), i), i)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
print(json.dumps(losses))
"""


def test_meta_step_under_real_mesh(tmp_path):
    """Same program, 8 sharded host devices vs 1: losses must agree."""
    script = tmp_path / "mesh_run.py"
    script.write_text(_MESH_SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))

    def run(mode):
        out = subprocess.run(
            [sys.executable, str(script), mode], env=env, capture_output=True,
            text=True, timeout=1200,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    losses_mesh = run("mesh")
    losses_single = run("single")
    for a, b in zip(losses_mesh, losses_single):
        assert abs(a - b) < 5e-2, (losses_mesh, losses_single)
