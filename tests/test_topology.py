"""repro.topology acceptance tests: equivalence invariants between
topologies and the flat algorithms (the style of test_meta_properties),
mixing-matrix algebra, the Pallas neighbor-mix kernel vs its jnp oracle,
per-edge-class wire modeling, and checkpoint round-trips of the extended
MetaState.

Invariants:
  T1  Hierarchical(groups=1, outer_every=1, mu_out=0) == flat mavg exactly.
  T2  Gossip(complete graph) == kavg's all-reduce average.
  T3  every mixing matrix is doubly stochastic; gossip mixing preserves
      the learner mean exactly (to float tolerance).
  T4  neighbor-mix Pallas kernel (interpret) == jnp oracle.
  T5  hierarchical outer level fires only every H meta steps.
  T6  extended MetaState (topo buffers) checkpoint round-trips and a
      resumed run stays bit-identical.
  T7  modeled inter-node bytes: hierarchical with int8_topk cross-group
      <= 1/4 of flat dense at equal meta-iterations.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_state, save_state
from repro.configs.base import (
    GOSSIP_GRAPHS,
    CommConfig,
    MAvgConfig,
    TopologyConfig,
)
from repro.core.meta import init_state, make_meta_step
from repro.kernels import ops, ref
from repro.models.simple import mlp_init, mlp_loss
from repro.topology import graph_degree, mixing_matrix
from repro.utils import tree_mean_axis0, tree_norm, tree_sub

D, C, H = 8, 4, 16
PARAMS = mlp_init(jax.random.PRNGKey(0), D, H, C)
RNG = np.random.RandomState(11)


def _batches(seed, L, K, B=4):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (L, K, B, D))
    y = jax.random.randint(ky, (L, K, B), 0, C)
    return {"x": x, "y": y}


def _run(cfg, n_steps=3, params=PARAMS):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(n_steps):
        state, metrics = step(state, _batches(i, cfg.num_learners, cfg.k_steps))
    return state, metrics


def _close(a, b, tol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=tol,
                                   atol=tol)


# ---------------------------------------------------------------------------
# T1 / T2: equivalence invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mu", [0.0, 0.6])
@pytest.mark.parametrize("eta", [1.0, 1.3])
def test_t1_hierarchical_g1_is_flat_mavg(mu, eta):
    base = dict(algorithm="mavg", num_learners=4, k_steps=2,
                learner_lr=0.1, momentum=mu, meta_lr=eta)
    s_flat, _ = _run(MAvgConfig(**base))
    s_h, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        kind="hierarchical", groups=1, outer_every=1, outer_momentum=0.0)))
    _close(s_flat.global_params, s_h.global_params, tol=1e-6)
    _close(s_flat.learners, s_h.learners, tol=1e-6)


def test_t2_gossip_complete_is_kavg():
    base = dict(algorithm="kavg", num_learners=4, k_steps=2, learner_lr=0.1)
    s_kavg, _ = _run(MAvgConfig(**base))
    s_g, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        kind="gossip", graph="complete")))
    _close(s_kavg.global_params, s_g.global_params)
    # every learner's private params coincide with the global average
    _close(s_g.topo["params"],
           jax.tree.map(lambda g, x: jnp.broadcast_to(g[None], x.shape),
                        s_g.global_params, s_g.topo["params"]))


def test_gossip_complete_mu_matches_flat_mavg():
    """With the complete graph the gossip recursion collapses to flat
    M-AVG for any mu (all learners share one consensus trajectory)."""
    base = dict(algorithm="mavg", num_learners=4, k_steps=2,
                learner_lr=0.1, momentum=0.6)
    s_flat, _ = _run(MAvgConfig(**base))
    s_g, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        kind="gossip", graph="complete")))
    _close(s_flat.global_params, s_g.global_params)


# ---------------------------------------------------------------------------
# T3: mixing-matrix algebra
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("graph", GOSSIP_GRAPHS)
@pytest.mark.parametrize("L", [1, 2, 3, 4, 7, 8, 16])
def test_t3_doubly_stochastic(graph, L):
    W = mixing_matrix(graph, L)
    np.testing.assert_allclose(W.sum(axis=0), 1.0, rtol=1e-6)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, rtol=1e-6)
    assert (W >= 0).all()
    np.testing.assert_allclose(W, W.T, rtol=1e-6)  # symmetric circulant
    assert graph_degree(graph, L) == int((W[0] > 0).sum()) - 1  # minus self


@pytest.mark.parametrize("graph", GOSSIP_GRAPHS)
def test_t3_mixing_preserves_learner_mean(graph):
    L = 8
    W = jnp.asarray(mixing_matrix(graph, L))
    x = {"a": jnp.asarray(RNG.randn(L, 5, 7), jnp.float32),
         "b": jnp.asarray(RNG.randn(L, 33), jnp.float32)}
    mixed = ops.neighbor_mix_tree(x, W, use_pallas=False)
    _close(tree_mean_axis0(mixed), tree_mean_axis0(x), tol=1e-5)
    # and through a whole gossip meta step: global_params == learner mean
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     momentum=0.5,
                     topology=TopologyConfig(kind="gossip", graph=graph))
    s, _ = _run(cfg)
    _close(s.global_params, tree_mean_axis0(s.topo["params"]), tol=1e-6)


# ---------------------------------------------------------------------------
# T4: Pallas kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L,rows", [(2, 8), (4, 64), (8, 256), (3, 16)])
def test_t4_neighbor_mix_kernel_matches_ref(L, rows):
    from repro.kernels import neighbor_mix as nm

    x = jnp.asarray(RNG.randn(L, rows, 128), jnp.float32)
    W = jnp.asarray(mixing_matrix("ring", L))
    out_k = nm.neighbor_mix_3d(x, W, interpret=True)
    out_r = ref.neighbor_mix_ref(x, W)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(1000,), (33, 7), (3,)])
def test_t4_neighbor_mix_any_shape(shape):
    L = 4
    x = jnp.asarray(RNG.randn(L, *shape), jnp.float32)
    W = jnp.asarray(mixing_matrix("exponential", L))
    out = ops.neighbor_mix(x, W, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.neighbor_mix_ref(x, W)),
                               rtol=1e-5, atol=1e-5)


def test_t4_pallas_gossip_step_matches_jnp():
    base = dict(algorithm="mavg", num_learners=4, k_steps=2, momentum=0.6,
                topology=TopologyConfig(kind="gossip", graph="ring",
                                        momentum_tracking=True))
    s_jnp, _ = _run(MAvgConfig(**base, use_pallas=False))
    s_pl, _ = _run(MAvgConfig(**base, use_pallas=True))
    _close(s_jnp.global_params, s_pl.global_params, tol=1e-4)
    _close(s_jnp.topo, s_pl.topo, tol=1e-4)


# ---------------------------------------------------------------------------
# T5: outer cadence
# ---------------------------------------------------------------------------


def test_t5_outer_fires_every_h():
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     momentum=0.5,
                     topology=TopologyConfig(kind="hierarchical", groups=2,
                                             outer_every=3))
    state = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(6):
        prev_gp = state.global_params
        state, m = step(state, _batches(i, 4, 2))
        moved = float(tree_norm(tree_sub(state.global_params, prev_gp)))
        if (i + 1) % 3 == 0:
            assert m["outer_fired"] == 1.0 and moved > 1e-7
        else:
            assert m["outer_fired"] == 0.0 and moved == 0.0


# ---------------------------------------------------------------------------
# T6: checkpoint round-trip of the extended state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", [
    TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                   outer_momentum=0.3,
                   outer_comm=CommConfig(scheme="int8_topk",
                                         error_feedback=True)),
    TopologyConfig(kind="gossip", graph="exponential", momentum_tracking=True,
                   inner_comm=CommConfig(scheme="int8", error_feedback=True)),
])
def test_t6_topology_state_roundtrip(tmp_path, topo):
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     momentum=0.6, topology=topo)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(PARAMS, cfg)
    for i in range(3):
        state, _ = step(state, _batches(i, 4, 2))
    assert state.topo is not None
    buf_norm = sum(float(jnp.sum(jnp.abs(x)))
                   for x in jax.tree.leaves(state.topo))
    assert buf_norm > 0  # the buffers actually accumulated something

    path = save_state(str(tmp_path), state, 3)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    live, resumed = state, restored
    for i in range(3, 5):
        live, _ = step(live, _batches(i, 4, 2))
        resumed, _ = step(resumed, _batches(i, 4, 2))
    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# T7: per-edge-class wire model
# ---------------------------------------------------------------------------


def test_t7_hierarchical_inter_bytes_reduction():
    from repro.roofline import meta_wire_bytes, topology_wire_bytes

    n, L = 1_000_000, 8
    flat = topology_wire_bytes(n, CommConfig(), None, num_learners=L)
    hier = topology_wire_bytes(
        n, CommConfig(),
        TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                       outer_comm=CommConfig(scheme="int8_topk",
                                             error_feedback=True)),
        num_learners=L,
    )
    assert flat["intra_bytes"] == 0.0
    assert flat["inter_bytes"] >= 4.0 * hier["inter_bytes"], (flat, hier)
    # flat split agrees with the legacy flat model
    dense, wire = meta_wire_bytes(n, CommConfig(), num_learners=L)
    assert flat["inter_bytes"] == wire == dense

    # gossip: degree-scaled, no amortization
    goss = topology_wire_bytes(
        n, CommConfig(), TopologyConfig(kind="gossip", graph="ring"),
        num_learners=L,
    )
    assert goss["inter_bytes"] == 2 * flat["inter_bytes"]  # ring degree 2


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_topology_config_validation():
    with pytest.raises(AssertionError):
        TopologyConfig(kind="mesh")
    with pytest.raises(AssertionError):
        TopologyConfig(graph="torus")
    with pytest.raises(ValueError):
        MAvgConfig(num_learners=4,
                   topology=TopologyConfig(kind="hierarchical", groups=3))
    with pytest.raises(ValueError):
        MAvgConfig(algorithm="eamsgd",
                   topology=TopologyConfig(kind="gossip"))


def test_momentum_tracking_changes_trajectory():
    base = dict(algorithm="mavg", num_learners=4, k_steps=2, momentum=0.6)
    s_plain, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        kind="gossip", graph="ring")))
    s_mt, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        kind="gossip", graph="ring", momentum_tracking=True)))
    diff = float(tree_norm(tree_sub(s_plain.global_params,
                                    s_mt.global_params)))
    assert diff > 1e-7
    for leaf in jax.tree.leaves(s_mt.global_params):
        assert jnp.isfinite(leaf).all()
