"""Elastic & heterogeneous execution acceptance tests (repro.topology).

Invariants (the style of test_topology / test_meta_properties):
  E1  an all-present membership schedule (drop_frac=0) reproduces the
      static topology *bit-for-bit* — gossip and hierarchical, dense and
      compressed+EF edge classes.
  E2  uniform group_k == (K, ..., K) reproduces scalar K bit-for-bit.
  E3  one-peer exponential mixing: every per-step matrix is doubly
      stochastic, degree 1 at power-of-two L, and the learner mean is
      preserved exactly through whole gossip meta steps.
  E4  membership churn: absent learners are fully frozen (params,
      momentum, EF residual), the masked matrix stays doubly stochastic,
      and the mix preserves the all-learner mean.
  E5  checkpoint resume across the new state: mid-churn round-trip is
      bit-identical, schedule shape mismatches are rejected, and a
      restored Trainer replays the same warmup-phase lr trajectory.
  E6  warmup_cosine is continuous at the warmup boundary (satellite fix).
  E7  hierarchical dense-yardstick wire accounting is gated on the outer
      cadence (satellite fix): hold steps charge intra bytes only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_state, save_state
from repro.configs.base import (
    CommConfig,
    ElasticConfig,
    MAvgConfig,
    TopologyConfig,
    TrainConfig,
)
from repro.core.meta import init_state, make_meta_step
from repro.kernels import ops, ref
from repro.models.simple import mlp_init, mlp_loss
from repro.topology import (
    avg_graph_degree,
    graph_degree,
    mask_mixing_matrix,
    membership_schedule,
    mixing_matrix,
    mixing_matrix_stack,
    mixing_period,
)

D, C, H = 8, 4, 16
PARAMS = mlp_init(jax.random.PRNGKey(0), D, H, C)
RNG = np.random.RandomState(7)


def _batches(seed, L, K, B=4):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (L, K, B, D))
    y = jax.random.randint(ky, (L, K, B), 0, C)
    return {"x": x, "y": y}


def _run(cfg, n_steps=4, params=PARAMS):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(n_steps):
        state, metrics = step(state, _batches(i, cfg.num_learners, cfg.k_steps))
    return state, metrics


def _bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# E1: all-present membership == static topology, bit-for-bit
# ---------------------------------------------------------------------------

ALL_PRESENT = ElasticConfig(period=4, drop_frac=0.0)


@pytest.mark.parametrize("topo", [
    dict(kind="gossip", graph="ring"),
    dict(kind="gossip", graph="one_peer_exponential", momentum_tracking=True),
    dict(kind="gossip", graph="exponential",
         inner_comm=CommConfig(scheme="int8", error_feedback=True)),
])
def test_e1_all_present_gossip_is_static_bitwise(topo):
    base = dict(algorithm="mavg", num_learners=4, k_steps=3,
                learner_lr=0.1, momentum=0.6)
    s_static, _ = _run(MAvgConfig(**base, topology=TopologyConfig(**topo)))
    s_el, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        **topo, elastic=ALL_PRESENT)))
    _bitwise(s_static.global_params, s_el.global_params)
    _bitwise(s_static.topo["params"], s_el.topo["params"])
    _bitwise(s_static.topo["momentum"], s_el.topo["momentum"])
    if s_static.topo["residual"] is not None:
        _bitwise(s_static.topo["residual"], s_el.topo["residual"])
    _bitwise(s_static.learners, s_el.learners)


@pytest.mark.parametrize("topo", [
    dict(kind="hierarchical", groups=2, outer_every=2, outer_momentum=0.3),
    dict(kind="hierarchical", groups=2, outer_every=2,
         inner_comm=CommConfig(scheme="int8", error_feedback=True),
         outer_comm=CommConfig(scheme="int8_topk", error_feedback=True)),
])
def test_e1_all_present_hierarchical_is_static_bitwise(topo):
    base = dict(algorithm="mavg", num_learners=4, k_steps=3,
                learner_lr=0.1, momentum=0.6)
    s_static, _ = _run(MAvgConfig(**base, topology=TopologyConfig(**topo)))
    s_el, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        **topo, elastic=ALL_PRESENT)))
    _bitwise(s_static.global_params, s_el.global_params)
    _bitwise(s_static.topo["group_params"], s_el.topo["group_params"])
    _bitwise(s_static.topo["group_momentum"], s_el.topo["group_momentum"])
    _bitwise(s_static.learners, s_el.learners)


# ---------------------------------------------------------------------------
# E2: uniform group_k == scalar K, bit-for-bit
# ---------------------------------------------------------------------------


def test_e2_uniform_group_k_is_scalar_k_bitwise():
    base = dict(algorithm="mavg", num_learners=4, k_steps=3,
                learner_lr=0.1, momentum=0.6)
    topo = dict(kind="hierarchical", groups=2, outer_every=2)
    s_plain, m_plain = _run(MAvgConfig(**base, topology=TopologyConfig(**topo)))
    s_k, m_k = _run(MAvgConfig(**base, topology=TopologyConfig(
        **topo, group_k=(3, 3))))
    _bitwise(s_plain.global_params, s_k.global_params)
    _bitwise(s_plain.topo["group_params"], s_k.topo["group_params"])
    _bitwise(s_plain.learners, s_k.learners)
    # metrics reduce in a different (weighted) order — allclose, not bitwise
    np.testing.assert_allclose(float(m_plain["loss"]), float(m_k["loss"]),
                               rtol=1e-5)


def test_e2_hetero_group_k_changes_trajectory():
    base = dict(algorithm="mavg", num_learners=4, k_steps=4,
                learner_lr=0.1, momentum=0.6)
    topo = dict(kind="hierarchical", groups=2, outer_every=2)
    s_plain, _ = _run(MAvgConfig(**base, topology=TopologyConfig(**topo)))
    s_het, _ = _run(MAvgConfig(**base, topology=TopologyConfig(
        **topo, group_k=(1, 4))))
    diffs = [float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(s_plain.global_params),
        jax.tree.leaves(s_het.global_params))]
    assert max(diffs) > 1e-7
    for leaf in jax.tree.leaves(s_het.global_params):
        assert jnp.isfinite(leaf).all()


def test_group_k_validation():
    with pytest.raises(AssertionError):
        TopologyConfig(kind="gossip", group_k=(2, 2))
    with pytest.raises(AssertionError):
        TopologyConfig(kind="hierarchical", groups=2, group_k=(2,))
    with pytest.raises(ValueError):
        MAvgConfig(num_learners=4, k_steps=2, topology=TopologyConfig(
            kind="hierarchical", groups=2, group_k=(2, 5)))
    with pytest.raises(AssertionError):
        TopologyConfig(kind="flat", elastic=ElasticConfig())


# ---------------------------------------------------------------------------
# E3: one-peer exponential (time-varying graphs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L", [2, 3, 4, 7, 8, 16])
def test_e3_one_peer_matrices(L):
    T = mixing_period("one_peer_exponential", L)
    assert T == max(1, int(np.ceil(np.log2(L)))) if L > 2 else T == 1
    stack = mixing_matrix_stack("one_peer_exponential", L)
    assert stack.shape == (T, L, L)
    for t in range(T):
        W = stack[t]
        np.testing.assert_allclose(W.sum(0), 1.0, rtol=1e-6)
        np.testing.assert_allclose(W.sum(1), 1.0, rtol=1e-6)
        np.testing.assert_allclose(W, W.T, rtol=1e-6)
        deg = graph_degree("one_peer_exponential", L, t)
        if L & (L - 1) == 0:  # power of two: XOR perfect matching
            assert deg == 1
        else:
            assert 1 <= deg <= 2
    assert avg_graph_degree("one_peer_exponential", L) <= 2.0
    # far sparser than the static exponential graph at larger L
    if L >= 8:
        assert (avg_graph_degree("one_peer_exponential", L)
                < graph_degree("exponential", L))


def test_e3_one_peer_gossip_preserves_mean():
    cfg = MAvgConfig(algorithm="mavg", num_learners=8, k_steps=2,
                     momentum=0.5, topology=TopologyConfig(
                         kind="gossip", graph="one_peer_exponential"))
    s, _ = _run(cfg, n_steps=5)
    mean_xp = jax.tree.map(lambda x: jnp.mean(x, axis=0), s.topo["params"])
    for a, b in zip(jax.tree.leaves(mean_xp),
                    jax.tree.leaves(s.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_e3_one_peer_consensus_converges():
    """Alternating one-peer matrices over one period mix every pair:
    the product over the period contracts the consensus gap."""
    L = 8
    stack = mixing_matrix_stack("one_peer_exponential", L)
    P = np.eye(L, dtype=np.float64)
    for t in range(stack.shape[0]):
        P = stack[t].astype(np.float64) @ P
    # the full-period product is exactly the complete-graph average
    np.testing.assert_allclose(P, np.full((L, L), 1.0 / L), atol=1e-7)


def test_e3_stepped_kernel_matches_ref():
    from repro.kernels import neighbor_mix as nm

    L, rows = 8, 16
    x = jnp.asarray(RNG.randn(L, rows, 128), jnp.float32)
    stack = jnp.asarray(mixing_matrix_stack("one_peer_exponential", L))
    for t in [0, 1, 5]:
        out_k = nm.neighbor_mix_3d_stepped(x, stack, t, interpret=True)
        out_r = ref.neighbor_mix_stepped_ref(x, stack, t)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-5)
    # ops-level stack threading (any-shape leaf)
    y = jnp.asarray(RNG.randn(L, 33, 7), jnp.float32)
    out = ops.neighbor_mix_tree({"y": y}, stack, use_pallas=True, step=2,
                                interpret=True)
    np.testing.assert_allclose(
        np.asarray(out["y"]),
        np.asarray(ref.neighbor_mix_stepped_ref(y, stack, 2)),
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# E4: membership churn
# ---------------------------------------------------------------------------


def test_e4_membership_schedule_properties():
    el = ElasticConfig(period=6, drop_frac=0.25, seed=3)
    s1 = membership_schedule(8, el, groups=2)
    s2 = membership_schedule(8, el, groups=2)
    np.testing.assert_array_equal(s1, s2)  # deterministic in the seed
    assert s1.shape == (6, 8)
    assert ((s1 == 0) | (s1 == 1)).all()
    assert (s1.sum(axis=1) == 6).all()  # exactly round(0.25*8)=2 absent
    for g in range(2):  # every group keeps >= 1 present learner
        assert (s1[:, g * 4:(g + 1) * 4].sum(axis=1) >= 1).all()
    assert (membership_schedule(8, ElasticConfig(period=3, drop_frac=0.0))
            == 1.0).all()
    # extreme drop_frac still leaves one learner present
    s3 = membership_schedule(4, ElasticConfig(period=2, drop_frac=0.99))
    assert (s3.sum(axis=1) >= 1).all()


@pytest.mark.parametrize("graph", ["ring", "exponential",
                                   "one_peer_exponential"])
def test_e4_masked_matrix_doubly_stochastic(graph):
    L = 8
    W = jnp.asarray(mixing_matrix(graph, L))
    m = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
    Wm = np.asarray(mask_mixing_matrix(W, m))
    np.testing.assert_allclose(Wm.sum(0), 1.0, rtol=1e-6)
    np.testing.assert_allclose(Wm.sum(1), 1.0, rtol=1e-6)
    assert (Wm >= 0).all()
    for j in np.where(np.asarray(m) == 0)[0]:  # absent rows are identity
        expect = np.zeros(L, np.float32)
        expect[j] = 1.0
        np.testing.assert_array_equal(Wm[j], expect)
        np.testing.assert_array_equal(Wm[:, j], expect)
    # mean preservation through the masked mix
    x = jnp.asarray(RNG.randn(L, 33), jnp.float32)
    mixed = np.asarray(Wm) @ np.asarray(x)
    np.testing.assert_allclose(mixed.mean(0), np.asarray(x).mean(0),
                               rtol=1e-5, atol=1e-6)
    # all-present mask is the identity on W, bitwise
    np.testing.assert_array_equal(
        np.asarray(mask_mixing_matrix(W, jnp.ones(L, jnp.float32))),
        np.asarray(W))


def _diag_renorm_mask(W, m):
    """The retired churn masking: lost edge mass onto the diagonal."""
    L = W.shape[0]
    eye = np.eye(L, dtype=W.dtype)
    offdiag = W * (1.0 - eye)
    masked_off = offdiag * (m[:, None] * m[None, :])
    diag_present = np.diagonal(W) + (offdiag * (1.0 - m)[None, :]).sum(axis=1)
    diag = m * diag_present + (1.0 - m)
    return masked_off + eye * diag[:, None]


@pytest.mark.parametrize("graph", ["ring", "exponential"])
def test_e4_rewired_mask_improves_spectral_gap(graph):
    """Censoring the absent block re-wires present learners through the
    hole instead of making them lazier: the present-submatrix spectral
    gap (consensus rate) strictly beats diagonal renormalization."""
    L = 8
    W = np.asarray(mixing_matrix(graph, L), np.float64)
    m = np.asarray([1, 0, 1, 1, 0, 1, 1, 1], np.float64)
    present = np.where(m == 1)[0]

    def gap(Wm):
        sub = np.asarray(Wm, np.float64)[np.ix_(present, present)]
        ev = np.sort(np.abs(np.linalg.eigvalsh(sub)))[::-1]
        return 1.0 - ev[1]  # 1 - |lambda_2| of the present chain

    g_new = gap(mask_mixing_matrix(jnp.asarray(W, jnp.float32),
                                   jnp.asarray(m, jnp.float32)))
    g_old = gap(_diag_renorm_mask(W, m))
    assert g_new > g_old + 1e-3, (g_new, g_old)


def test_e4_gossip_churn_absent_frozen():
    cfg = MAvgConfig(
        algorithm="mavg", num_learners=8, k_steps=3, momentum=0.6,
        learner_lr=0.1,
        topology=TopologyConfig(
            kind="gossip", graph="ring",
            inner_comm=CommConfig(scheme="int8", error_feedback=True),
            elastic=ElasticConfig(period=4, drop_frac=0.25, seed=1)))
    state = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    sched = np.asarray(state.topo["membership"])
    for i in range(5):
        prev = state
        state, m = step(state, _batches(i, 8, 3))
        absent = sched[i % 4] == 0
        for key in ("params", "momentum", "residual"):
            for a, b in zip(jax.tree.leaves(prev.topo[key]),
                            jax.tree.leaves(state.topo[key])):
                np.testing.assert_array_equal(
                    np.asarray(a)[absent], np.asarray(b)[absent])
        assert float(m["present_count"]) == 6.0
        # wire bytes scale with live edges, never exceed the static model
        assert float(m["comm_bytes"]) <= float(m["comm_bytes_dense"])
    # global params still track the all-learner mean exactly
    mean_xp = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.topo["params"])
    for a, b in zip(jax.tree.leaves(mean_xp),
                    jax.tree.leaves(state.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_e4_hierarchical_churn_runs_finite():
    cfg = MAvgConfig(
        algorithm="mavg", num_learners=8, k_steps=3, momentum=0.6,
        learner_lr=0.1,
        topology=TopologyConfig(
            kind="hierarchical", groups=2, outer_every=2,
            group_k=(2, 3),
            elastic=ElasticConfig(period=4, drop_frac=0.25, seed=1)))
    s, m = _run(cfg, n_steps=5)
    for leaf in jax.tree.leaves(s.global_params):
        assert jnp.isfinite(leaf).all()
    assert float(m["present_count"]) == 6.0


# ---------------------------------------------------------------------------
# E5: checkpoint resume across the new state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", [
    TopologyConfig(kind="gossip", graph="one_peer_exponential",
                   elastic=ElasticConfig(period=4, drop_frac=0.25, seed=2)),
    TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                   group_k=(2, 3),
                   elastic=ElasticConfig(period=4, drop_frac=0.25, seed=2)),
])
def test_e5_mid_churn_roundtrip(tmp_path, topo):
    cfg = MAvgConfig(algorithm="mavg", num_learners=8, k_steps=3,
                     momentum=0.6, topology=topo)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(PARAMS, cfg)
    for i in range(3):  # stop mid-schedule (period 4)
        state, _ = step(state, _batches(i, 8, 3))
    path = save_state(str(tmp_path), state, 3)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    live, resumed = state, restored
    for i in range(3, 6):  # replay across the schedule wrap-around
        live, _ = step(live, _batches(i, 8, 3))
        resumed, _ = step(resumed, _batches(i, 8, 3))
    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_e5_schedule_shape_mismatch_rejected(tmp_path):
    def cfg_with(period):
        return MAvgConfig(algorithm="mavg", num_learners=8, k_steps=2,
                          topology=TopologyConfig(
                              kind="gossip", graph="ring",
                              elastic=ElasticConfig(period=period,
                                                    drop_frac=0.25)))

    state = init_state(PARAMS, cfg_with(2))
    path = save_state(str(tmp_path), state, 0)
    template = jax.eval_shape(
        lambda: init_state(PARAMS, cfg_with(4)))
    with pytest.raises(ValueError, match="membership|shape"):
        load_state(path, template)


def _make_trainer(tmp_path, warmup=6, steps_total=12):
    from repro.core.trainer import Trainer
    from repro.data import classif_batch_fn
    from repro.optim import warmup_cosine

    mcfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                      learner_lr=0.2, momentum=0.5)
    tcfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=4, seq_len=8,
        meta_steps=steps_total, log_every=4,
        checkpoint_dir=str(tmp_path), checkpoint_every=4,
    )
    return Trainer(
        tcfg,
        mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D, H, C),
        batch_fn=classif_batch_fn(D, C, 2, 2, 4),
        lr_schedule=warmup_cosine(0.2, warmup, steps_total),
    )


def test_e5_trainer_resume_mid_warmup_parity(tmp_path):
    from repro.checkpoint import latest_checkpoint

    t_full = _make_trainer(tmp_path / "full")
    hist_full = t_full.run(log=None)

    t_a = _make_trainer(tmp_path / "resume")
    t_a.run(meta_steps=4, log=None)  # checkpoint lands at step 4 (mid-warmup)
    t_b = _make_trainer(tmp_path / "resume")
    t_b.restore(latest_checkpoint(str(tmp_path / "resume")))
    hist_b = t_b.run(meta_steps=8, log=None)

    assert [h["meta_step"] for h in hist_b] == list(range(4, 12))
    # identical data + identical schedule indexing -> identical losses
    for h_full, h_res in zip(hist_full[4:], hist_b):
        np.testing.assert_allclose(h_full["loss"], h_res["loss"],
                                   rtol=1e-6, atol=1e-7)
    # history materializes on log boundaries but is complete afterwards
    assert len(hist_full) == 12
    assert all(np.isfinite(h["loss"]) for h in hist_full)


# ---------------------------------------------------------------------------
# E6: warmup_cosine continuity (satellite)
# ---------------------------------------------------------------------------


def test_e6_warmup_cosine_continuous():
    from repro.optim import warmup_cosine

    lr, warmup, total = 1.0, 100, 1000
    f = jax.jit(warmup_cosine(lr, warmup, total))
    vals = np.asarray([float(f(s)) for s in range(total + 1)])
    # warmup tops out at lr, cosine starts at lr: no cliff at the boundary
    np.testing.assert_allclose(vals[warmup - 1], lr, rtol=1e-6)
    np.testing.assert_allclose(vals[warmup], lr, rtol=1e-6)
    steps_diff = np.abs(np.diff(vals))
    assert steps_diff.max() <= 1.5 * lr / warmup, (
        f"discontinuity {steps_diff.max():.4f} at step {steps_diff.argmax()}"
    )
    # decay spans [warmup, total]: endpoint reaches final_frac * lr
    np.testing.assert_allclose(vals[total], 0.1 * lr, rtol=1e-5)
    assert (np.diff(vals[warmup:]) <= 1e-6).all()  # monotone decay


# ---------------------------------------------------------------------------
# E7: hierarchical dense-yardstick gating (satellite)
# ---------------------------------------------------------------------------


def test_e7_hier_dense_bytes_gated_on_outer_cadence():
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     momentum=0.5,
                     topology=TopologyConfig(kind="hierarchical", groups=2,
                                             outer_every=3))
    state = init_state(PARAMS, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(6):
        state, m = step(state, _batches(i, 4, 2))
        if (i + 1) % 3 == 0:
            assert float(m["outer_fired"]) == 1.0
            assert float(m["comm_bytes_dense"]) > float(m["comm_bytes_intra"])
        else:
            # hold step: no inter-node traffic under *any* scheme, so the
            # dense yardstick charges the intra class only
            assert float(m["outer_fired"]) == 0.0
            assert float(m["comm_bytes_inter"]) == 0.0
            assert float(m["comm_bytes_dense"]) == float(m["comm_bytes_intra"])


# ---------------------------------------------------------------------------
# degree-over-time wire model
# ---------------------------------------------------------------------------


def test_wire_model_degree_over_time():
    from repro.roofline import elastic_presence, topology_wire_bytes

    n, L = 1_000_000, 8
    static = topology_wire_bytes(
        n, CommConfig(), TopologyConfig(kind="gossip", graph="exponential"),
        num_learners=L)
    one_peer = topology_wire_bytes(
        n, CommConfig(),
        TopologyConfig(kind="gossip", graph="one_peer_exponential"),
        num_learners=L)
    # degree 1 vs degree 5 at L=8: bytes scale with the averaged degree
    assert one_peer["avg_degree"] == 1.0
    assert static["avg_degree"] == 5.0
    assert one_peer["inter_bytes"] == pytest.approx(
        static["inter_bytes"] / static["avg_degree"])

    el = TopologyConfig(kind="gossip", graph="ring",
                        elastic=ElasticConfig(period=4, drop_frac=0.25))
    lf, ef = elastic_presence(el, L)
    assert 0.0 < ef < 1.0 and lf == pytest.approx(0.75)
    churn = topology_wire_bytes(n, CommConfig(), el, num_learners=L)
    ring = topology_wire_bytes(
        n, CommConfig(), TopologyConfig(kind="gossip", graph="ring"),
        num_learners=L)
    assert churn["inter_bytes"] == pytest.approx(ring["inter_bytes"] * ef)
    assert churn["edge_presence"] == pytest.approx(ef)

    hier = topology_wire_bytes(
        n, CommConfig(),
        TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                       elastic=ElasticConfig(period=4, drop_frac=0.25)),
        num_learners=L)
    full = topology_wire_bytes(
        n, CommConfig(),
        TopologyConfig(kind="hierarchical", groups=2, outer_every=2),
        num_learners=L)
    assert hier["intra_bytes"] == pytest.approx(full["intra_bytes"] * 0.75)
    assert hier["inter_bytes"] == full["inter_bytes"]  # groups always sync
