"""MoE layer correctness against a per-token python-loop oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.layers import swiglu
from repro.models.moe import _capacity, init_moe, moe_layer


def _oracle(x, p, cfg):
    """Brute force: route each token to its top-k experts, respecting the
    same first-come capacity rule (tokens in flattened slot order)."""
    B, S, d = x.shape
    T = B * S
    xt = np.asarray(x.reshape(T, d), np.float32)
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    k = cfg.moe_top_k
    E = cfg.num_experts
    C = _capacity(T, cfg)
    topk = np.argsort(-probs, axis=-1)[:, :k]
    gates = np.take_along_axis(probs, topk, axis=-1)
    gates = gates / np.maximum(gates.sum(-1, keepdims=True), 1e-9)

    counts = np.zeros(E, int)
    out = np.zeros((T, d), np.float32)
    w_in = np.asarray(p["w_in"], np.float32)
    w_out = np.asarray(p["w_out"], np.float32)
    for t in range(T):
        for j in range(k):
            e = int(topk[t, j])
            if counts[e] >= C:
                counts[e] += 1
                continue
            counts[e] += 1
            h = np.einsum("d,dtf->tf", xt[t], w_in[e])  # (2, de)
            act = h[0] / (1 + np.exp(-h[0])) * h[1]
            out[t] += gates[t, j] * (act @ w_out[e])
    if cfg.num_shared_experts:
        out = out + np.asarray(
            swiglu(jnp.asarray(xt), p["shared"]), np.float32
        )
    return out.reshape(B, S, d)


def test_moe_matches_oracle():
    cfg = dataclasses.replace(
        get_config("deepseek-moe-16b").reduced(), dtype="float32"
    )
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    out, aux = moe_layer(x, p, cfg)
    expect = _oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-3, atol=2e-3)
    assert jnp.isfinite(aux)


def test_moe_capacity_drops_tokens():
    """With capacity_factor tiny, overflow tokens must contribute zero
    routed output (not garbage)."""
    cfg = dataclasses.replace(
        get_config("deepseek-moe-16b").reduced(), dtype="float32",
        capacity_factor=0.01, num_shared_experts=0,
    )
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    out, _ = moe_layer(x, p, cfg)
    expect = _oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-3, atol=2e-3)


def test_moe_aux_loss_balanced_router():
    """A uniform router gives the minimum-possible aux loss ~ coef."""
    cfg = dataclasses.replace(
        get_config("deepseek-moe-16b").reduced(), dtype="float32"
    )
    p = init_moe(jax.random.PRNGKey(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    _, aux = moe_layer(x, p, cfg)
    assert float(aux) <= cfg.moe_aux_coef * 1.3
