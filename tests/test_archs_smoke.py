"""Per-architecture smoke tests (spec deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model <= 256, <= 4 experts), run one forward/train step on
CPU, assert output shapes and absence of NaNs; run one decode step where
the family supports decoding.
"""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # full-arch sweep, ~70s

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import MAvgConfig
from repro.core import init_state, make_meta_step
from repro.models import (
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    make_batch,
    prefill,
)

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = init_params(RNG, cfg)
    return request.param, cfg, params


def test_reduced_config_limits(arch_setup):
    _, cfg, _ = arch_setup
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


def test_forward_loss(arch_setup):
    arch, cfg, params = arch_setup
    batch = make_batch(RNG, cfg, 2, 32)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    assert jnp.isfinite(metrics["ce"])


def test_train_step_mavg(arch_setup):
    """One full M-AVG meta step (2 learners x 2 local steps)."""
    arch, cfg, params = arch_setup
    mcfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                      learner_lr=0.05, momentum=0.5)
    state = init_state(params, mcfg)
    step = jax.jit(make_meta_step(lambda p, b: loss_fn(p, cfg, b), mcfg))
    one = make_batch(RNG, cfg, 2, 32)
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None, None], (2, 2) + x.shape), one
    )
    state, metrics = step(state, batches)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["v_norm"])
    assert int(state.step) == 1
    for leaf in jax.tree.leaves(state.global_params):
        assert jnp.isfinite(leaf).all(), arch


def test_decode_step(arch_setup):
    arch, cfg, params = arch_setup
    if not cfg.supports_decode:
        pytest.skip("encoder-only: no decode (recorded in DESIGN.md)")
    cache = init_cache(cfg, 2, 48)
    toks = jnp.array([1, 2], jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t)
    )(params, cache, toks)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    assert int(cache2["pos"]) == 1


def test_prefill_shapes(arch_setup):
    arch, cfg, params = arch_setup
    if not cfg.supports_decode or cfg.input_mode != "tokens":
        pytest.skip("prefill test targets token decoders")
    toks = jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size, jnp.int32)
    logits, cache = jax.jit(
        lambda p, b: prefill(p, cfg, b, 48)
    )(params, {"tokens": toks})
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
