"""repro.chaos acceptance tests (DESIGN.md §13).

The contract has three legs, each pinned here:

1. **Determinism / replay** — a ``FaultSchedule`` is a pure function of
   (ChaosConfig, num_learners, salt); retries (salt > 0) drop transient
   faults but keep sticky ones, and the config STRUCTURE (membership
   schedule, straggle profile) survives the salt so checkpoints restore
   across attempts.
2. **Off == bitwise identity** — every injector disabled (idle
   corruptor installed, finite guard on) reproduces the vanilla run
   bit-for-bit, so chaos can ride in the default config path.
3. **Supervised recovery** — an injected fault halts the run, the
   Supervisor rolls back through the verified chain and completes the
   target steps with schema-valid fault/recovery telemetry; a sticky
   fault exhausts the bounded retry budget instead of looping forever.
"""
import dataclasses
import importlib.util
import os
from types import SimpleNamespace

import pytest

import jax
import numpy as np

from repro.chaos import (
    ChaosConfig,
    FaultSchedule,
    FaultSpec,
    PayloadCorruptor,
    apply_chaos,
    standard_chaos,
    wrap_batch_fn,
)
from repro.checkpoint import save_state
from repro.configs.base import (
    AsyncConfig,
    MAvgConfig,
    ObsConfig,
    TopologyConfig,
    TrainConfig,
)
from repro.core import (
    RecoveryExhausted,
    RecoveryPolicy,
    Supervisor,
    Trainer,
)
from repro.core.meta import init_state, make_meta_step
from repro.data import classif_batch_fn
from repro.models.simple import mlp_init, mlp_loss
from repro.obs import HealthHalt
from repro.utils.retry import retry_io

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

L, K, B, D, C = 2, 2, 4, 8, 4


def _mcfg(**kw):
    kw.setdefault("num_learners", L)
    kw.setdefault("learner_lr", 0.1)
    return MAvgConfig(algorithm="mavg", k_steps=K, momentum=0.6, **kw)


def _batches(seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _leaves_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ---------------------------------------------------------------------------
# CH1: schedule determinism + salt semantics
# ---------------------------------------------------------------------------


def test_ch1_schedule_deterministic():
    cfg = standard_chaos(4, 32, seed=7)
    a, b = FaultSchedule(cfg, 4), FaultSchedule(cfg, 4)
    for name in ("nan", "inf", "scale", "xor", "pos", "crash"):
        np.testing.assert_array_equal(getattr(a, name), getattr(b, name))
    np.testing.assert_array_equal(a.straggle_extra, b.straggle_extra)
    assert a.save_faults == b.save_faults


def test_ch1_salt_drops_transient_keeps_sticky():
    cfg = ChaosConfig(seed=3, horizon=8, faults=(
        FaultSpec("nan_batch", step=1, learner=0),               # transient
        FaultSpec("payload_scale", step=2, learner=1,
                  magnitude=2.0, sticky=True),                   # broken hw
    ))
    s0 = FaultSchedule(cfg, L)
    s1 = FaultSchedule(cfg, L, salt=1)
    assert s0.nan[1, 0] == 1.0 and s0.scale[2, 1] == 2.0
    # the retry replays the transient fault clean...
    assert not s1.nan.any()
    # ...but the sticky one re-fires identically
    np.testing.assert_array_equal(s1.scale, s0.scale)


def test_ch1_out_of_horizon_steps_are_clean():
    cfg = ChaosConfig(seed=0, horizon=4,
                      faults=(FaultSpec("nan_batch", step=3, learner=0),))
    sched = FaultSchedule(cfg, L)
    nan, inf = sched.batch_fault_at(3)
    assert nan.any()
    for step in (-1, 4, 100):
        nan, inf = sched.batch_fault_at(step)
        assert not (nan.any() or inf.any())
    assert sched.save_fault(100) is None


def test_ch1_config_validation():
    with pytest.raises(AssertionError):  # fault beyond the horizon
        ChaosConfig(horizon=4, faults=(FaultSpec("crash", step=3,
                                                 duration=2),))
    with pytest.raises(AssertionError):  # unknown kind
        FaultSpec("meteor_strike", step=0)
    with pytest.raises(AssertionError):  # save faults target the run
        FaultSpec("torn_save", step=0, learner=1)


# ---------------------------------------------------------------------------
# CH2: every injector off => bitwise identical to vanilla
# ---------------------------------------------------------------------------


def test_ch2_injectors_off_bitwise_identical():
    """Idle corruptor installed + finite guard on == no chaos at all, at
    the bit level — the pin that lets chaos live in the default path."""
    empty = FaultSchedule(ChaosConfig(seed=0, horizon=8, faults=()), L)
    assert not (empty.any_batch_faults or empty.any_payload_faults
                or empty.any_crash_faults)
    assert wrap_batch_fn(lambda rng, s: _batches(0), empty)(None, 0) \
        is not None  # no-fault schedule returns batch_fn itself
    plain = jax.jit(make_meta_step(mlp_loss, _mcfg()))
    armed = jax.jit(make_meta_step(mlp_loss, _mcfg(finite_guard=True),
                                   chaos=PayloadCorruptor(empty)))
    sp = sa = init_state(mlp_init(jax.random.PRNGKey(0), D, 16, C),
                         _mcfg())
    for i in range(3):
        sp, _ = plain(sp, _batches(i))
        sa, ma = armed(sa, _batches(i))
    assert _leaves_equal(sp, sa)
    assert float(ma["nonfinite_learners"]) == 0.0


def test_ch2_apply_chaos_no_structural_faults_is_identity():
    mcfg = _mcfg()
    chaos = ChaosConfig(seed=0, horizon=8,
                        faults=(FaultSpec("nan_batch", step=1, learner=0),))
    assert apply_chaos(mcfg, chaos) is mcfg  # the identical object


# ---------------------------------------------------------------------------
# CH3: per-layer injection
# ---------------------------------------------------------------------------


def test_ch3_nan_batch_guard_keeps_state_finite():
    """A poisoned batch NaNs the target learner's local phase; the
    in-step finite guard resets it to the broadcast global params
    (skip-and-decay), reports it in ``nonfinite_learners``, and no
    non-finite value ever reaches MetaState."""
    chaos = ChaosConfig(seed=0, horizon=4,
                        faults=(FaultSpec("nan_batch", step=0, learner=0),))
    sched = FaultSchedule(chaos, L)
    poisoned = wrap_batch_fn(lambda rng, s: _batches(0), sched)(None, 0)
    assert np.isnan(np.asarray(poisoned["x"])[0]).all()
    assert np.isfinite(np.asarray(poisoned["x"])[1]).all()

    step = jax.jit(make_meta_step(mlp_loss, _mcfg(finite_guard=True)))
    state = init_state(mlp_init(jax.random.PRNGKey(0), D, 16, C), _mcfg())
    state, metrics = step(state, poisoned)
    assert float(metrics["nonfinite_learners"]) == 1.0
    for x in jax.tree.leaves((state.global_params, state.momentum,
                              state.learners)):
        assert np.isfinite(np.asarray(x)).all()


def test_ch3_payload_corruption_deterministic_and_localized():
    """Payload corruption fires exactly on its scheduled step, changes
    the trajectory, and replays identically."""
    chaos = ChaosConfig(seed=0, horizon=8, faults=(
        FaultSpec("payload_scale", step=1, learner=1, magnitude=3.0),
    ))
    cor = PayloadCorruptor(FaultSchedule(chaos, L))
    assert cor.active
    plain = jax.jit(make_meta_step(mlp_loss, _mcfg()))
    dirty = jax.jit(make_meta_step(mlp_loss, _mcfg(), chaos=cor))

    def run(step_fn):
        s = init_state(mlp_init(jax.random.PRNGKey(0), D, 16, C), _mcfg())
        out = []
        for i in range(3):
            s, _ = step_fn(s, _batches(i))
            out.append(s)
        return out

    sp, sd, sd2 = run(plain), run(dirty), run(dirty)
    assert _leaves_equal(sp[0], sd[0])        # step 0: quiet => bitwise
    assert not _leaves_equal(sp[1], sd[1])    # step 1: fault fired
    for a, b in zip(sd, sd2):                 # replay identical
        assert _leaves_equal(a, b)


def test_ch3_bitflip_is_a_real_bit():
    """payload_bitflip changes exactly ONE element of one leaf, by an
    XOR of the configured bit — a bit-level event, not a rescale."""
    chaos = ChaosConfig(seed=1, horizon=4, faults=(
        FaultSpec("payload_bitflip", step=0, learner=1, bit=23),
    ))
    cor = PayloadCorruptor(FaultSchedule(chaos, L))
    learners = {
        "w": jax.numpy.ones((L, 3, 5), jax.numpy.float32),
        "b": jax.numpy.zeros((L, 7), jax.numpy.float32),
    }
    out = cor(learners, jax.numpy.int32(0))
    diffs = [
        int((np.asarray(out[k]) != np.asarray(learners[k])).sum())
        for k in ("w", "b")
    ]
    assert sum(diffs) == 1  # exactly one element anywhere
    a = np.asarray(learners["w"]).view(np.int32)
    bflip = np.asarray(out["w"]).view(np.int32)
    changed = a != bflip
    if changed.any():
        assert (a[changed] ^ bflip[changed] == (1 << 23)).all()
    # learner 0 untouched bitwise
    assert np.array_equal(np.asarray(out["w"])[0],
                          np.asarray(learners["w"])[0])


def test_ch3_crash_maps_to_membership_schedule():
    """Crash windows become rows of an explicit elastic membership
    schedule; a retry (salt > 0) keeps the STRUCTURE (same-shape
    schedule, checkpoint-compatible) but drops the injected absences."""
    mcfg = _mcfg(num_learners=4, topology=TopologyConfig(
        kind="async", server=AsyncConfig(staleness=2)))
    chaos = ChaosConfig(seed=0, horizon=6, faults=(
        FaultSpec("crash", step=1, learner=2, duration=2),
    ))
    out = apply_chaos(mcfg, chaos)
    rows = np.asarray(out.topology.elastic.schedule, np.float32)
    assert rows.shape == (6, 4)
    assert rows[1, 2] == 0.0 and rows[2, 2] == 0.0  # the crash window
    assert rows[0, 2] == 1.0 and rows[3, 2] == 1.0  # present outside it
    assert (rows.sum(axis=1) >= 1.0).all()

    retry = apply_chaos(mcfg, chaos, salt=1)
    rows1 = np.asarray(retry.topology.elastic.schedule, np.float32)
    assert rows1.shape == rows.shape  # structure survives the salt
    assert (rows1 == 1.0).all()       # the transient absences do not

    with pytest.raises(ValueError, match="flat"):
        apply_chaos(_mcfg(num_learners=4), chaos)


def test_ch3_straggle_lands_on_async_profile():
    mcfg = _mcfg(num_learners=2, topology=TopologyConfig(
        kind="async", server=AsyncConfig(staleness=1)))
    chaos = ChaosConfig(seed=0, horizon=8, faults=(
        FaultSpec("straggle", step=0, learner=1, magnitude=3.0),
    ))
    out = apply_chaos(mcfg, chaos)
    prof = out.topology.server.step_time
    assert prof[1] - prof[0] == 3
    assert out.topology.server.staleness >= max(prof) - 1


# ---------------------------------------------------------------------------
# CH4: supervised recovery
# ---------------------------------------------------------------------------


def _check_telemetry():
    spec = importlib.util.spec_from_file_location(
        "check_telemetry", os.path.join(_ROOT, "tools", "check_telemetry.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _make_trainer_factory(tmp_path, chaos, *, steps=8):
    ckpt = str(tmp_path / "ckpt")
    run_dir = str(tmp_path / "run")

    def make_trainer(plan):
        mcfg = _mcfg(learner_lr=0.1 * plan.lr_scale, finite_guard=True)
        tcfg = TrainConfig(
            model=None, mavg=mcfg, batch_per_learner=B, meta_steps=steps,
            seed=0, log_every=1, checkpoint_dir=ckpt, checkpoint_every=2,
            chaos=chaos, data_salt=plan.data_salt,
            obs=ObsConfig(sink="jsonl", run_dir=run_dir, health=True),
        )
        return Trainer(
            tcfg, mlp_loss,
            init_params_fn=lambda rng: mlp_init(rng, D, 16, C),
            batch_fn=classif_batch_fn(D, C, L, K, B),
        )

    return make_trainer, ckpt, run_dir


def test_ch4_supervised_recovery_completes(tmp_path):
    """A transient NaN burst halts the run; the supervisor rolls back
    through the verified chain and the retry (fault dropped by the salt)
    completes the target steps with schema-valid telemetry."""
    steps = 8
    chaos = ChaosConfig(seed=0, horizon=steps, faults=(
        FaultSpec("nan_batch", step=3, learner=0),
    ))
    make_trainer, ckpt, run_dir = _make_trainer_factory(
        tmp_path, chaos, steps=steps)
    sup = Supervisor(make_trainer, target_steps=steps, checkpoint_dir=ckpt)
    trainer, history = sup.run(log=None)
    assert int(trainer.state.step) == steps
    for x in jax.tree.leaves((trainer.state.global_params,
                              trainer.state.learners)):
        assert np.isfinite(np.asarray(x)).all()

    faults = [r for r in sup.records if r.get("kind") == "fault"]
    recoveries = [r for r in sup.records if r.get("kind") == "recovery"]
    assert faults and faults[0]["fault"] == "nonfinite_loss"
    assert faults[0]["learner"] == 0  # the schedule's attribution oracle
    assert recoveries and recoveries[0]["attempt"] == 1
    assert "rollback" in recoveries[0]["policy"]
    trainer.close()

    ct = _check_telemetry()
    schema = ct.load_schema(os.path.join(_ROOT, "tools",
                                         "telemetry_schema.json"))
    with open(os.path.join(run_dir, "run.jsonl")) as f:
        assert ct.check_stream(f, schema) == []


def test_ch4_sticky_fault_exhausts_retries(tmp_path):
    """A sticky fault re-fires on every salt: the bounded budget runs
    out, RecoveryExhausted carries the fault, and the
    recovery_exhausted watchdog alert lands in the record stream."""
    steps = 8
    chaos = ChaosConfig(seed=0, horizon=steps, faults=(
        FaultSpec("nan_batch", step=1, learner=0, sticky=True),
    ))
    make_trainer, ckpt, _ = _make_trainer_factory(
        tmp_path, chaos, steps=steps)
    sup = Supervisor(make_trainer, target_steps=steps, checkpoint_dir=ckpt,
                     policy=RecoveryPolicy(max_retries=1))
    with pytest.raises(RecoveryExhausted) as ei:
        sup.run(log=None)
    assert ei.value.fault["fault"] == "nonfinite_loss"
    assert any(r.get("rule") == "recovery_exhausted" for r in sup.records)


def test_ch4_rollback_is_causal_and_walks_back(tmp_path):
    """The supervisor never resumes from a snapshot at/after the fault
    step (the emergency halt snapshot verifies finite yet carries the
    sick state), and a retry that stalls without progress distrusts the
    snapshot it resumed from — one snapshot further back per stalled
    attempt, down to a scratch restart."""
    tree = {"a": np.arange(4.0)}
    ckpt = str(tmp_path)
    for s in (2, 4, 5):  # 5 plays the emergency halt snapshot
        save_state(ckpt, tree, s)

    class _FakeTrainer:
        def __init__(self):
            self.state = SimpleNamespace(step=0)
            self.history = []
            self._monitor = None

        def restore(self, path):
            from repro.checkpoint import checkpoint_step
            self.state.step = checkpoint_step(path)

        def run(self, remaining, log=None):
            self.state.step = 5
            raise HealthHalt({"rule": "loss_divergence", "metric": "loss",
                              "value": 99.0, "meta_step": 4})

        def emit(self, record):
            pass

        def close(self):
            pass

    sup = Supervisor(lambda plan: _FakeTrainer(), target_steps=10,
                     checkpoint_dir=ckpt,
                     policy=RecoveryPolicy(max_retries=3))
    with pytest.raises(RecoveryExhausted):
        sup.run(log=None)
    resumes = [
        (r["meta_step"], r["resume_path"])
        for r in sup.records if r.get("kind") == "recovery"
    ]
    # never step 5; then 4 -> 2 -> scratch as the stall deepens
    assert [s for s, _ in resumes] == [4, 2, 0]
    assert resumes[-1][1] is None


def test_ch4_quarantine_masks_then_readmits(tmp_path):
    """Quarantine rewrites the membership window after the resume step
    (probation), leaves later rows untouched (readmission), and never
    empties a row."""
    mcfg = _mcfg(num_learners=4, topology=TopologyConfig(
        kind="async", server=AsyncConfig(staleness=2)))
    chaos = ChaosConfig(seed=0, horizon=8, faults=(
        FaultSpec("crash", step=6, learner=3),
    ))
    tcfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=B, meta_steps=8, seed=0,
        chaos=chaos, obs=ObsConfig(sink="none"),
    )
    trainer = Trainer(
        tcfg, mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D, 16, C),
        batch_fn=classif_batch_fn(D, C, 4, K, B),
    )
    sup = Supervisor(lambda plan: trainer, target_steps=8,
                     checkpoint_dir=None,
                     policy=RecoveryPolicy(quarantine_steps=2))
    sup._quarantine(trainer, (1,), 2)
    m = np.asarray(trainer.state.topo["membership"])
    assert m[2, 1] == 0.0 and m[3, 1] == 0.0   # probation window
    assert m[4, 1] == 1.0 and m[1, 1] == 1.0   # readmitted / untouched
    assert (m.sum(axis=1) >= 1.0).all()
    trainer.close()


# ---------------------------------------------------------------------------
# CH5: shared retry helper + sink resilience
# ---------------------------------------------------------------------------


def test_ch5_retry_io_backoff_then_success():
    calls = {"n": 0}
    delays = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert retry_io(flaky, sleep=delays.append) == "ok"
    assert calls["n"] == 3
    assert delays == [0.05, 0.05 * 2.0]  # exponential backoff observed


def test_ch5_retry_io_exhausts_loudly():
    calls = {"n": 0}

    def dead():
        calls["n"] += 1
        raise OSError("gone")

    with pytest.raises(OSError, match="gone"):
        retry_io(dead, attempts=3, sleep=lambda d: None)
    assert calls["n"] == 3


def test_ch5_retry_io_only_retries_transient_classes():
    calls = {"n": 0}

    def broken():
        calls["n"] += 1
        raise ValueError("a bug, not an I/O hiccup")

    with pytest.raises(ValueError):
        retry_io(broken, sleep=lambda d: None)
    assert calls["n"] == 1


def test_ch5_jsonl_sink_survives_transient_oserror(tmp_path):
    from repro.obs.sink import JsonlSink

    path = str(tmp_path / "run.jsonl")
    sink = JsonlSink(path)

    real = sink._f
    flaky = SimpleNamespace(
        fails=1,
        write=lambda s: _flaky_write(flaky, real, s),
        flush=real.flush,
        close=real.close,
        closed=False,
    )
    sink._f = flaky
    sink.append({"kind": "step", "meta_step": 0, "loss": 1.0})
    sink.flush()
    real.close()
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    assert len(lines) == 1 and '"loss": 1.0' in lines[0]


def _flaky_write(self, real, s):
    if self.fails:
        self.fails -= 1
        raise OSError("EAGAIN")
    return real.write(s)


def test_ch5_backoff_schedule_seeded_jitter():
    """The jittered backoff schedule is a pure function of (seed, jitter):
    replayable bit-for-bit, bounded by [base*f^i, base*f^i*(1+jitter)],
    and jitter=0 (the default) IS the plain exponential schedule."""
    from repro.utils import backoff_schedule

    assert backoff_schedule(4) == [0.05, 0.05 * 2.0, 0.05 * 4.0]
    a = backoff_schedule(5, jitter=0.5, seed=11)
    assert a == backoff_schedule(5, jitter=0.5, seed=11)  # deterministic
    assert a != backoff_schedule(5, jitter=0.5, seed=12)  # seed-keyed
    assert a != backoff_schedule(5, jitter=0.0, seed=11)  # jitter is real
    for i, d in enumerate(a):
        base = 0.05 * 2.0 ** i
        assert base <= d <= base * 1.5

    calls = {"n": 0}
    delays = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    # retry_io sleeps exactly the schedule's delays, in order
    assert retry_io(flaky, jitter=0.5, seed=11, sleep=delays.append) == "ok"
    assert delays == a[:2]


def test_ch4_quarantine_hysteresis_extends_probation(tmp_path):
    """``readmit_clean_windows=M`` stretches the probation window to
    M x quarantine_steps — a flapping learner must stay clean for M
    windows before readmission; M=1 is the old single-window behavior
    (pinned by test_ch4_quarantine_masks_then_readmits)."""
    mcfg = _mcfg(num_learners=4, topology=TopologyConfig(
        kind="async", server=AsyncConfig(staleness=2)))
    chaos = ChaosConfig(seed=0, horizon=8, faults=(
        FaultSpec("crash", step=6, learner=3),
    ))
    tcfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=B, meta_steps=8, seed=0,
        chaos=chaos, obs=ObsConfig(sink="none"),
    )
    trainer = Trainer(
        tcfg, mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D, 16, C),
        batch_fn=classif_batch_fn(D, C, 4, K, B),
    )
    sup = Supervisor(lambda plan: trainer, target_steps=8,
                     checkpoint_dir=None,
                     policy=RecoveryPolicy(quarantine_steps=2,
                                           readmit_clean_windows=2))
    sup._quarantine(trainer, (1,), 2)
    m = np.asarray(trainer.state.topo["membership"])
    assert (m[2:6, 1] == 0.0).all()            # 2 x 2 probation rows
    assert m[6, 1] == 1.0 and m[1, 1] == 1.0   # readmitted / untouched
    assert (m.sum(axis=1) >= 1.0).all()
    trainer.close()


def test_ch4_readmit_clean_windows_validation():
    with pytest.raises(AssertionError):
        RecoveryPolicy(readmit_clean_windows=0)
