"""Sharding-rule unit tests (uses AbstractMesh — no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import specs as S
from repro.models import api as model_api
from repro.sharding import add_learner_axis, make_param_specs

# jax >= 0.4.35: AbstractMesh takes a single ((name, size), ...) tuple
MESH = AbstractMesh((("data", 16), ("model", 16)))


def _specs(arch, **kw):
    cfg = get_config(arch)
    params = S.abstract_params(cfg)
    return params, make_param_specs(params, MESH, **kw)


def test_llama_attention_head_parallel():
    params, specs = _specs("llama3-405b")
    # wq (126, d, h, hd): heads divisible by 16 -> head-parallel
    assert specs["blocks"]["attn"]["wq"] == P(None, None, "model", None)
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", None, None)
    # mlp wi (126, d, 2, ff): ff-parallel
    assert specs["blocks"]["mlp"]["wi"] == P(None, None, None, "model")
    assert specs["blocks"]["mlp"]["wo"] == P(None, "model", None)
    assert specs["embed"]["embedding"] == P("model", None)


def test_qwen2_head_fallback():
    """28 heads don't divide 16 -> fall back to d_model row-parallel."""
    params, specs = _specs("qwen2-7b")
    assert specs["blocks"]["attn"]["wq"] == P(None, "model", None, None)
    # wo (h, hd, d): heads 28 not divisible -> output dim
    assert specs["blocks"]["attn"]["wo"] == P(None, None, None, "model")


def test_moe_expert_parallel():
    params, specs = _specs("kimi-k2-1t-a32b")
    assert specs["blocks"]["moe"]["w_in"] == P(None, "model", None, None, None)
    assert specs["blocks"]["moe"]["w_out"] == P(None, "model", None, None)
    assert specs["blocks"]["moe"]["router"] == P(None, None, "model")


def test_norms_replicated():
    params, specs = _specs("qwen3-1.7b")
    assert specs["final_norm"]["scale"] == P(None)
    assert specs["blocks"]["attn_norm"]["scale"] == P(None, None)


def test_fsdp_second_axis():
    params, specs = _specs("llama3-405b", fsdp_axis="data")
    # wq gets model on heads + data on d_model
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model", None)


def test_learner_axis_prepend():
    params, specs = _specs("qwen3-1.7b")
    lspecs = add_learner_axis(specs, "data")
    assert lspecs["blocks"]["attn"]["wq"] == P("data", None, None, "model", None)


def test_every_leaf_has_spec_every_arch():
    """No parameter silently missing a rule (catches new layer types)."""
    from repro.configs import ARCH_IDS

    for arch in ARCH_IDS:
        params, specs = _specs(arch)
        np_, ns_ = len(jax.tree.leaves(params)), len(
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        )
        assert np_ == ns_, arch


def test_divisibility_every_arch():
    """Sharded dims always divisible by the mesh-axis size."""
    from repro.configs import ARCH_IDS

    for arch in ARCH_IDS:
        params, specs = _specs(arch, fsdp_axis="data")
        flat_p = jax.tree_util.tree_leaves_with_path(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat_p, flat_s):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                size = MESH.shape[ax] if isinstance(ax, str) else 16
                assert leaf.shape[dim] % size == 0, (arch, path, spec)
