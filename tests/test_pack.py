"""repro.pack acceptance tests: the packed flat meta-plane (DESIGN.md §9).

Invariants:
  PK1  pack -> unpack round-trips every models/ architecture's param tree
       bit-exactly, preserving per-leaf dtypes; stacked (L, ...) planes
       round-trip through pack_stacked/unpack_stacked the same way.
  PK2  PackSpec layout: lane-aligned offsets, non-overlapping slots,
       8-row buffer, padding waste never exceeds the legacy per-leaf
       8x128 tile waste; the spec is hashable and value-equal across
       reconstructions (the static-field contract).
  PK3  packed meta-step parity with the legacy per-leaf path: dense
       comm is bit-level (identical algebra, different layout) for
       flat / hierarchical / gossip; int8+EF agrees to quantization
       noise (different chunk boundaries by design) and stays unbiased.
  PK4  the fused pack_update kernel (interpret) matches its jnp oracle
       (shared dither: bit-identical rounding decisions) and satisfies
       the EF invariant delta + e = C(delta + e) + e' exactly.
  PK5  padding slots stay zero through training (the invariant that
       makes packed norms/means equal per-leaf ones).
  PK6  a legacy per-leaf checkpoint loads bit-exactly into a packed
       MetaState template (layout-converting restore), and packed
       checkpoints carry the __packspec__ decode sidecar.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_packspec, load_state, save_state
from repro.configs.base import (
    ARCH_IDS,
    CommConfig,
    MAvgConfig,
    TopologyConfig,
    get_config,
)
from repro.core.meta import init_state, make_meta_step
from repro.kernels import ops, ref
from repro.models import api as model_api
from repro.models.simple import mlp_init, mlp_loss
from repro.pack import make_pack_spec, unpack_params
from repro.utils import tree_norm, tree_sub

D, C, H = 8, 4, 16
PARAMS = mlp_init(jax.random.PRNGKey(0), D, H, C)
RNG = np.random.RandomState(3)


def _batches(seed, L, K, B=4):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _run(cfg, n_steps=3, params=PARAMS):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    for i in range(n_steps):
        state, metrics = step(state, _batches(i, cfg.num_learners, cfg.k_steps))
    return state, metrics


def _bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# PK1: round trip over every architecture's param tree
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_pk1_roundtrip_all_archs(arch):
    cfg = get_config(arch).reduced()
    params = model_api.init_params(jax.random.PRNGKey(0), cfg)
    spec = make_pack_spec(params)
    buf = spec.pack(params)
    assert buf.shape == (spec.rows, 128) and spec.rows % 8 == 0
    restored = spec.unpack(buf)
    assert (jax.tree_util.tree_structure(restored)
            == jax.tree_util.tree_structure(params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pk1_stacked_roundtrip():
    spec = make_pack_spec(PARAMS)
    L = 3
    stacked = jax.tree.map(
        lambda x: jnp.asarray(
            RNG.randn(L, *x.shape), jnp.float32
        ),
        PARAMS,
    )
    buf = spec.pack_stacked(stacked)
    assert buf.shape == (L, spec.rows, 128)
    restored = spec.unpack_stacked(buf)
    _bitwise(stacked, restored)
    # the L-axis is positional: plane j is exactly pack(tree slice j)
    one = spec.pack(jax.tree.map(lambda x: x[1], stacked))
    np.testing.assert_array_equal(np.asarray(buf[1]), np.asarray(one))


def test_pk1_dtype_cast_roundtrip():
    """bf16 leaves survive an f32 buffer bit-exactly (cast up then down)."""
    tree = {"a": jnp.asarray(RNG.randn(33), jnp.bfloat16),
            "b": jnp.asarray(RNG.randn(5, 7), jnp.float32)}
    spec = make_pack_spec(tree)
    assert spec.dtype == "float32"  # result type of bf16 + f32
    restored = spec.unpack(spec.pack(tree))
    assert restored["a"].dtype == jnp.bfloat16
    assert restored["b"].dtype == jnp.float32
    _bitwise(tree, restored)


# ---------------------------------------------------------------------------
# PK2: layout invariants + static-field contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3-405b", "hymba-1.5b", "xlstm-350m"])
def test_pk2_layout_invariants(arch):
    from repro.launch.specs import abstract_params

    spec = make_pack_spec(abstract_params(get_config(arch)))
    end = 0
    for off, size in zip(spec.offsets, spec.sizes):
        assert off % 128 == 0, "leaf starts off a lane boundary"
        assert off >= end, "overlapping leaf slots"
        end = off + size
    assert end <= spec.total and spec.rows % 8 == 0
    # lane alignment bounds the gap waste at < 128 per leaf + tail tile
    assert spec.pad_waste < 128 * spec.num_leaves + 8 * 128
    # and never exceeds the legacy per-leaf 8x128 tile padding
    assert spec.pad_waste <= spec.per_leaf_pad_waste() + 8 * 128


def test_pk2_spec_static_contract():
    s1 = make_pack_spec(PARAMS)
    s2 = make_pack_spec(jax.tree.map(jnp.zeros_like, PARAMS))
    assert s1 == s2 and hash(s1) == hash(s2)  # value identity, not object
    # jit caches on the static spec: same-structure states share a trace
    state = init_state(PARAMS, MAvgConfig(num_learners=2, k_steps=1))
    assert state.spec == s1
    assert "spec" not in [  # static field contributes no leaves
        str(p) for p, _ in jax.tree_util.tree_flatten_with_path(state)[0]
    ]


# ---------------------------------------------------------------------------
# PK3: packed vs per-leaf meta-step parity
# ---------------------------------------------------------------------------

TOPOLOGIES = [
    TopologyConfig(),
    TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                   outer_momentum=0.3),
    TopologyConfig(kind="gossip", graph="ring", momentum_tracking=True),
]


@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_pk3_dense_parity_bitwise(topo):
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     learner_lr=0.1, momentum=0.6, topology=topo)
    s_packed, m_p = _run(cfg)
    s_leaf, m_l = _run(dc.replace(cfg, packed=False))
    spec = s_packed.spec
    # identical algebra on a different layout: repacking the per-leaf
    # planes reproduces the packed planes bit for bit
    _bitwise(s_packed.global_params, spec.pack(s_leaf.global_params))
    _bitwise(s_packed.momentum, spec.pack(s_leaf.momentum))
    _bitwise(s_packed.learners,
             spec.pack_stacked(s_leaf.learners, dtype=cfg.compute_dtype))
    np.testing.assert_allclose(float(m_p["loss"]), float(m_l["loss"]),
                               rtol=1e-6)


@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_pk3_int8_ef_parity(topo):
    """Quantized cells: the packed wire chunks the packed layout, the
    per-leaf wire chunks each leaf — same scheme, different chunk
    boundaries and dither draws, so parity is to quantization noise
    (bounded well below the displacement scale), not bitwise."""
    inner = CommConfig(scheme="int8", error_feedback=True)
    if topo.kind == "flat":
        cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                         learner_lr=0.1, momentum=0.6, comm=inner,
                         topology=topo)
    else:
        cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                         learner_lr=0.1, momentum=0.6,
                         topology=dc.replace(topo, inner_comm=inner))
    s_packed, m_p = _run(cfg, n_steps=4)
    s_leaf, m_l = _run(dc.replace(cfg, packed=False), n_steps=4)
    gp_p = unpack_params(s_packed)
    gp_l = unpack_params(s_leaf)
    scale = float(tree_norm(gp_l))
    diff = float(tree_norm(tree_sub(gp_p, gp_l)))
    assert diff / scale < 5e-3, (diff, scale)
    np.testing.assert_allclose(float(m_p["loss"]), float(m_l["loss"]),
                               rtol=2e-2)
    for leaf in jax.tree.leaves(gp_p):
        assert jnp.isfinite(leaf).all()


@pytest.mark.parametrize("scheme", ["topk", "int8_topk"])
def test_pk3_topk_parity(scheme):
    """Packed top-k selects over the whole model vector where the
    per-leaf path budgeted each leaf separately (comm/topk.py) — a
    deliberate semantic shift pinned here at the trajectory level: same
    convergence, bounded displacement-scale divergence, and the EF
    residual keeps the skipped mass."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     learner_lr=0.1, momentum=0.6,
                     comm=CommConfig(scheme=scheme, error_feedback=True))
    s_packed, m_p = _run(cfg, n_steps=4)
    s_leaf, m_l = _run(dc.replace(cfg, packed=False), n_steps=4)
    gp_p, gp_l = unpack_params(s_packed), unpack_params(s_leaf)
    diff = float(tree_norm(tree_sub(gp_p, gp_l)))
    assert diff / float(tree_norm(gp_l)) < 5e-2
    np.testing.assert_allclose(float(m_p["loss"]), float(m_l["loss"]),
                               rtol=5e-2)
    assert float(jnp.abs(s_packed.comm_residual).sum()) > 0


def test_pk3_eamsgd_downpour_packed_match_per_leaf():
    """The non-averaging algorithms ride the packed planes through the
    same tree algebra — parity is bitwise there too."""
    for algo, extra in [("eamsgd", {}), ("downpour", {"staleness": 2})]:
        cfg = MAvgConfig(algorithm=algo, num_learners=3, k_steps=2,
                         learner_lr=0.1, momentum=0.5, **extra)
        s_packed, _ = _run(cfg)
        s_leaf, _ = _run(dc.replace(cfg, packed=False))
        _bitwise(s_packed.global_params,
                 s_packed.spec.pack(s_leaf.global_params))


def test_pk3_packed_pallas_matches_jnp():
    """use_pallas routes the packed planes through the fused kernels
    (one launch per op) — same trajectory as the jnp path."""
    base = dict(algorithm="mavg", num_learners=4, k_steps=2, momentum=0.6)
    comm = CommConfig(scheme="int8", error_feedback=True)
    s_jnp, _ = _run(MAvgConfig(**base, use_pallas=False, comm=comm))
    s_pl, _ = _run(MAvgConfig(
        **base, use_pallas=True, comm=dc.replace(comm, use_pallas=True)))
    np.testing.assert_allclose(
        np.asarray(s_jnp.global_params), np.asarray(s_pl.global_params),
        rtol=1e-4, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# PK4: fused pack_update kernel vs oracle + EF invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L,rows,block", [(2, 8, 8), (4, 64, 64),
                                          (3, 24, None)])
@pytest.mark.parametrize("with_residual", [True, False])
def test_pk4_pack_update_kernel_matches_ref(L, rows, block, with_residual):
    w = jnp.asarray(RNG.randn(L, rows, 128) * 0.02, jnp.float32)
    g = jnp.asarray(RNG.randn(rows, 128) * 0.02, jnp.float32)
    e = (jnp.asarray(RNG.randn(L, rows, 128) * 1e-3, jnp.float32)
         if with_residual else None)
    u = jnp.asarray(RNG.rand(L, rows, 128), jnp.float32)
    ck, errk, sk = ops.pack_update(w, g, e, u, qmax=127, block=block,
                                   use_pallas=True, interpret=True)
    cr, errr, sr = ops.pack_update(w, g, e, u, qmax=127, block=block,
                                   use_pallas=False)
    # shared dither: rounding decisions identical, scales to 1 ulp
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr),
                               rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(np.asarray(errk), np.asarray(errr),
                               rtol=1e-5, atol=1e-8)
    # EF invariant holds exactly on both routes: delta (+e) = c + err
    d = np.asarray(w - g[None]) + (np.asarray(e) if e is not None else 0)
    np.testing.assert_allclose(np.asarray(ck + errk), d, atol=1e-7)
    np.testing.assert_allclose(np.asarray(cr + errr), d, atol=1e-7)


def test_pk4_fused_reduce_matches_compress_stack_geometry():
    """The fused QuantReducer.reduce and the compress-only path (gossip /
    masked hierarchical) share chunk geometry and dither, so the same
    delta quantizes identically through either route — the invariant
    behind the all-present == static bitwise tests."""
    from repro.comm import ErrorFeedback, QuantReducer
    from repro.topology.gossip import compress_stack

    red = ErrorFeedback(QuantReducer(dtype="int8"))
    L, rows = 4, 16
    learners = jnp.asarray(RNG.randn(L, rows, 128) * 0.1, jnp.float32)
    gp = jnp.asarray(RNG.randn(rows, 128) * 0.1, jnp.float32)
    res = jnp.asarray(RNG.randn(L, rows, 128) * 1e-3, jnp.float32)
    step = jnp.int32(5)
    avg, new_res, m = red.reduce(learners, gp, res, step=step)
    delta = learners - gp[None] + res
    c2, res2, wire = compress_stack(red, learners - gp[None], res,
                                    step=step, learners=learners)
    np.testing.assert_allclose(np.asarray(new_res), np.asarray(res2),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(
        np.asarray(avg), np.asarray(gp + jnp.mean(c2, 0)),
        rtol=1e-6, atol=1e-8,
    )
    assert m["comm_bytes"] == wire


# ---------------------------------------------------------------------------
# PK5: padding slots stay zero through training
# ---------------------------------------------------------------------------


def _pad_mask(spec):
    mask = np.ones((spec.total,), bool)
    for off, size in zip(spec.offsets, spec.sizes):
        mask[off:off + size] = False
    return mask.reshape(spec.rows, 128)


@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_pk5_padding_stays_zero(topo):
    inner = CommConfig(scheme="int8", error_feedback=True)
    cfg = MAvgConfig(
        algorithm="mavg", num_learners=4, k_steps=2, momentum=0.6,
        comm=inner if topo.kind == "flat" else CommConfig(),
        topology=(topo if topo.kind == "flat"
                  else dc.replace(topo, inner_comm=inner)),
    )
    state, _ = _run(cfg, n_steps=4)
    mask = _pad_mask(state.spec)
    if not mask.any():
        pytest.skip("layout has no padding to check")
    for name, plane in [("global_params", state.global_params),
                        ("momentum", state.momentum),
                        ("learners", state.learners)]:
        arr = np.asarray(plane, np.float32)
        assert np.all(arr[..., mask] == 0.0), name
    for k, v in (state.topo or {}).items():
        if v is not None and np.asarray(v).ndim >= 2 \
                and np.asarray(v).shape[-2:] == mask.shape:
            assert np.all(np.asarray(v, np.float32)[..., mask] == 0.0), k


# ---------------------------------------------------------------------------
# PK6: checkpoint — legacy per-leaf load + packed sidecar
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", [
    TopologyConfig(),
    TopologyConfig(kind="hierarchical", groups=2, outer_every=2),
    TopologyConfig(kind="gossip", graph="exponential",
                   inner_comm=CommConfig(scheme="int8",
                                         error_feedback=True)),
])
def test_pk6_legacy_checkpoint_loads_into_packed(tmp_path, topo):
    cfg = MAvgConfig(
        algorithm="mavg", num_learners=4, k_steps=2, momentum=0.6,
        comm=(CommConfig(scheme="int8", error_feedback=True)
              if topo.kind == "flat" else CommConfig()),
        topology=topo,
    )
    legacy = dc.replace(cfg, packed=False)
    s_leaf, _ = _run(legacy)
    path = save_state(str(tmp_path), s_leaf, 3)
    assert load_packspec(path) is None  # per-leaf save: no sidecar

    template = jax.eval_shape(lambda: init_state(PARAMS, cfg))
    restored = load_state(path, template)
    spec = restored.spec
    _bitwise(restored.global_params, spec.pack(s_leaf.global_params))
    _bitwise(restored.learners,
             spec.pack_stacked(s_leaf.learners, dtype=cfg.compute_dtype))
    assert int(restored.step) == 3

    # packed re-save round-trips bit-exactly and carries the decode map
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    live, _ = step(restored, _batches(3, 4, 2))
    p2 = save_state(str(tmp_path), live, 4)
    side = load_packspec(p2)
    assert side is not None and side["rows"] == spec.rows
    assert side["paths"] == list(spec.paths)
    r2 = load_state(p2, jax.eval_shape(lambda: live))
    _bitwise(live, r2)


@pytest.mark.parametrize("scheme", ["int8", "fp8", "topk", "int8_topk"])
def test_pk3_wire_bytes_exclude_padding(scheme):
    """Padding slots must not count as wire payload: the packed path's
    comm_bytes stay comparable to the per-leaf accounting for the same
    scheme (meta_step rescales by the real-parameter fraction)."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=4, k_steps=2,
                     learner_lr=0.1, momentum=0.6,
                     comm=CommConfig(scheme=scheme, error_feedback=False))
    _, m_p = _run(cfg, n_steps=1)
    _, m_l = _run(dc.replace(cfg, packed=False), n_steps=1)
    for key in ("comm_bytes", "comm_bytes_dense"):
        ratio = float(m_p[key]) / float(m_l[key])
        assert 0.9 < ratio < 1.1, (scheme, key, ratio, m_p[key], m_l[key])


def test_pk6_layout_mismatch_rejected_by_sidecar(tmp_path):
    """A packed checkpoint whose leaf layout differs from the template's
    can still match every plane's (rows, 128) shape (rows quantizes to
    8x128 tiles) — the __packspec__ sidecar must catch it instead of
    restoring weights at wrong offsets."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2)
    s, _ = _run(cfg)
    path = save_state(str(tmp_path), s, 1)
    # same total parameter count, different leaf split -> same rows
    flat = {"w": jnp.zeros((sum(s.spec.sizes),), jnp.float32)}
    other = jax.eval_shape(lambda: init_state(flat, cfg))
    assert other.spec.rows == s.spec.rows  # the shape check alone passes
    with pytest.raises(ValueError, match="layout"):
        load_state(path, other)


def test_pk6_packed_checkpoint_rejected_by_mismatched_template(tmp_path):
    """A packed checkpoint must not silently load into a template of a
    different layout (learner count changes the stacked planes)."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2)
    s, _ = _run(cfg)
    path = save_state(str(tmp_path), s, 1)
    bad = jax.eval_shape(
        lambda: init_state(PARAMS, dc.replace(cfg, num_learners=4))
    )
    with pytest.raises(ValueError, match="shape"):
        load_state(path, bad)
