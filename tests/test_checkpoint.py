"""Checkpoint round-trip: resumed training is bit-identical."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_state, save_state
from repro.checkpoint.npz import latest_checkpoint
from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.models.simple import mlp_init, mlp_loss


def _batches(seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (2, 2, 4, 8)),
        "y": jax.random.randint(ky, (2, 2, 4), 0, 4),
    }


def test_roundtrip_bit_identical(tmp_path):
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.6)
    params = mlp_init(jax.random.PRNGKey(0), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))

    state = init_state(params, cfg)
    for i in range(3):
        state, _ = step(state, _batches(i))
    path = save_state(str(tmp_path), state, 3)
    assert latest_checkpoint(str(tmp_path)) == path

    # continue 2 more steps from live state
    live = state
    for i in range(3, 5):
        live, _ = step(live, _batches(i))

    # restore and continue identically
    restored = load_state(path, jax.eval_shape(lambda: state))
    assert int(restored.step) == 3
    for i in range(3, 5):
        restored, _ = step(restored, _batches(i))

    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_momentum_saved(tmp_path):
    """The block-momentum buffer v must survive the round trip (a resumed
    M-AVG run with v=0 would silently change the optimizer trajectory)."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=1,
                     learner_lr=0.2, momentum=0.9)
    params = mlp_init(jax.random.PRNGKey(1), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(params, cfg)
    state, _ = step(state, _batches(0))
    v_norm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(state.momentum))
    assert v_norm > 0
    path = save_state(str(tmp_path), state, 1)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state.momentum),
                    jax.tree.leaves(restored.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_comm_residual_roundtrip(tmp_path):
    """Extended MetaState: a non-None error-feedback comm_residual
    round-trips bit-identically, and a resumed int8+EF run stays on the
    live trajectory — losing e_j would silently re-bias the compressed
    averaging."""
    from repro.configs.base import CommConfig

    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.6,
                     comm=CommConfig(scheme="int8", error_feedback=True))
    params = mlp_init(jax.random.PRNGKey(2), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(params, cfg)
    for i in range(3):
        state, _ = step(state, _batches(i))
    assert state.comm_residual is not None
    res_norm = sum(float(jnp.sum(jnp.abs(x)))
                   for x in jax.tree.leaves(state.comm_residual))
    assert res_norm > 0  # EF actually accumulated something

    path = save_state(str(tmp_path), state, 3)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # resume and check bit-identical continuation
    live, resumed = state, restored
    for i in range(3, 5):
        live, _ = step(live, _batches(i))
        resumed, _ = step(resumed, _batches(i))
    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_topo_roundtrip(tmp_path):
    """The async server's clock/stamp/anchor buffers (MetaState.topo) are
    the successor of the retired downpour stale_queue: a run halted
    mid-staleness-window and resumed must continue bit-identically — a
    clock or anchor reset would silently change which learners fire and
    what displacement they push."""
    from repro.configs.base import AsyncConfig, TopologyConfig

    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.6,
                     topology=TopologyConfig(
                         kind="async",
                         server=AsyncConfig(staleness=2, step_time=(1, 3)),
                     ))
    params = mlp_init(jax.random.PRNGKey(2), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(params, cfg)
    # halt mid-window: step 2 is inside learner 1's 3-tick block
    for i in range(2):
        state, _ = step(state, _batches(i))
    assert int(np.asarray(state.topo["clock"]).max()) > 0  # mid-block
    path = save_state(str(tmp_path), state, 2)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    live, resumed = state, restored
    for i in range(2, 6):
        live, _ = step(live, _batches(i))
        resumed, _ = step(resumed, _batches(i))
    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
