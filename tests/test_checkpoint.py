"""Checkpoint round-trip: resumed training is bit-identical.

Plus the verified chain (DESIGN.md §13): atomic writes + CRC32 sidecars
mean a torn or corrupted snapshot is *detected* and skipped, never
restored — ``latest_verified_checkpoint`` always falls back to the
newest intact snapshot bit-exactly.
"""
import json
import os

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    CheckpointVerifyError,
    checkpoint_step,
    latest_verified_checkpoint,
    load_state,
    prune_checkpoints,
    save_state,
    verified_checkpoints,
    verify_checkpoint,
)
from repro.checkpoint.npz import CRC_SUFFIX, latest_checkpoint
from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.models.simple import mlp_init, mlp_loss


def _batches(seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (2, 2, 4, 8)),
        "y": jax.random.randint(ky, (2, 2, 4), 0, 4),
    }


def test_roundtrip_bit_identical(tmp_path):
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.6)
    params = mlp_init(jax.random.PRNGKey(0), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))

    state = init_state(params, cfg)
    for i in range(3):
        state, _ = step(state, _batches(i))
    path = save_state(str(tmp_path), state, 3)
    assert latest_checkpoint(str(tmp_path)) == path

    # continue 2 more steps from live state
    live = state
    for i in range(3, 5):
        live, _ = step(live, _batches(i))

    # restore and continue identically
    restored = load_state(path, jax.eval_shape(lambda: state))
    assert int(restored.step) == 3
    for i in range(3, 5):
        restored, _ = step(restored, _batches(i))

    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_momentum_saved(tmp_path):
    """The block-momentum buffer v must survive the round trip (a resumed
    M-AVG run with v=0 would silently change the optimizer trajectory)."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=1,
                     learner_lr=0.2, momentum=0.9)
    params = mlp_init(jax.random.PRNGKey(1), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(params, cfg)
    state, _ = step(state, _batches(0))
    v_norm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(state.momentum))
    assert v_norm > 0
    path = save_state(str(tmp_path), state, 1)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state.momentum),
                    jax.tree.leaves(restored.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_comm_residual_roundtrip(tmp_path):
    """Extended MetaState: a non-None error-feedback comm_residual
    round-trips bit-identically, and a resumed int8+EF run stays on the
    live trajectory — losing e_j would silently re-bias the compressed
    averaging."""
    from repro.configs.base import CommConfig

    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.6,
                     comm=CommConfig(scheme="int8", error_feedback=True))
    params = mlp_init(jax.random.PRNGKey(2), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(params, cfg)
    for i in range(3):
        state, _ = step(state, _batches(i))
    assert state.comm_residual is not None
    res_norm = sum(float(jnp.sum(jnp.abs(x)))
                   for x in jax.tree.leaves(state.comm_residual))
    assert res_norm > 0  # EF actually accumulated something

    path = save_state(str(tmp_path), state, 3)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # resume and check bit-identical continuation
    live, resumed = state, restored
    for i in range(3, 5):
        live, _ = step(live, _batches(i))
        resumed, _ = step(resumed, _batches(i))
    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_topo_roundtrip(tmp_path):
    """The async server's clock/stamp/anchor buffers (MetaState.topo) are
    the successor of the retired downpour stale_queue: a run halted
    mid-staleness-window and resumed must continue bit-identically — a
    clock or anchor reset would silently change which learners fire and
    what displacement they push."""
    from repro.configs.base import AsyncConfig, TopologyConfig

    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.6,
                     topology=TopologyConfig(
                         kind="async",
                         server=AsyncConfig(staleness=2, step_time=(1, 3)),
                     ))
    params = mlp_init(jax.random.PRNGKey(2), 8, 16, 4)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    state = init_state(params, cfg)
    # halt mid-window: step 2 is inside learner 1's 3-tick block
    for i in range(2):
        state, _ = step(state, _batches(i))
    assert int(np.asarray(state.topo["clock"]).max()) > 0  # mid-block
    path = save_state(str(tmp_path), state, 2)
    restored = load_state(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    live, resumed = state, restored
    for i in range(2, 6):
        live, _ = step(live, _batches(i))
        resumed, _ = step(resumed, _batches(i))
    for a, b in zip(jax.tree.leaves(live), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# verified chain: atomic saves, CRC sidecars, torn/corrupt detection
# ---------------------------------------------------------------------------


def _small_state(seed=0):
    cfg = MAvgConfig(algorithm="mavg", num_learners=2, k_steps=2,
                     learner_lr=0.1, momentum=0.6)
    return init_state(mlp_init(jax.random.PRNGKey(seed), 8, 16, 4), cfg)


def test_kill_mid_save_falls_back_bit_exact(tmp_path):
    """A save that dies mid-write (simulated via ``fault='torn'``: half
    the npz bytes at the final path, no sidecar) must not poison resume:
    the newest torn snapshot is skipped and the previous verified one
    restores bit-exactly."""
    state = _small_state()
    good = save_state(str(tmp_path), state, 1)
    torn = save_state(str(tmp_path), state, 2, fault="torn")
    # the unverified scan would pick the torn head; the verified one skips
    assert latest_checkpoint(str(tmp_path)) == torn
    assert latest_verified_checkpoint(str(tmp_path)) == good
    with pytest.raises(CheckpointVerifyError, match="sidecar"):
        verify_checkpoint(torn)
    restored = load_state(good, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_save_caught_by_crc(tmp_path):
    """Post-write corruption (``fault='corrupt'`` flips one byte after
    the full atomic save landed) passes the size check but fails the
    per-entry CRC32 — bit rot is detected, not restored."""
    state = _small_state()
    good = save_state(str(tmp_path), state, 1)
    bad = save_state(str(tmp_path), state, 2, fault="corrupt")
    with pytest.raises(CheckpointVerifyError):
        verify_checkpoint(bad)
    assert latest_verified_checkpoint(str(tmp_path)) == good


def test_truncated_npz_detected(tmp_path):
    """A complete save later truncated on disk (filesystem-level tear)
    fails the sidecar's byte-size check."""
    state = _small_state()
    path = save_state(str(tmp_path), state, 1)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(CheckpointVerifyError, match="torn write"):
        verify_checkpoint(path)
    assert latest_verified_checkpoint(str(tmp_path)) is None


def test_entry_set_mismatch_detected(tmp_path):
    """A sidecar that disagrees with the npz's entry set (e.g. a sidecar
    from a different config pasted next to the snapshot) is rejected."""
    path = save_state(str(tmp_path), {"a": np.arange(4.0),
                                      "b": np.ones((2, 2))}, 1)
    with open(path + CRC_SUFFIX) as f:
        sidecar = json.load(f)
    del sidecar["entries"]["['b']" if "['b']" in sidecar["entries"]
                           else list(sidecar["entries"])[-1]]
    with open(path + CRC_SUFFIX, "w") as f:
        json.dump(sidecar, f)
    with pytest.raises(CheckpointVerifyError, match="entry set mismatch"):
        verify_checkpoint(path)


def test_nonfinite_snapshot_not_a_rollback_target(tmp_path):
    """``check_finite`` (the default) refuses a snapshot of a poisoned
    state — NaN never re-enters MetaState via resume."""
    save_state(str(tmp_path), {"a": np.array([1.0, np.nan])}, 1)
    assert latest_verified_checkpoint(str(tmp_path)) is None
    # integrity-only verification still accepts it (forensics use)
    assert latest_verified_checkpoint(
        str(tmp_path), check_finite=False
    ) is not None


def test_torn_sidecar_tolerated(tmp_path):
    """A sidecar torn mid-write (invalid JSON) marks the snapshot
    unverified instead of crashing the rollback scan."""
    state = _small_state()
    good = save_state(str(tmp_path), state, 1)
    newer = save_state(str(tmp_path), state, 2)
    with open(newer + CRC_SUFFIX, "w") as f:
        f.write('{"npz_bytes": 12')  # truncated JSON
    with pytest.raises(CheckpointVerifyError, match="torn sidecar"):
        verify_checkpoint(newer)
    assert latest_verified_checkpoint(str(tmp_path)) == good


def test_retention_keeps_last_n_verified(tmp_path):
    """``keep=N`` prunes everything older than the N newest verified
    snapshots — torn leftovers older than the cutoff go too, and the
    survivors are exactly the rollback chain."""
    state = _small_state()
    save_state(str(tmp_path), state, 1)
    save_state(str(tmp_path), state, 2, fault="torn")
    for s in (3, 4, 5):
        save_state(str(tmp_path), state, s, keep=2)
    snaps = sorted(f for f in os.listdir(str(tmp_path))
                   if f.endswith(".npz"))
    assert snaps == ["step_00000004.npz", "step_00000005.npz"]
    assert all(os.path.exists(os.path.join(str(tmp_path), f + CRC_SUFFIX))
               for f in snaps)


def test_verified_chain_before_step(tmp_path):
    """``verified_checkpoints(before_step=s)`` is the Supervisor's causal
    filter: snapshots at or after the fault step (e.g. the emergency halt
    snapshot, which can verify finite yet carry a diverged state) are
    never rollback targets."""
    state = _small_state()
    p2 = save_state(str(tmp_path), state, 2)
    p4 = save_state(str(tmp_path), state, 4)
    p5 = save_state(str(tmp_path), state, 5)  # "emergency halt" snapshot
    assert [checkpoint_step(p) for p in (p2, p4, p5)] == [2, 4, 5]
    assert verified_checkpoints(str(tmp_path)) == [p2, p4, p5]
    assert verified_checkpoints(str(tmp_path), before_step=5) == [p2, p4]
    assert verified_checkpoints(str(tmp_path), before_step=2) == []


def test_prune_requires_positive_keep(tmp_path):
    with pytest.raises(AssertionError):
        prune_checkpoints(str(tmp_path), 0)


def test_prune_deletes_sidecar_with_snapshot(tmp_path):
    """A pruned snapshot takes its CRC sidecar with it — retention must
    not strand ``.crc32.json`` files nothing will ever list again."""
    state = _small_state()
    for s in (1, 2, 3, 4):
        save_state(str(tmp_path), state, s)
    removed = prune_checkpoints(str(tmp_path), 2)
    assert [os.path.basename(p) for p in removed] == [
        "step_00000001.npz", "step_00000002.npz"]
    left = sorted(os.listdir(str(tmp_path)))
    assert not any(f.startswith("step_0000000" + str(s))
                   for s in (1, 2) for f in left)
    for s in (3, 4):
        assert f"step_0000000{s}.npz" in left
        assert f"step_0000000{s}.npz" + CRC_SUFFIX in left


def test_prune_sweeps_orphaned_sidecars(tmp_path):
    """A sidecar whose snapshot is gone (interrupted delete under the old
    npz-first order, external cleanup) is swept by the next prune."""
    state = _small_state()
    for s in (1, 2):
        save_state(str(tmp_path), state, s)
    orphan = os.path.join(str(tmp_path), "step_00000099.npz" + CRC_SUFFIX)
    with open(orphan, "w") as f:
        f.write("{}")
    assert prune_checkpoints(str(tmp_path), 2) == []  # nothing to prune...
    assert not os.path.exists(orphan)                 # ...orphan swept anyway
    for s in (1, 2):  # the live chain is untouched
        assert os.path.exists(
            os.path.join(str(tmp_path), f"step_0000000{s}.npz" + CRC_SUFFIX))


def test_prune_interrupted_delete_sidecar_first_and_converges(
        tmp_path, monkeypatch):
    """Removal order is sidecar FIRST: an unlink interrupted between the
    two deletes leaves a sidecar-less npz — a torn-save lookalike the
    rollback scan skips and the next prune sweeps — never an orphaned
    sidecar."""
    import repro.checkpoint.npz as npz_mod

    state = _small_state()
    for s in (1, 2, 3):
        save_state(str(tmp_path), state, s)
    p3 = os.path.join(str(tmp_path), "step_00000003.npz")

    calls = []
    real_remove = os.remove

    def interrupted_remove(p):
        calls.append(os.path.basename(p))
        if p.endswith(".npz"):
            raise OSError("interrupted mid-prune")
        return real_remove(p)

    monkeypatch.setattr(npz_mod.os, "remove", interrupted_remove)
    assert prune_checkpoints(str(tmp_path), 2) == []  # unlink failed
    monkeypatch.setattr(npz_mod.os, "remove", real_remove)

    # the sidecar went first, then the npz unlink was interrupted
    assert calls == ["step_00000001.npz" + CRC_SUFFIX, "step_00000001.npz"]
    leftover = os.path.join(str(tmp_path), "step_00000001.npz")
    assert os.path.exists(leftover)
    assert not os.path.exists(leftover + CRC_SUFFIX)
    # the torn-save lookalike is invisible to rollback ...
    assert latest_verified_checkpoint(str(tmp_path)) == p3
    # ... and once the chain advances, the next prune sweeps it along
    # with the then-stale step 2
    save_state(str(tmp_path), state, 4)
    removed = prune_checkpoints(str(tmp_path), 2)
    assert [os.path.basename(p) for p in removed] == [
        "step_00000001.npz", "step_00000002.npz"]
    assert not os.path.exists(leftover)
