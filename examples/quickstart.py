"""Quickstart: the paper's algorithm in ~40 lines.

Trains a small MLP on a synthetic teacher-classification stream with
M-AVG (Algorithm 1) and its K-AVG baseline, printing loss-per-samples
curves that show the block-momentum acceleration.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.data import classif_batch_fn, classif_eval_set
from repro.models.simple import mlp_accuracy, mlp_init, mlp_loss
from repro.pack import unpack_params

P, K, B, D, C = 4, 4, 16, 32, 10  # learners, local steps, batch, dims


def train(algorithm: str, momentum: float, steps: int = 60):
    cfg = MAvgConfig(algorithm=algorithm, num_learners=P, k_steps=K,
                     learner_lr=0.2, momentum=momentum)
    params = mlp_init(jax.random.PRNGKey(0), D, 64, C)
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    batch_fn = classif_batch_fn(D, C, P, K, B)

    losses = []
    for i in range(steps):
        batches = batch_fn(jax.random.fold_in(jax.random.PRNGKey(1), i), i)
        state, metrics = step(state, batches)
        losses.append(float(metrics["loss"]))
        if i % 10 == 0:
            samples = (i + 1) * P * K * B
            print(f"  {algorithm:5s} samples={samples:6d} "
                  f"loss={losses[-1]:.4f}")
    acc = float(mlp_accuracy(unpack_params(state), classif_eval_set(D, C)))
    return losses, acc


if __name__ == "__main__":
    print("K-AVG (the baseline: mu = 0)")
    k_losses, k_acc = train("kavg", 0.0)
    print("M-AVG (the paper: block momentum mu = 0.7)")
    m_losses, m_acc = train("mavg", 0.7)
    print(f"\nfinal: K-AVG loss={k_losses[-1]:.4f} acc={k_acc:.3f} | "
          f"M-AVG loss={m_losses[-1]:.4f} acc={m_acc:.3f}")
    print("M-AVG reaches the same loss with "
          f"~{sum(l > k_losses[-1] for l in m_losses) / len(m_losses):.0%}"
          " of the samples.")
