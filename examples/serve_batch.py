"""Batched serving example: prefill a prompt batch, then decode with the
KV/state cache — runs every decode-capable assigned architecture at
reduced scale.

  PYTHONPATH=src python examples/serve_batch.py --arch hymba-1.5b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import api as model_api


def serve(arch: str, batch: int, prompt_len: int, new_tokens: int):
    cfg = get_config(arch).reduced()
    if not cfg.supports_decode:
        print(f"{arch}: encoder-only, no decode (skipped)")
        return
    if cfg.input_mode != "tokens":
        print(f"{arch}: stub-frontend input; decode-only demo")
    params = model_api.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 0, cfg.vocab_size,
                                jnp.int32)
    cache_len = prompt_len + new_tokens + 8

    if cfg.input_mode == "tokens":
        prefill = jax.jit(lambda p, b: model_api.prefill(p, cfg, b, cache_len))
        logits, cache = prefill(params, {"tokens": prompt})
    else:  # vlm: decode from an empty cache for the demo
        cache = model_api.init_cache(cfg, batch, cache_len)
        logits = jnp.zeros((batch, cfg.vocab_size))

    decode = jax.jit(lambda p, c, t: model_api.decode_step(p, cfg, c, t))
    toks, t0 = [], time.time()
    for _ in range(new_tokens):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(nxt)
        logits, cache = decode(params, cache, nxt)
    dt = time.time() - t0
    print(f"{arch}: {batch} seqs x {new_tokens} tokens in {dt:.2f}s "
          f"({batch * new_tokens / dt:.1f} tok/s), cache pos "
          f"{int(cache['pos'])}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="default: every decode-capable arch")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCH_IDS
    for a in archs:
        serve(a, args.batch, args.prompt_len, args.tokens)
