"""The paper's tuning guidelines as a runnable study (Lemmas 6 & 7):

1. more processors -> use a larger momentum mu
2. switching K-AVG -> M-AVG -> use a smaller K

  PYTHONPATH=src python examples/momentum_tuning.py
"""
import sys

sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None

from benchmarks.common import run_mlp  # noqa: E402


def guideline_1():
    print("Guideline 1 (Lemma 6): optimal mu grows with P")
    for P in (2, 8):
        accs = {}
        for mu in (0.0, 0.5, 0.9):
            _, acc = run_mlp("mavg", P=P, K=4, mu=mu, steps=60, batch=8)
            accs[mu] = acc
            print(f"  P={P} mu={mu}: val_acc={acc:.3f}")
        print(f"  -> best mu at P={P}: {max(accs, key=accs.get)}")


def guideline_2():
    print("Guideline 2 (Lemma 7): momentum prefers smaller K (S = N*K fixed)")
    for mu in (0.0, 0.7):
        accs = {}
        for K in (2, 8):
            _, acc = run_mlp("mavg", P=4, K=K, mu=mu, steps=128 // K, batch=8)
            accs[K] = acc
            print(f"  mu={mu} K={K}: val_acc={acc:.3f}")
        print(f"  -> best K at mu={mu}: {max(accs, key=accs.get)}")


if __name__ == "__main__":
    guideline_1()
    guideline_2()
