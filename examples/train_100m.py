"""End-to-end driver: train a ~100M-parameter dense transformer with
M-AVG for a few hundred meta-steps on the bigram-teacher LM stream.

This is the deliverable-(b) end-to-end example. On CPU a full 300-step
run takes hours; the default below runs 300 steps at a reduced width so
the driver completes on CPU, and ``--width full`` selects the true ~100M
configuration (the program is identical — same code path the TPU pod
runs under the production mesh via repro.launch.train).

  PYTHONPATH=src python examples/train_100m.py --steps 300
  PYTHONPATH=src python examples/train_100m.py --width full --steps 300
"""
import argparse
import dataclasses

import jax

from repro.configs.base import MAvgConfig, ModelConfig, TrainConfig
from repro.core.trainer import Trainer
from repro.data import lm_batch_fn, lm_eval_set
from repro.models import api as model_api
from repro.optim import warmup_cosine
from repro.pack import unpack_params


def make_config(width: str) -> ModelConfig:
    if width == "full":  # ~100M params
        return ModelConfig(
            name="dense-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
            rope_theta=10000.0,
        )
    return ModelConfig(  # CPU-friendly stand-in, same family/code path
        name="dense-8m", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=1024, vocab_size=4096,
        rope_theta=10000.0,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", default="small", choices=["small", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.25)
    ap.add_argument("--momentum", type=float, default=0.7)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = make_config(args.width)
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda k: model_api.init_params(k, cfg),
                           jax.random.PRNGKey(0))
        )
    )
    print(f"model: {cfg.name} ({n_params / 1e6:.1f}M params), "
          f"P={args.learners} K={args.k} B={args.batch} seq={args.seq}")

    mcfg = MAvgConfig(algorithm="mavg", num_learners=args.learners,
                      k_steps=args.k, learner_lr=args.lr,
                      momentum=args.momentum)
    tcfg = TrainConfig(model=cfg, mavg=mcfg,
                       batch_per_learner=args.batch, seq_len=args.seq,
                       meta_steps=args.steps, log_every=10,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=100 if args.checkpoint_dir else 0)

    trainer = Trainer(
        tcfg,
        lambda p, b: model_api.loss_fn(p, cfg, b),
        init_params_fn=lambda rng: model_api.init_params(rng, cfg),
        batch_fn=lm_batch_fn(cfg, args.learners, args.k, args.batch, args.seq),
        lr_schedule=warmup_cosine(args.lr, 20, args.steps),
    )
    history = trainer.run()
    ev = lm_eval_set(cfg, n=32, seq_len=args.seq)
    loss, _ = jax.jit(lambda p, b: model_api.loss_fn(p, cfg, b))(
        unpack_params(trainer.state), ev)
    print(f"\ndone: train loss {history[0]['loss']:.3f} -> "
          f"{history[-1]['loss']:.3f}; eval loss {float(loss):.3f}; "
          f"samples {history[-1]['samples']}")


if __name__ == "__main__":
    main()
