from repro.sharding.rules import (
    add_learner_axis,
    leaf_spec,
    make_param_specs,
    named,
)
