"""Sharding rules: parameter-name → PartitionSpec.

Rules are expressed as *negative-dim preference lists* so they apply
unchanged to layer-stacked parameters (scan adds leading axes which stay
unsharded). For each leaf we place the tensor-parallel (``model``) axis on
the first preferred dim whose size divides the axis; optionally an FSDP
axis (``data`` inside a learner, hierarchical mode / serving of the
largest configs) on a second dim.

Examples
--------
* ``wq (d_model, heads, head_dim)`` prefers heads (Megatron head-parallel);
  qwen2-7b's 28 heads don't divide a 16-way model axis, so it falls back to
  d_model (row-parallel with a psum, GSPMD inserts it).
* MoE ``w_in (E, d, 2, d_e)`` shards the expert dim — expert parallelism.
* xLSTM/mamba projections shard the inner dim.
"""
from __future__ import annotations

import logging

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger(__name__)

# name -> preference list of negative dims for the model (TP) axis.
# (A variant placing the second/fsdp axis on head_dim instead of d_model
# was tried and REFUTED — it doubled all-gather traffic on qwen1.5-110b
# prefill without touching the all-reduce term; see EXPERIMENTS.md.)
PREFS: dict[str, tuple[int, ...]] = {
    # attention (d, h, hd) / (h, hd, d)
    "wq": (-2, -3),
    "wk": (-2, -3),
    "wv": (-2, -3),
    "wo": (-3, -1),
    "bq": (-2,),
    "bk": (-2,),
    "bv": (-2,),
    # mlp
    "wi": (-1, -3),
    # embeddings
    "embedding": (-2,),
    "head": (-1,),
    # moe
    "router": (-1,),
    "w_in": (-4,),
    "w_out": (-3,),
    # xlstm / mamba inner projections
    "w_up": (-1, -3),
    "w_down": (-2,),
    "w_xz": (-1, -3),
    "w_ssm_out": (-2,),
    "conv": (-1,),
    "w_bc": (-2,),
    "w_dt_down": (-2,),
    "w_dt_up": (-1,),
    "A_log": (-2,),
    "D": (-1,),
    "b_dt": (-1,),
    "w_i": (-2,),
    "w_f": (-2,),
    # sLSTM per-head recurrent + gates
    "r_i": (-1,),
    "r_f": (-1,),
    "r_z": (-1,),
    "r_o": (-1,),
    "w_z": (-1,),
    "w_o": (-1,),
    "b_i": (-1,),
    "b_f": (-1,),
    "b_z": (-1,),
    "b_o": (-1,),
}

# shared-mlp 'wo' (f, d) wants (-2,); attention 'wo' (h, hd, d) wants (-3, -1).
# Disambiguated by rank in _prefs_for.
REPLICATED = {"scale", "beta_attn", "beta_ssm", "meta", "patch_pos"}


def _prefs_for(name: str, ndim_base: int) -> tuple[int, ...]:
    if name == "wo" and ndim_base == 2:  # mlp down-proj (f, d)
        return (-2,)
    if name in ("w_i", "w_f") and ndim_base == 3:  # sLSTM gate (d, nh, hd)
        return (-1,)
    if name in ("b_i", "b_f") and ndim_base == 1:  # mLSTM gate bias (nh,)
        return ()
    return PREFS.get(name, ())


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def leaf_spec(path, leaf, mesh: Mesh, *, model_axis="model", fsdp_axis=None,
              stack_dims: int = 0) -> P:
    """Compute the PartitionSpec for one parameter leaf.

    stack_dims: number of leading scan/stack dims (inferred by caller or 0);
    we simply never shard dims that a preference doesn't reach, so layer
    stacking needs no special handling (negative indexing).
    """
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    ndim = leaf.ndim
    spec = [None] * ndim
    if name in REPLICATED or ndim == 0:
        return P(*spec)
    used = set()
    prefs = _prefs_for(name, ndim - stack_dims)
    if model_axis is not None:
        msize = _axis_size(mesh, model_axis)
        for neg in prefs:
            dim = ndim + neg
            if 0 <= dim < ndim and leaf.shape[dim] % msize == 0 and leaf.shape[dim] >= msize:
                spec[dim] = model_axis
                used.add(dim)
                break
    if fsdp_axis is not None:
        fsize = _axis_size(mesh, fsdp_axis)
        # FSDP axis goes on the first remaining preferred dim, else the
        # largest remaining divisible dim (skipping stacked leading dims).
        candidates = [ndim + n for n in prefs if (ndim + n) not in used]
        rest = [
            d
            for d in range(stack_dims, ndim)
            if d not in used and d not in candidates
        ]
        rest.sort(key=lambda d: -leaf.shape[d])
        for dim in candidates + rest:
            if 0 <= dim < ndim and leaf.shape[dim] % fsize == 0 and leaf.shape[dim] >= fsize:
                spec[dim] = fsdp_axis
                break
    return P(*spec)


def make_param_specs(params, mesh: Mesh, *, model_axis="model", fsdp_axis=None,
                     stack_dims_fn=None):
    """Pytree of PartitionSpec matching ``params``."""

    def f(path, leaf):
        sd = stack_dims_fn(path) if stack_dims_fn else _default_stack_dims(path)
        return leaf_spec(
            path, leaf, mesh, model_axis=model_axis, fsdp_axis=fsdp_axis,
            stack_dims=sd,
        )

    return jax.tree_util.tree_map_with_path(f, params)


def _default_stack_dims(path) -> int:
    keys = [p.key if hasattr(p, "key") else str(p) for p in path]
    for k in keys:
        if k == "mlstm":
            return 2  # (groups, blocks-per-group, ...)
        if k in ("blocks", "dense_blocks", "slstm"):
            return 1
    return 0


def add_learner_axis(specs, learner_axes):
    """Prepend the learner mesh axis to every spec (stacked learner copies)."""
    return jax.tree.map(
        lambda s: P(learner_axes, *s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def named(specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
