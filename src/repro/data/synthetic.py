"""Deterministic synthetic data pipelines.

Two requirements drive the design:
1. Convergence experiments (the paper's claims) need *learnable* data so
   loss curves mean something: we use a fixed random bigram teacher for LM
   data and a fixed random teacher network for classification data.
2. Learners must see disjoint i.i.d. streams (Assumption 1's i.i.d. xi^j):
   every (learner, meta_step, local_step) triple gets an independent fold
   of the seed, so runs are reproducible across algorithms and P.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# bigram-teacher LM stream
# ---------------------------------------------------------------------------


def bigram_table(seed: int, vocab: int, concentration: float = 0.3):
    """Row-stochastic transition matrix with low entropy (learnable)."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (vocab, vocab)) / concentration
    return jax.nn.softmax(logits, axis=-1)


@partial(jax.jit, static_argnums=(2, 3))
def sample_lm(key, table, batch: int, seq_len: int):
    """Sample (batch, seq_len) token sequences from the bigram teacher."""
    k0, k1 = jax.random.split(key)
    vocab = table.shape[0]
    first = jax.random.randint(k0, (batch,), 0, vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, jnp.log(table[tok] + 1e-9))
        return nxt, nxt

    ks = jax.random.split(k1, seq_len - 1)
    _, rest = lax.scan(step, first, ks)
    toks = jnp.concatenate([first[None], rest], axis=0).T  # (B, S)
    return toks.astype(jnp.int32)


def lm_batch_fn(model_cfg: ModelConfig, num_learners: int, k_steps: int,
                batch: int, seq_len: int, table_seed: int = 1234):
    """Returns ``batch_fn(rng, step)`` producing (L, K, B, S) token batches."""
    table = bigram_table(table_seed, model_cfg.vocab_size)

    def batch_fn(rng, step):
        ks = jax.random.split(rng, num_learners * k_steps)
        toks = jnp.stack(
            [sample_lm(k, table, batch, seq_len) for k in ks]
        ).reshape(num_learners, k_steps, batch, seq_len)
        return {"tokens": toks, "labels": toks}

    return batch_fn


# ---------------------------------------------------------------------------
# teacher-network classification stream (the paper's CIFAR-10 stand-in)
# ---------------------------------------------------------------------------


def make_teacher(seed: int, d_in: int, classes: int, hidden: int = 64):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "w1": jax.random.normal(k1, (d_in, hidden)) / jnp.sqrt(d_in),
        "w2": jax.random.normal(k2, (hidden, classes)) / jnp.sqrt(hidden),
    }


@jax.jit
def _teacher_labels(teacher, x):
    h = jnp.tanh(x @ teacher["w1"])
    return jnp.argmax(h @ teacher["w2"], axis=-1).astype(jnp.int32)


def classif_batch_fn(d_in: int, classes: int, num_learners: int, k_steps: int,
                     batch: int, teacher_seed: int = 7, noise: float = 0.0):
    teacher = make_teacher(teacher_seed, d_in, classes)

    @partial(jax.jit, static_argnums=())
    def gen(rng):
        L, K, B = num_learners, k_steps, batch
        kx, kn = jax.random.split(rng)
        x = jax.random.normal(kx, (L, K, B, d_in))
        y = _teacher_labels(teacher, x.reshape(-1, d_in)).reshape(L, K, B)
        if noise:
            x = x + noise * jax.random.normal(kn, x.shape)
        return {"x": x, "y": y}

    def batch_fn(rng, step):
        return gen(rng)

    return batch_fn


# ---------------------------------------------------------------------------
# fixed evaluation sets (validation accuracy, as in the paper's Table I)
# ---------------------------------------------------------------------------


def classif_eval_set(d_in: int, classes: int, n: int = 2048, teacher_seed: int = 7,
                     seed: int = 99):
    teacher = make_teacher(teacher_seed, d_in, classes)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d_in))
    y = _teacher_labels(teacher, x)
    return {"x": x, "y": y}


def lm_eval_set(model_cfg: ModelConfig, n: int = 64, seq_len: int = 64,
                table_seed: int = 1234, seed: int = 98):
    table = bigram_table(table_seed, model_cfg.vocab_size)
    toks = sample_lm(jax.random.PRNGKey(seed), table, n, seq_len)
    return {"tokens": toks, "labels": toks}
