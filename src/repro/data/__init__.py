from repro.data.synthetic import (
    bigram_table,
    classif_batch_fn,
    classif_eval_set,
    lm_batch_fn,
    lm_eval_set,
    sample_lm,
)
