"""Bounded retry with exponential backoff for transient I/O errors.

The one retry helper in the repo (DESIGN.md §13): checkpoint writes
(checkpoint/npz.py) and run-log appends (obs.sink.JsonlSink) share it, so
a transient ``OSError`` — NFS hiccup, disk-pressure EAGAIN, a flaky
container overlay — costs a few milliseconds of backoff instead of a
dead run. It retries *transient* failure classes only and re-raises the
last error when the budget is exhausted: a genuinely broken path fails
loudly after ``attempts`` tries, never silently.

Backoff jitter is *seeded and deterministic* — many learners retrying a
shared filesystem in lock-step is exactly the thundering herd jitter
exists to break, but a run's retry schedule must still replay bit-for-bit
under the supervisor (every delay is a pure function of ``(seed, i)``,
never of wall clock or global RNG state).
"""
from __future__ import annotations

import random
import time
from typing import Callable, Tuple, Type


def backoff_schedule(
    attempts: int,
    *,
    base_delay: float = 0.05,
    factor: float = 2.0,
    jitter: float = 0.0,
    seed: int = 0,
) -> list:
    """The deterministic sleep schedule ``retry_io`` uses: one delay per
    failed attempt that still has retries left (``attempts - 1`` entries).

    Delay i is ``base_delay * factor**i * (1 + jitter * u_i)`` with
    ``u_i`` drawn uniformly from [0, 1) by a ``random.Random(seed)``
    private to this call — ``jitter=0`` (the default) reproduces the
    plain exponential schedule exactly, and equal ``(seed, jitter)``
    always yield equal schedules.
    """
    assert attempts >= 1, attempts
    assert jitter >= 0.0, jitter
    rng = random.Random(seed)
    return [
        base_delay * factor**i * (1.0 + jitter * rng.random())
        for i in range(attempts - 1)
    ]


def retry_io(
    fn: Callable,
    *,
    attempts: int = 4,
    base_delay: float = 0.05,
    factor: float = 2.0,
    jitter: float = 0.0,
    seed: int = 0,
    retry_on: Tuple[Type[BaseException], ...] = (OSError,),
    sleep: Callable[[float], None] = time.sleep,
):
    """Call ``fn()``; on ``retry_on`` retry up to ``attempts`` times total,
    sleeping per ``backoff_schedule`` between tries (seeded deterministic
    jitter on the exponential backoff; ``jitter=0`` is the plain
    schedule). Returns ``fn()``'s value; re-raises the final exception
    when every attempt failed.

    ``sleep`` is injectable so tests (and latency-sensitive callers) can
    observe / suppress the backoff schedule.
    """
    delays = backoff_schedule(
        attempts, base_delay=base_delay, factor=factor, jitter=jitter,
        seed=seed,
    )
    for i in range(attempts):
        try:
            return fn()
        except retry_on:
            if i == attempts - 1:
                raise
            sleep(delays[i])
