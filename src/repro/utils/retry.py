"""Bounded retry with exponential backoff for transient I/O errors.

The one retry helper in the repo (DESIGN.md §13): checkpoint writes
(checkpoint/npz.py) and run-log appends (obs.sink.JsonlSink) share it, so
a transient ``OSError`` — NFS hiccup, disk-pressure EAGAIN, a flaky
container overlay — costs a few milliseconds of backoff instead of a
dead run. It retries *transient* failure classes only and re-raises the
last error when the budget is exhausted: a genuinely broken path fails
loudly after ``attempts`` tries, never silently.
"""
from __future__ import annotations

import time
from typing import Callable, Tuple, Type


def retry_io(
    fn: Callable,
    *,
    attempts: int = 4,
    base_delay: float = 0.05,
    factor: float = 2.0,
    retry_on: Tuple[Type[BaseException], ...] = (OSError,),
    sleep: Callable[[float], None] = time.sleep,
):
    """Call ``fn()``; on ``retry_on`` retry up to ``attempts`` times total,
    sleeping ``base_delay * factor**i`` between tries. Returns ``fn()``'s
    value; re-raises the final exception when every attempt failed.

    ``sleep`` is injectable so tests (and latency-sensitive callers) can
    observe / suppress the backoff schedule.
    """
    assert attempts >= 1, attempts
    for i in range(attempts):
        try:
            return fn()
        except retry_on:
            if i == attempts - 1:
                raise
            sleep(base_delay * factor**i)
