"""Pytree arithmetic helpers used across the meta-optimizers.

All meta-level algebra in the paper (Algorithm 1) is pytree-wide:
``a = mean_j w_j``, ``d = a - w~``, ``v = mu v + d``, ``w~ += v``.
These helpers keep that code readable and jit-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.asarray(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_size(tree) -> int:
    """Total number of scalar parameters (static)."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_mean_axis0(tree):
    """Mean over the leading (learner) axis of every leaf.

    Under GSPMD with axis 0 sharded over the learner mesh axis this lowers
    to one all-reduce per fusion group -- the paper's meta-level averaging.
    """
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_broadcast_learners(tree, num_learners: int):
    """w_j <- w~ for every learner j: add a leading learner axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_learners,) + x.shape), tree
    )


def tree_slice_learner(tree, j: int):
    return jax.tree.map(lambda x: x[j], tree)
