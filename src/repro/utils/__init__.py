from repro.utils.retry import backoff_schedule, retry_io
from repro.utils.tree import (
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_sub,
    tree_axpy,
    tree_dot,
    tree_norm,
    tree_size,
    tree_cast,
    tree_mean_axis0,
    tree_broadcast_learners,
    tree_slice_learner,
)
