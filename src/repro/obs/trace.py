"""Phase tracing: config-gated span timers with Chrome-trace export.

The training loop has four host-visible phases worth timing — step
dispatch (local phase + meta mix enqueue), host flush (the one sync per
``log_every`` window), checkpoint I/O, and sink writes. ``Tracer.span``
wraps each in a wall-clock timer plus a ``jax.profiler.TraceAnnotation``
so the spans also show up inside a device profile when one is being
captured (``profiler_start``/``profiler_stop`` drive
``jax.profiler.start_trace`` around the run; the on-device split of
local phase vs meta mix comes from the ``jax.named_scope`` annotations
in ``core.meta.meta_step``, which label the HLO itself).

Disabled tracers cost one predicate per span — safe to leave in hot
paths. ``export_chrome_trace`` writes the collected spans in the Chrome
``chrome://tracing`` / Perfetto JSON event format, no profiler plugin
needed.

Tracing is exception-safe: ``session`` is the context-manager form the
Trainer wraps its whole run in — on ANY exit (normal, KeyboardInterrupt,
a crash mid-span) it closes still-open spans (recorded with an
``interrupted`` mark), stops a live device profile, and flushes the
Chrome-trace file, so a crashed run still yields a loadable trace of
everything up to the failure.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager


class Tracer:
    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self.events: list[tuple[str, float, float]] = []  # (name, t0, dur) s
        self._t0 = time.perf_counter()
        self._profiling = False
        # spans entered but not yet exited, as (name, t0) — a crash inside
        # a span unwinds through span()'s finally, but a crash BETWEEN the
        # profiler annotation setup and it, or a generator that is never
        # resumed (GC'd mid-suspend), leaves entries here for
        # close_open_spans to finalize
        self._open: list[tuple[str, float]] = []
        self.interrupted: list[str] = []  # names closed abnormally

    @contextmanager
    def span(self, name: str):
        """Time a phase; no-op (one branch) when disabled."""
        if not self.enabled:
            yield
            return
        import jax

        t0 = time.perf_counter()
        entry = (name, t0)
        self._open.append(entry)
        try:
            with jax.profiler.TraceAnnotation(name):
                yield
        finally:
            if entry in self._open:
                self._open.remove(entry)
            self.events.append((name, t0 - self._t0, time.perf_counter() - t0))

    def close_open_spans(self) -> list[str]:
        """Finalize every still-open span at the current wall clock.

        Normally a no-op (span()'s finally pops the stack); after an
        abnormal unwind it records each orphan as a complete event ending
        now and returns the closed names (also kept in ``interrupted``).
        """
        now = time.perf_counter()
        closed = []
        while self._open:
            name, t0 = self._open.pop()
            self.events.append((name, t0 - self._t0, now - t0))
            closed.append(name)
        self.interrupted.extend(closed)
        return closed

    @contextmanager
    def session(self, export_path: str | None = None,
                profiler_dir: str | None = None):
        """Exception-safe tracing scope around a whole run.

        Enter: optionally starts a device profile into ``profiler_dir``.
        Exit — ALWAYS, crash included: closes open spans, stops the
        profiler, and (if ``export_path``) writes the Chrome trace, so
        whatever was recorded before a failure is loadable. Export
        errors are swallowed on the exception path only — telemetry must
        not mask the real traceback.
        """
        if profiler_dir:
            self.profiler_start(profiler_dir)
        ok = False
        try:
            yield self
            ok = True
        finally:
            self.close_open_spans()
            self.profiler_stop()
            if self.enabled and export_path:
                try:
                    self.export_chrome_trace(export_path)
                except Exception:
                    if ok:  # pragma: no cover - export itself failed
                        raise

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        """{phase: {count, total_s, mean_s}} over all recorded spans."""
        out: dict[str, dict] = {}
        for name, _t, dur in self.events:
            s = out.setdefault(name, {"count": 0, "total_s": 0.0})
            s["count"] += 1
            s["total_s"] += dur
        for s in out.values():
            s["mean_s"] = s["total_s"] / s["count"]
        return out

    def export_chrome_trace(self, path: str) -> str:
        """Write spans as Chrome-trace JSON (load in chrome://tracing or
        https://ui.perfetto.dev). Timestamps in microseconds since the
        tracer was created."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        events = [
            {
                "name": name,
                "ph": "X",  # complete event: begin + duration
                "ts": t0 * 1e6,
                "dur": dur * 1e6,
                "pid": 0,
                "tid": 0,
                "cat": "repro.obs",
            }
            for name, t0, dur in self.events
        ]
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return path

    # ------------------------------------------------------------------
    def profiler_start(self, trace_dir: str) -> bool:
        """Start a jax device profile into ``trace_dir`` (TensorBoard /
        xplane format, includes its own Chrome trace). Best-effort: some
        builds lack profiler support — returns False instead of raising
        so telemetry never kills a run."""
        try:
            import jax

            jax.profiler.start_trace(trace_dir)
            self._profiling = True
            return True
        except Exception:
            return False

    def profiler_stop(self) -> None:
        if not self._profiling:
            return
        try:
            import jax

            jax.profiler.stop_trace()
        except Exception:
            pass
        self._profiling = False
