"""Run-health watchdogs: declarative rules over flushed metric windows.

The Trainer flushes one window of host-side metric records per
``log_every`` boundary (repro.obs.metrics). A ``HealthMonitor`` consumes
exactly those records — it never touches device buffers, adds no syncs,
and is therefore bitwise-invisible to a healthy run (pinned in
tests/test_obs_health.py). Per record it evaluates a list of
``HealthRule``s:

* ``nonfinite`` — the metric is NaN/inf (a dead run: NaN loss or
  displacement norm propagates to every parameter within one meta step);
* ``max`` / ``min`` — absolute threshold (e.g. mixing_spectral_gap
  collapsing toward 0 under churn means consensus has stalled);
* ``rel_max`` / ``rel_min`` — the value vs the trailing-window median of
  the SAME metric (loss divergence, consensus_dist blow-up, throughput
  collapse — the straggler signal: a skewed learner drags
  meta_steps_per_sec down long before it shows in loss).

Violations become structured ``alert`` records (``kind: "alert"``,
schema-validated by tools/check_telemetry.py) appended to the run sink
next to the step records they fired on. A ``fatal`` rule additionally
asks the Trainer to halt-with-checkpoint: the run stops at the next
flush boundary with a resumable checkpoint and a ``HealthHalt``
exception carrying the alert — crash forensics with a restart point, not
a stack trace and a dead run.

This signal surface is what the ROADMAP's K/μ autotuner and the async
bounded-staleness server consume: both need machine-readable "this run
is sick, and how" long before a human reads a loss curve.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, replace

SEVERITIES = ("warn", "fatal")
RULE_KINDS = ("nonfinite", "max", "min", "rel_max", "rel_min")


@dataclass(frozen=True)
class HealthRule:
    """One declarative health check over a single metric.

    name         alert identity (rule field of the emitted record)
    metric       key of the flushed step record to watch (absent -> skip)
    kind         nonfinite | max | min | rel_max | rel_min
    threshold    absolute bound (max/min) or multiplier vs the trailing
                 median (rel_max: fire when value > median * threshold;
                 rel_min: fire when value < median * threshold)
    window       trailing history length for the rel_* median
    min_history  rel_* rules stay silent until this many prior values —
                 the first windows of a run are legitimately wild
    severity     warn (record only) | fatal (record + request halt)
    """

    name: str
    metric: str
    kind: str
    threshold: float = 0.0
    window: int = 16
    min_history: int = 4
    severity: str = "warn"

    def __post_init__(self):
        assert self.kind in RULE_KINDS, (
            f"unknown rule kind {self.kind!r}; choose from {RULE_KINDS}"
        )
        assert self.severity in SEVERITIES, (
            f"unknown severity {self.severity!r}; choose from {SEVERITIES}"
        )
        assert self.window >= 1 and self.min_history >= 1

    @property
    def halt(self) -> bool:
        return self.severity == "fatal"


# the default watch list: the failure modes this repo's subsystems have
# actual metrics for. Divergence multipliers are deliberately loose —
# a watchdog that cries on a noisy-but-converging run teaches people to
# disable it.
DEFAULT_RULES = (
    HealthRule("nonfinite_loss", "loss", "nonfinite", severity="fatal"),
    HealthRule("nonfinite_displacement", "displacement_norm", "nonfinite",
               severity="fatal"),
    HealthRule("loss_divergence", "loss", "rel_max", threshold=10.0,
               severity="fatal"),
    HealthRule("consensus_blowup", "consensus_dist", "rel_max",
               threshold=50.0),
    HealthRule("spectral_gap_collapse", "mixing_spectral_gap", "min",
               threshold=1e-4),
    # straggler skew: per-learner step times aren't separable under SPMD
    # (one fused program), so the observable is the window throughput —
    # a straggling host/device drags meta_steps_per_sec far below its
    # own trailing median
    HealthRule("throughput_collapse", "meta_steps_per_sec", "rel_min",
               threshold=0.1, min_history=8),
    # async bounded-staleness server: applied staleness is bounded by
    # construction (tau <= AsyncConfig.staleness, validated at config
    # time), so a p99 drifting past any sane bound means the step-time
    # profile or the clock state is corrupt — absolute, loose, and absent
    # from synchronous runs (absent metric -> rule skipped)
    HealthRule("staleness_runaway", "staleness_p99", "max",
               threshold=32.0),
    # supervised recovery (core/supervisor.py, DESIGN.md §13): the
    # supervisor feeds these synthetic metrics into its own monitor so
    # checkpoint-chain damage and retry exhaustion surface as the same
    # schema-valid alert records every other failure mode gets. Both
    # metrics are absent from ordinary step records, so the rules are
    # skipped on every normal run.
    HealthRule("checkpoint_verify_failed", "ckpt_verify_failed", "max",
               threshold=0.0, severity="warn"),
    HealthRule("recovery_exhausted", "recovery_exhausted", "max",
               threshold=0.0, severity="fatal"),
)


class HealthHalt(RuntimeError):
    """A fatal health rule fired and the Trainer halted the run.

    Carries the triggering alert record and the path of the checkpoint
    written at the halt boundary (None when checkpointing was off)."""

    def __init__(self, alert: dict, checkpoint_path=None):
        self.alert = dict(alert)
        self.checkpoint_path = checkpoint_path
        where = f"; checkpoint at {checkpoint_path}" if checkpoint_path else ""
        super().__init__(
            f"health rule {alert.get('rule')!r} fired on "
            f"{alert.get('metric')!r}={alert.get('value')!r} at meta_step "
            f"{alert.get('meta_step')}{where}"
        )


def _finite(x) -> bool:
    try:
        return math.isfinite(float(x))
    except (TypeError, ValueError):
        return False


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    m = n // 2
    return s[m] if n % 2 else 0.5 * (s[m - 1] + s[m])


class HealthMonitor:
    """Evaluates rules against each flushed record; collects alerts.

    ``observe(records)`` returns the alert dicts fired by this window (in
    record order) and remembers whether any of them requested a halt
    (``halt_requested`` / ``halt_alert``). Each record is checked against
    the history EXCLUDING itself, then pushed into the trailing windows —
    so a divergence rule compares today against the recent past, not
    against a median it already contaminated. Non-finite values are never
    pushed into rel_* histories (one NaN would poison every later
    median).
    """

    def __init__(self, rules=DEFAULT_RULES):
        self.rules = tuple(rules)
        self._hist: dict[str, deque] = {
            r.metric: deque(maxlen=r.window)
            for r in self.rules if r.kind in ("rel_max", "rel_min")
        }
        # widen a shared metric's window to the largest requesting rule
        for r in self.rules:
            if r.metric in self._hist and r.window > (
                    self._hist[r.metric].maxlen or 0):
                self._hist[r.metric] = deque(
                    self._hist[r.metric], maxlen=r.window
                )
        self.alerts: list[dict] = []
        self.halt_alert: dict | None = None

    @property
    def halt_requested(self) -> bool:
        return self.halt_alert is not None

    # ------------------------------------------------------------------
    def _check(self, rule: HealthRule, value, record) -> dict | None:
        if rule.kind == "nonfinite":
            if _finite(value):
                return None
            reference = None
        elif rule.kind == "max":
            if not _finite(value) or float(value) <= rule.threshold:
                return None
            reference = rule.threshold
        elif rule.kind == "min":
            if not _finite(value) or float(value) >= rule.threshold:
                return None
            reference = rule.threshold
        else:  # rel_max / rel_min vs trailing median
            hist = self._hist[rule.metric]
            if not _finite(value) or len(hist) < rule.min_history:
                return None
            med = _median(hist)
            if rule.kind == "rel_max":
                if med <= 0 or float(value) <= med * rule.threshold:
                    return None
            else:
                if med <= 0 or float(value) >= med * rule.threshold:
                    return None
            reference = med
        alert = {
            "kind": "alert",
            "rule": rule.name,
            "metric": rule.metric,
            "value": float(value) if value is not None else None,
            "severity": rule.severity,
            "halt": rule.halt,
            "meta_step": record.get("meta_step"),
            "rule_kind": rule.kind,
            "threshold": rule.threshold,
            "window": rule.window,
        }
        if reference is not None:
            alert["reference"] = float(reference)
        return alert

    def seed(self, records) -> None:
        """Pre-load the rel_* trailing windows from historical records
        WITHOUT evaluating any rule. A rolled-back retry remembers its
        pre-fault medians (core/supervisor.py seeds the rebuilt
        trainer's monitor with the history below the resume step) —
        otherwise a short retry diverges silently inside ``min_history``
        and the rel_* watchdogs never arm."""
        for rec in records:
            for metric, hist in self._hist.items():
                if metric in rec and _finite(rec[metric]):
                    hist.append(float(rec[metric]))

    def observe(self, records) -> list[dict]:
        fired = []
        for rec in records:
            for rule in self.rules:
                if rule.metric not in rec:
                    continue
                alert = self._check(rule, rec[rule.metric], rec)
                if alert is not None:
                    fired.append(alert)
                    if alert["halt"] and self.halt_alert is None:
                        self.halt_alert = alert
            # push AFTER checking: the rel_* median is strictly trailing
            for metric, hist in self._hist.items():
                if metric in rec and _finite(rec[metric]):
                    hist.append(float(rec[metric]))
        self.alerts.extend(fired)
        return fired


def make_monitor(rules=None, *, halt: bool = True) -> HealthMonitor:
    """Monitor over ``rules`` (default ``DEFAULT_RULES``). ``halt=False``
    demotes every fatal rule to warn — alerts are still recorded, the
    run never stops (ObsConfig.health_halt)."""
    rules = DEFAULT_RULES if rules is None else tuple(rules)
    if not halt:
        rules = tuple(
            replace(r, severity="warn") if r.severity == "fatal" else r
            for r in rules
        )
    return HealthMonitor(rules)
