"""Structured run sinks: one record schema for every trainer run and bench.

A run log is a stream of flat JSON-able records with a ``kind`` tag:

    {"kind": "manifest", ...}   run environment + config (manifest.py)
    {"kind": "step", ...}       one per-meta-step telemetry record
    {"kind": "row", ...}        one benchmark result row (benchmarks/)

Sinks are dumb and synchronous by design — all batching happens upstream
in the on-device ``MetricsBuffer`` (metrics.py), so a sink append is a
handful of host floats, never a device sync. ``JsonlSink`` is the
canonical on-disk format (append-only, resume-friendly: a resumed run
reopens the same file in append mode and writes a fresh manifest line —
``tools/check_telemetry.py`` validates the stream); ``CsvSink`` is for
spreadsheet ergonomics; ``MemorySink`` for tests and in-process readers
(the K_g/mu autotuner consumes it).
"""
from __future__ import annotations

import csv
import json
import os
from typing import Optional


class Sink:
    """Protocol: open_run(manifest) once per (re)open, append(record) per
    step/row, flush() at sync boundaries, close() when done."""

    def open_run(self, manifest: dict) -> None:
        raise NotImplementedError

    def append(self, record: dict) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """In-memory record list — tests, notebooks, and online consumers."""

    def __init__(self):
        self.manifests: list[dict] = []
        self.records: list[dict] = []

    def open_run(self, manifest: dict) -> None:
        self.manifests.append(dict(manifest))

    def append(self, record: dict) -> None:
        self.records.append(dict(record))


class JsonlSink(Sink):
    """Append-only JSONL file; one JSON object per line.

    ``resume=True`` appends to an existing file (the same run log across
    restarts — meta_step stays monotone across the manifest boundary);
    ``resume=False`` truncates. The manifest is written as the first line
    of every (re)open so a reader can always recover the config that
    produced the records that follow it.
    """

    def __init__(self, path: str, *, resume: bool = False):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a" if resume else "w")

    def open_run(self, manifest: dict) -> None:
        self._write({"kind": "manifest", **manifest})

    def append(self, record: dict) -> None:
        rec = record if record.get("kind") else {"kind": "step", **record}
        self._write(rec)

    def _write(self, obj: dict) -> None:
        self._f.write(json.dumps(obj, sort_keys=True, default=_jsonify) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class CsvSink(Sink):
    """CSV of the step records; the manifest goes to a JSON sidecar
    (``<path>.manifest.json``) since it is nested. The header is fixed by
    the FIRST record's keys; later records must agree (one schema per
    run is the whole point)."""

    def __init__(self, path: str, *, resume: bool = False):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        existing = resume and os.path.exists(path) and os.path.getsize(path) > 0
        self._f = open(path, "a" if resume else "w", newline="")
        self._writer: Optional[csv.DictWriter] = None
        if existing:
            with open(path) as f:
                header = f.readline().strip()
            if header:
                self._writer = csv.DictWriter(
                    self._f, fieldnames=header.split(",")
                )

    def open_run(self, manifest: dict) -> None:
        with open(self.path + ".manifest.json", "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True, default=_jsonify)

    def append(self, record: dict) -> None:
        if self._writer is None:
            self._writer = csv.DictWriter(self._f, fieldnames=sorted(record))
            self._writer.writeheader()
        self._writer.writerow(record)

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def _jsonify(x):
    """numpy / jax scalars -> python scalars at the serialization boundary."""
    if hasattr(x, "item"):
        return x.item()
    if hasattr(x, "tolist"):
        return x.tolist()
    return str(x)


SINKS = ("none", "jsonl", "csv", "memory")


def make_sink(kind: str, run_dir: Optional[str] = None, *,
              resume: bool = False) -> Optional[Sink]:
    """Build the sink named by ``ObsConfig.sink`` (None for 'none').

    File sinks write ``<run_dir>/run.jsonl`` / ``run.csv`` — one
    canonical filename per run directory so resume finds the same log.
    """
    if kind == "none":
        return None
    if kind == "memory":
        return MemorySink()
    if run_dir is None:
        raise ValueError(f"sink {kind!r} needs a run_dir (ObsConfig.run_dir)")
    if kind == "jsonl":
        return JsonlSink(os.path.join(run_dir, "run.jsonl"), resume=resume)
    if kind == "csv":
        return CsvSink(os.path.join(run_dir, "run.csv"), resume=resume)
    raise ValueError(f"unknown sink {kind!r}; choose from {SINKS}")
