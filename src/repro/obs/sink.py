"""Structured run sinks: one record schema for every trainer run and bench.

A run log is a stream of flat JSON-able records with a ``kind`` tag:

    {"kind": "manifest", ...}   run environment + config (manifest.py)
    {"kind": "step", ...}       one per-meta-step telemetry record
    {"kind": "row", ...}        one benchmark result row (benchmarks/)

Sinks are dumb and synchronous by design — all batching happens upstream
in the on-device ``MetricsBuffer`` (metrics.py), so a sink append is a
handful of host floats, never a device sync. ``JsonlSink`` is the
canonical on-disk format (append-only, resume-friendly: a resumed run
reopens the same file in append mode and writes a fresh manifest line —
``tools/check_telemetry.py`` validates the stream); ``CsvSink`` is for
spreadsheet ergonomics; ``MemorySink`` for tests and in-process readers
(the K_g/mu autotuner consumes it).
"""
from __future__ import annotations

import csv
import json
import os
from typing import Optional

from repro.utils.retry import retry_io


class Sink:
    """Protocol: open_run(manifest) once per (re)open, append(record) per
    step/row, flush() at sync boundaries, close() when done."""

    def open_run(self, manifest: dict) -> None:
        raise NotImplementedError

    def append(self, record: dict) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """In-memory record list — tests, notebooks, and online consumers."""

    def __init__(self):
        self.manifests: list[dict] = []
        self.records: list[dict] = []

    def open_run(self, manifest: dict) -> None:
        self.manifests.append(dict(manifest))

    def append(self, record: dict) -> None:
        self.records.append(dict(record))


class JsonlSink(Sink):
    """Append-only JSONL file; one JSON object per line.

    ``resume=True`` appends to an existing file (the same run log across
    restarts — meta_step stays monotone across the manifest boundary);
    ``resume=False`` truncates. The manifest is written as the first line
    of every (re)open so a reader can always recover the config that
    produced the records that follow it.

    A killed run can leave a torn final line (a partial ``write`` that
    never reached its newline). Appending after one would glue the
    resumed run's manifest onto the fragment and corrupt the whole
    stream, so resume first repairs the tail: if the last line is not a
    complete JSON object, the file is truncated back to the last good
    newline (``repaired_bytes`` records how much was dropped — at most
    one record, which had no durable effect anyway since the run died
    before checkpointing past it).
    """

    def __init__(self, path: str, *, resume: bool = False):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self.repaired_bytes = 0
        if resume and os.path.exists(path):
            self.repaired_bytes = _repair_torn_tail(path)
        self._f = open(path, "a" if resume else "w")

    def open_run(self, manifest: dict) -> None:
        self._write({"kind": "manifest", **manifest})

    def append(self, record: dict) -> None:
        rec = record if record.get("kind") else {"kind": "step", **record}
        self._write(rec)

    def _write(self, obj: dict) -> None:
        # transient OSErrors (NFS hiccup, disk-pressure EAGAIN) get the
        # shared bounded retry/backoff treatment — the same helper the
        # checkpoint writer's atomic rename uses (repro.utils.retry)
        line = json.dumps(obj, sort_keys=True, default=_jsonify) + "\n"
        retry_io(lambda: self._f.write(line))

    def flush(self) -> None:
        retry_io(self._f.flush)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class CsvSink(Sink):
    """CSV of the step records; the manifest goes to a JSON sidecar
    (``<path>.manifest.json``) since it is nested. The header is fixed by
    the FIRST record's keys; later records must agree (one schema per
    run is the whole point)."""

    def __init__(self, path: str, *, resume: bool = False):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        existing = resume and os.path.exists(path) and os.path.getsize(path) > 0
        self._f = open(path, "a" if resume else "w", newline="")
        self._writer: Optional[csv.DictWriter] = None
        if existing:
            with open(path) as f:
                header = f.readline().strip()
            if header:
                self._writer = csv.DictWriter(
                    self._f, fieldnames=header.split(",")
                )

    def open_run(self, manifest: dict) -> None:
        with open(self.path + ".manifest.json", "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True, default=_jsonify)

    def append(self, record: dict) -> None:
        if self._writer is None:
            self._writer = csv.DictWriter(self._f, fieldnames=sorted(record))
            self._writer.writeheader()
        self._writer.writerow(record)

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def _repair_torn_tail(path: str) -> int:
    """Truncate a torn final line of a JSONL file; returns bytes dropped.

    A line is torn when it lacks its trailing newline or does not parse
    as a JSON object (a write cut mid-record). Scans backward from the
    end to the last newline-terminated line that parses; everything after
    it is truncated. An empty file (or one with no complete line at all)
    is truncated to zero — the resumed open rewrites the manifest anyway.
    """
    size = os.path.getsize(path)
    if size == 0:
        return 0
    with open(path, "rb") as f:
        data = f.read()
    good = len(data)
    # an unterminated tail fragment is torn by definition
    if not data.endswith(b"\n"):
        good = data.rfind(b"\n") + 1  # 0 when no newline at all
    # then walk back over newline-terminated lines that still don't parse
    # (json.dumps output never contains a raw newline, so any unparseable
    # complete line is corruption, not payload)
    while good > 0:
        prev = data.rfind(b"\n", 0, good - 1)
        line = data[prev + 1: good - 1]
        try:
            if isinstance(json.loads(line.decode("utf-8")), dict):
                break
        except (ValueError, UnicodeDecodeError):
            pass
        good = prev + 1
    dropped = size - good
    if dropped:
        with open(path, "rb+") as f:
            f.truncate(good)
    return dropped


def _jsonify(x):
    """numpy / jax scalars -> python scalars at the serialization boundary."""
    if hasattr(x, "item"):
        return x.item()
    if hasattr(x, "tolist"):
        return x.tolist()
    return str(x)


SINKS = ("none", "jsonl", "csv", "memory")


def make_sink(kind: str, run_dir: Optional[str] = None, *,
              resume: bool = False) -> Optional[Sink]:
    """Build the sink named by ``ObsConfig.sink`` (None for 'none').

    File sinks write ``<run_dir>/run.jsonl`` / ``run.csv`` — one
    canonical filename per run directory so resume finds the same log.
    """
    if kind == "none":
        return None
    if kind == "memory":
        return MemorySink()
    if run_dir is None:
        raise ValueError(f"sink {kind!r} needs a run_dir (ObsConfig.run_dir)")
    if kind == "jsonl":
        return JsonlSink(os.path.join(run_dir, "run.jsonl"), resume=resume)
    if kind == "csv":
        return CsvSink(os.path.join(run_dir, "run.csv"), resume=resume)
    raise ValueError(f"unknown sink {kind!r}; choose from {SINKS}")
