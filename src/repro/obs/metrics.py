"""On-device metrics ring: per-step metric planes, one host sync per flush.

The training loop's observability problem is a sync problem: reading any
scalar metric with ``float(v)`` blocks the host on device completion and
serializes dispatch, so per-step host reads turn an async pipelined loop
into a lock-step one. The ``MetricsBuffer`` keeps per-step metrics ON
DEVICE in a fixed-size (capacity, n_metrics) f32 ring: each meta step
writes one row *inside the jitted step* (``write_row`` composes into the
step's trace, so telemetry adds zero extra kernel launches and zero
extra host syncs), and ``flush()`` materializes the whole window with a
single device->host transfer at ``log_every`` boundaries — the same sync
cadence as the pending-list path it replaces (Trainer.run), now with one
bulk transfer instead of one tiny transfer per scalar.

Donation contract (DESIGN.md §10): the ring buffer is donated to the
jitted step alongside the MetaState, so the row write is an in-place
dynamic-update-slice — no second buffer is ever live. Like the state,
the buffer handed to a donated step is DEAD after dispatch; callers
rebind to the returned buffer (``note`` is the Trainer-side helper that
does so). Metrics are step OUTPUTS, never reads of a donated input.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def metric_keys(metrics) -> tuple[str, ...]:
    """Stable (sorted) key order of a metrics dict — the column layout of
    the ring. Derived once per run from an abstract evaluation of the
    step (``jax.eval_shape``), so the jitted row write and the flush
    decode agree without a host read."""
    return tuple(sorted(metrics))


def write_row(buf, row, metrics, keys):
    """Write one metric row into the ring *inside a jit trace*.

    ``buf``: (capacity, n) f32 ring; ``row``: traced int32 row index;
    ``metrics``: dict of scalar (traced) values; ``keys``: static column
    order (``metric_keys``). Values cast to f32 — the ring is a telemetry
    plane, not part of the optimizer state.
    """
    vals = jnp.stack(
        [jnp.asarray(metrics[k], jnp.float32).reshape(()) for k in keys]
    )
    return lax.dynamic_update_slice(buf, vals[None], (row, jnp.int32(0)))


@partial(jax.jit, donate_argnums=(0,))
def _append(buf, row, vals):
    return lax.dynamic_update_slice(buf, vals[None], (row, jnp.int32(0)))


class MetricsBuffer:
    """Host-side handle of the device ring.

    ``keys``      static column order (metric name per column)
    ``capacity``  rows before a flush is forced (size to >= log_every)
    ``buf``       the live device ring — pass into the jitted step, then
                  ``note(step, returned_buf)`` to rebind (donation)
    ``host_syncs`` number of device->host transfers performed — the
                  quantity the telemetry tests pin (no hidden syncs)
    """

    def __init__(self, keys, capacity: int):
        assert capacity >= 1, capacity
        self.keys = tuple(keys)
        self.capacity = int(capacity)
        self.buf = jnp.zeros((self.capacity, len(self.keys)), jnp.float32)
        self.steps: list[int] = []  # meta_step of each pending row, in order
        self.host_syncs = 0

    # ------------------------------------------------------------------
    @property
    def count(self) -> int:
        """Pending rows since the last flush (the next write's row index)."""
        return len(self.steps)

    @property
    def full(self) -> bool:
        return len(self.steps) >= self.capacity

    def row_index(self):
        """The next row index as a device scalar — pass it traced so the
        jitted step is compiled once, not once per row."""
        return jnp.asarray(self.count, jnp.int32)

    def note(self, step: int, new_buf) -> None:
        """Record a dispatched row: the jitted step wrote row ``count``
        and returned the (donated) ring as ``new_buf``."""
        assert not self.full, "MetricsBuffer overflow — flush() before append"
        self.steps.append(int(step))
        self.buf = new_buf

    # ------------------------------------------------------------------
    def append(self, metrics, step: int) -> None:
        """Standalone append (benches / tests): one tiny async device
        launch, still no host sync."""
        if self.full:
            raise RuntimeError(
                f"MetricsBuffer full ({self.capacity} rows) — flush() first"
            )
        vals = jnp.stack(
            [jnp.asarray(metrics[k], jnp.float32).reshape(()) for k in self.keys]
        )
        self.buf = _append(self.buf, self.row_index(), vals)
        self.steps.append(int(step))

    def flush(self) -> list[dict]:
        """Materialize all pending rows with ONE device->host transfer.

        Returns a list of plain-float dicts (one per pending step, with
        ``meta_step`` attached) and resets the pending window. Rows are
        decoded bitwise as written: f32 on device, f32 across the wire,
        widened to python float only at the dict boundary.
        """
        if not self.steps:
            return []
        rows = np.asarray(jax.device_get(self.buf))[: len(self.steps)]
        self.host_syncs += 1
        out = []
        for s, row in zip(self.steps, rows):
            rec = {k: float(v) for k, v in zip(self.keys, row)}
            rec["meta_step"] = s
            out.append(rec)
        self.steps.clear()
        return out
