"""Steady-state profiler attribution: measured wall-clock joined against
the modeled roofline cost (DESIGN.md §11, "Measured performance").

Every timing number this repo reports flows through ONE harness so the
methodology is uniform and stated once:

* **steady state** — ``warmup`` untimed calls first, so compilation,
  autotuning and allocator warm-up never leak into a reported number;
* **dispatch discipline** — each timed call is closed with
  ``jax.block_until_ready`` on its outputs, so what is measured is
  device completion, not async enqueue time;
* **median-of-N with IQR** — the reported statistic is the median over
  ``iters`` repeats with the interquartile range as the noise bar
  (means are garbage under scheduler jitter; a stddev assumes a
  symmetric distribution wall-clocks don't have).

The *attribution* join is the judgment half: a measured median on its
own says nothing about whether a kernel is fast. Joining it against the
compiled program's modeled HBM bytes (``roofline.hlo_cost.jit_cost``)
yields achieved GB/s, and dividing by a measured peak bandwidth
(``measured_peak_gbps`` — a jitted triad on this very machine, not a
datasheet constant) yields % of the roofline bound: the number that is
comparable across machines and across PRs, and the one
``tools/bench_compare.py`` gates on.

On CPU the Pallas kernels are timed through their jnp reference route
(interpret mode executes the kernel body block-by-block in Python — its
wall-clock is meaningless); on TPU the same harness times the native
``pallas_call``. The *methodology* is what is pinned by tests, not the
CPU numbers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# steady-state timing
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Timing:
    """Median-of-N wall clock with IQR noise bar (seconds)."""

    median_s: float
    iqr_s: float
    n: int
    warmup: int
    times_s: tuple

    @property
    def median_us(self) -> float:
        return self.median_s * 1e6

    @property
    def iqr_us(self) -> float:
        return self.iqr_s * 1e6


def _quantile(sorted_xs, q: float) -> float:
    """Linear-interpolated quantile of an already-sorted list."""
    if len(sorted_xs) == 1:
        return sorted_xs[0]
    pos = q * (len(sorted_xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_xs) - 1)
    frac = pos - lo
    return sorted_xs[lo] * (1 - frac) + sorted_xs[hi] * frac


def steady_timeit(fn, *args, iters: int = 10, warmup: int = 2) -> Timing:
    """Time ``fn(*args)`` in steady state; returns a :class:`Timing`.

    The warmup calls absorb compilation and first-touch allocation; every
    timed call blocks on its outputs (``jax.block_until_ready``) so the
    measurement is dispatch->completion, not dispatch->return.
    """
    assert iters >= 1 and warmup >= 0, (iters, warmup)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    xs = sorted(times)
    return Timing(
        median_s=_quantile(xs, 0.5),
        iqr_s=_quantile(xs, 0.75) - _quantile(xs, 0.25),
        n=iters,
        warmup=warmup,
        times_s=tuple(times),
    )


# ---------------------------------------------------------------------------
# measured peak bandwidth: the roofline ceiling of THIS machine
# ---------------------------------------------------------------------------

_PEAK_CACHE: dict[int, float] = {}


def measured_peak_gbps(nbytes: int = 1 << 26, *, refresh: bool = False,
                       iters: int = 5, warmup: int = 2) -> float:
    """Achievable memory bandwidth of the current default device, GB/s.

    A jitted saxpy over an ``nbytes``-sized f32 buffer (2 reads + 1
    write), timed with the same steady-state discipline as everything
    else. Cached per size — one measurement per process. Using a
    *measured* ceiling instead of a datasheet constant makes
    % -of-bound numbers meaningful on whatever machine the bench runs
    on (CPU container, TPU pod), and is the denominator
    ``attribution_row`` divides by.
    """
    if not refresh and nbytes in _PEAK_CACHE:
        return _PEAK_CACHE[nbytes]
    n = max(nbytes // 4, 1024)
    x = jnp.arange(n, dtype=jnp.float32)
    y = jnp.ones((n,), jnp.float32)
    saxpy = jax.jit(lambda x, y: x * 1.5 + y)
    t = steady_timeit(saxpy, x, y, iters=iters, warmup=warmup)
    gbps = 3.0 * n * 4 / t.median_s / 1e9  # 2 reads + 1 write
    _PEAK_CACHE[nbytes] = gbps
    return gbps


# ---------------------------------------------------------------------------
# measured-vs-modeled attribution
# ---------------------------------------------------------------------------


def attribution_row(op: str, timing: Timing, cost=None, *,
                    peak_gbps: float | None = None, extra=None) -> dict:
    """Join one measured :class:`Timing` against one modeled
    ``roofline.hlo_cost.JitCost`` into the canonical attribution record.

    Fields: the timing statistics, the modeled HBM bytes / flops of the
    compiled program, ``achieved_gbps`` (modeled bytes moved per measured
    second) and ``pct_of_bound`` (achieved bandwidth as a percentage of
    the measured peak — 100% means the kernel runs AT the machine's
    memory roofline; the gap is launch overhead, poor locality, or
    compute-boundness).
    """
    row = {
        "kind": "attribution",
        "op": op,
        "median_us": timing.median_us,
        "iqr_us": timing.iqr_us,
        "iters": timing.n,
        "warmup": timing.warmup,
        "backend": jax.default_backend(),
    }
    if cost is not None:
        achieved = cost.hbm_bytes / timing.median_s / 1e9
        row.update(
            modeled_hbm_bytes=float(cost.hbm_bytes),
            modeled_flops=float(cost.flops),
            achieved_gbps=achieved,
        )
        if peak_gbps:
            row.update(
                peak_gbps=float(peak_gbps),
                pct_of_bound=100.0 * achieved / peak_gbps,
            )
    if extra:
        row.update(extra)
    return row


def profile_fn(op: str, fn, *args, iters: int = 10, warmup: int = 2,
               peak_gbps: float | None = None, extra=None) -> dict:
    """Measure a jittable ``fn(*args)`` AND model it, in one call.

    Compiles ``fn`` twice on purpose: once through ``jit_cost`` (AOT
    lower/compile for the modeled HBM bytes — nothing is executed) and
    once for the timed steady-state loop. Returns the attribution row.
    """
    from repro.roofline.hlo_cost import jit_cost

    cost = jit_cost(fn, *args)
    timing = steady_timeit(jax.jit(fn), *args, iters=iters, warmup=warmup)
    return attribution_row(op, timing, cost, peak_gbps=peak_gbps, extra=extra)


# ---------------------------------------------------------------------------
# training-phase attribution: local phase vs meta mix vs whole step
# ---------------------------------------------------------------------------


def profile_phases(loss_fn, cfg, state, batches, lr=None, *, iters: int = 10,
                   warmup: int = 2, peak_gbps: float | None = None,
                   profiler_trace_dir: str | None = None) -> list[dict]:
    """Attribution rows for the two halves of one meta iteration.

    Times, with the shared steady-state discipline, (a) the whole jitted
    meta step, (b) the local phase alone (K-step scan over all learners)
    and, for the averaging algorithms, (c) the meta mix alone
    (``topology.mix`` on the current state's planes) — each joined
    against its own compiled-HLO modeled cost. The rows ride the same
    sink envelope as step records (``kind: attribution``) and are what
    ``pack_bench`` surfaces per config.

    Profiling uses FUNCTIONAL (non-donated) step instances: a donated
    step kills its input buffers on first dispatch, and a timing loop
    re-feeds the same arguments every iteration. Numerics are identical
    (donation is pure aliasing), so the attribution transfers.

    ``profiler_trace_dir``: optionally capture a ``jax.profiler`` device
    trace of one extra whole-step call into this directory (best-effort;
    the Chrome-trace-compatible xplane export lands next to the PR 6
    span traces).
    """
    from repro.core.meta import _local_phase, make_meta_step
    from repro.topology import make_topology

    lr = jnp.float32(cfg.learner_lr) if lr is None else lr
    # every algorithm now routes its meta phase through a Topology
    # (eamsgd/downpour are aliases onto the async server), so the
    # meta_mix row is always attributable
    topology = make_topology(cfg, None)

    step_fn = make_meta_step(loss_fn, cfg, topology=topology)

    def whole_step(s, b, l):
        return step_fn(s, b, lr=l)

    def local_phase(s, b, l):
        steps = topology.local_steps(s.topo, s.step)
        return _local_phase(loss_fn, s.learners, s.local_momentum, b, cfg,
                            l, steps=steps, spec=s.spec)

    def meta_mix(s):
        return topology.mix(s.learners, s.global_params, s.momentum,
                            s.comm_residual, s.topo, step=s.step)

    targets = [
        ("phase:step", whole_step, (state, batches, lr)),
        ("phase:local", local_phase, (state, batches, lr)),
        ("phase:meta_mix", meta_mix, (state,)),
    ]

    rows = [
        profile_fn(op, fn, *args, iters=iters, warmup=warmup,
                   peak_gbps=peak_gbps,
                   extra={"algorithm": cfg.algorithm,
                          "topology": cfg.topology.kind})
        for op, fn, args in targets
    ]

    if profiler_trace_dir:
        from repro.obs.trace import Tracer

        t = Tracer(enabled=True)
        if t.profiler_start(profiler_trace_dir):
            try:
                jax.block_until_ready(jax.jit(whole_step)(state, batches, lr))
            finally:
                t.profiler_stop()
    return rows
