"""Bench trajectory stores + baseline comparison (the regression sentinel).

Every ``benchmarks/run.py`` suite appends its JSONL-envelope rows to a
persistent per-suite trajectory file, ``BENCH_<suite>.json`` — one JSON
object per line, each a *trajectory point*: ``{"kind": "trajectory",
"suite", "manifest", "rows"}``. The manifest carries the device/config
identity so a trajectory mixing CPU-smoke and TPU points stays
interpretable; the rows are the suite's own result records, unmodified.
Append-only by design: the file IS the cross-run history the perf
claims of PRs 4–5 get measured against.

``compare`` turns the latest point against a committed *baseline spec*
(``benchmarks/expected/<suite>.json``) into pass/fail. A spec lists
metrics, each selecting rows by field equality and bounding one field:

    {"suite": "pack", "metrics": [
      {"name": "peak ratio",               # human label
       "select": {"row_kind": "hbm_peak_state"},
       "field": "ratio",
       "max": 0.6},                        # absolute bound, or:
      {"name": "meta step time",
       "select": {"row_kind": "pack_timing_xla_cpu"},
       "field": "meta_step_us_packed",
       "baseline": 1234.5, "tol_rel": 0.10, "direction": "min"}]}

``direction: "min"`` means lower-is-better (times, bytes, loss): the
metric fails when value > baseline * (1 + tol_rel). ``"max"`` means
higher-is-better (accuracy, reduction factors): fails when value <
baseline * (1 - tol_rel). A metric whose selector matches no row fails
too — a silently vanished measurement is the stealthiest regression.

This module is imported by ``tools/bench_compare.py`` WITHOUT the repro
package on the path (CI gate jobs are stdlib-only), so module level must
stay stdlib: no jax, no relative imports; ``run_manifest`` is pulled
lazily only when a caller asks for one.
"""
from __future__ import annotations

import json
import os
import time


def trajectory_path(bench_dir: str, suite: str) -> str:
    """``<bench_dir>/BENCH_<suite>.json`` — the per-suite trajectory."""
    return os.path.join(bench_dir, f"BENCH_{suite}.json")


def _jsonify(x):
    if hasattr(x, "item"):
        return x.item()
    if hasattr(x, "tolist"):
        return x.tolist()
    return str(x)


def append_trajectory(path: str, suite: str, rows, manifest=None,
                      created_unix=None) -> dict:
    """Append one trajectory point; returns the point written.

    ``manifest=None`` builds a fresh ``repro.obs.run_manifest`` (lazy
    import — needs jax; pass an explicit dict from stdlib-only callers).
    """
    if manifest is None:
        from repro.obs.manifest import run_manifest

        manifest = run_manifest(suite=suite)
    point = {
        "kind": "trajectory",
        "suite": suite,
        "created_unix": (
            time.time() if created_unix is None else created_unix
        ),
        "manifest": manifest,
        "rows": [dict(r) for r in rows],
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(point, sort_keys=True, default=_jsonify) + "\n")
    return point


def load_trajectory(path: str) -> list[dict]:
    """All trajectory points of a store, oldest first. Tolerates a torn
    final line (a killed bench run) by dropping it — same policy as the
    JSONL run-sink repair."""
    points = []
    if not os.path.exists(path):
        return points
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except ValueError:
                continue  # torn tail / corruption: skip, don't die
            if isinstance(obj, dict):
                points.append(obj)
    return points


def latest_rows(path: str, suite=None) -> list[dict]:
    """Rows of the newest trajectory point (optionally filtered to one
    suite); [] when the store is empty."""
    points = load_trajectory(path)
    if suite is not None:
        points = [p for p in points if p.get("suite") == suite]
    return list(points[-1].get("rows", ())) if points else []


# ---------------------------------------------------------------------------
# baseline comparison
# ---------------------------------------------------------------------------


def _select(rows, selector) -> list[dict]:
    out = []
    for r in rows:
        if all(r.get(k) == v for k, v in (selector or {}).items()):
            out.append(r)
    return out


def _bound(metric) -> tuple[float | None, float | None]:
    """(lo, hi) acceptance interval of one metric spec."""
    lo = hi = None
    if "max" in metric:
        hi = float(metric["max"])
    if "min" in metric:
        lo = float(metric["min"])
    if "baseline" in metric:
        base = float(metric["baseline"])
        tol = float(metric.get("tol_rel", 0.1))
        if metric.get("direction", "min") == "min":  # lower is better
            hi = base * (1.0 + tol) if hi is None else min(hi, base * (1 + tol))
        else:  # higher is better
            lo = base * (1.0 - tol) if lo is None else max(lo, base * (1 - tol))
    return lo, hi


def compare(rows, spec) -> list[str]:
    """Check rows against a baseline spec; returns violation strings
    (empty = pass). Every metric must match at least one row, and every
    matched value must land inside the metric's acceptance interval."""
    violations = []
    for metric in spec.get("metrics", ()):
        name = metric.get("name") or metric.get("field", "?")
        fld = metric["field"]
        matched = _select(rows, metric.get("select"))
        values = [r[fld] for r in matched if fld in r]
        if not values:
            violations.append(
                f"{name}: no row matches select={metric.get('select')} "
                f"with field {fld!r} — measurement vanished"
            )
            continue
        lo, hi = _bound(metric)
        for v in values:
            try:
                fv = float(v)
            except (TypeError, ValueError):
                violations.append(f"{name}: non-numeric value {v!r}")
                continue
            if fv != fv:  # NaN
                violations.append(f"{name}: value is NaN")
            elif hi is not None and fv > hi:
                violations.append(
                    f"{name}: {fv:.6g} exceeds bound {hi:.6g}"
                    + (f" (baseline {metric['baseline']:.6g} "
                       f"+{100 * float(metric.get('tol_rel', 0.1)):.0f}%)"
                       if "baseline" in metric else "")
                )
            elif lo is not None and fv < lo:
                violations.append(
                    f"{name}: {fv:.6g} below bound {lo:.6g}"
                    + (f" (baseline {metric['baseline']:.6g} "
                       f"-{100 * float(metric.get('tol_rel', 0.1)):.0f}%)"
                       if "baseline" in metric else "")
                )
    return violations


def seed_spec(rows, spec) -> dict:
    """Fill the ``baseline`` value of every relative metric from measured
    rows (worst matched value per direction, so the seeded baseline is
    the loosest honest one). Absolute-bound metrics pass through."""
    out = dict(spec)
    metrics = []
    for metric in spec.get("metrics", ()):
        m = dict(metric)
        if "tol_rel" in m or "baseline" in m or "direction" in m:
            matched = _select(rows, m.get("select"))
            values = []
            for r in matched:
                try:
                    values.append(float(r[m["field"]]))
                except (KeyError, TypeError, ValueError):
                    pass
            if values:
                m["baseline"] = (
                    max(values) if m.get("direction", "min") == "min"
                    else min(values)
                )
        metrics.append(m)
    out["metrics"] = metrics
    return out
