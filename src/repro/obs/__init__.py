"""repro.obs — on-device training telemetry, phase tracing, structured
run sinks (DESIGN.md §11).

Three pieces, composable and individually optional:

* ``MetricsBuffer`` (metrics.py): a fixed-size device-side ring of
  per-step metric rows, written INSIDE the jitted meta step (donated, in
  place) and flushed to host with one bulk transfer per ``log_every``
  window — telemetry without extra host syncs.
* ``Sink`` (sink.py): where flushed records and the run manifest go —
  JSONL (canonical, append-on-resume), CSV, or in-memory. Every Trainer
  run and every bench emits the same record envelope.
* ``Tracer`` (trace.py): config-gated phase span timers with Chrome-trace
  export and ``jax.profiler`` hooks.

``run_manifest`` (manifest.py) is the shared run-identity record: config,
PackSpec hash, topology/reducer/elastic settings, jax/device info, and
optionally the measured compiled-program cost (roofline.hlo_cost).
"""
from repro.obs.manifest import (
    SCHEMA_VERSION,
    device_env,
    packspec_hash,
    run_manifest,
)
from repro.obs.metrics import MetricsBuffer, metric_keys, write_row
from repro.obs.sink import (
    SINKS,
    CsvSink,
    JsonlSink,
    MemorySink,
    Sink,
    make_sink,
)
from repro.obs.trace import Tracer

__all__ = [
    "SCHEMA_VERSION",
    "SINKS",
    "CsvSink",
    "JsonlSink",
    "MemorySink",
    "MetricsBuffer",
    "Sink",
    "Tracer",
    "device_env",
    "make_sink",
    "metric_keys",
    "packspec_hash",
    "run_manifest",
    "write_row",
]
