"""repro.obs — on-device training telemetry, phase tracing, structured
run sinks (DESIGN.md §11).

Three pieces, composable and individually optional:

* ``MetricsBuffer`` (metrics.py): a fixed-size device-side ring of
  per-step metric rows, written INSIDE the jitted meta step (donated, in
  place) and flushed to host with one bulk transfer per ``log_every``
  window — telemetry without extra host syncs.
* ``Sink`` (sink.py): where flushed records and the run manifest go —
  JSONL (canonical, append-on-resume), CSV, or in-memory. Every Trainer
  run and every bench emits the same record envelope.
* ``Tracer`` (trace.py): config-gated phase span timers with Chrome-trace
  export and ``jax.profiler`` hooks.

``run_manifest`` (manifest.py) is the shared run-identity record: config,
PackSpec hash, topology/reducer/elastic settings, jax/device info, and
optionally the measured compiled-program cost (roofline.hlo_cost).

PR 7 adds the measurement-and-judgment layer on top:

* ``profile`` (profile.py): the steady-state timing harness every
  reported number flows through (warmup, block_until_ready, median +
  IQR) and the measured-vs-modeled attribution join against
  ``roofline.hlo_cost`` (achieved HBM GB/s, % of the machine's measured
  roofline bound).
* ``baseline`` (baseline.py): per-suite ``BENCH_<suite>.json`` trajectory
  stores + committed baseline specs; ``tools/bench_compare.py`` turns
  them into the CI regression gate.
* ``health`` (health.py): declarative run-health rules over flushed
  metric windows -> structured ``alert`` records, with fatal rules
  halting the Trainer on a resumable checkpoint (``HealthHalt``).
"""
from repro.obs.baseline import (
    append_trajectory,
    compare,
    latest_rows,
    load_trajectory,
    trajectory_path,
)
from repro.obs.health import (
    DEFAULT_RULES,
    HealthHalt,
    HealthMonitor,
    HealthRule,
    make_monitor,
)
from repro.obs.manifest import (
    SCHEMA_VERSION,
    device_env,
    packspec_hash,
    run_manifest,
)
from repro.obs.metrics import MetricsBuffer, metric_keys, write_row
from repro.obs.profile import (
    Timing,
    attribution_row,
    measured_peak_gbps,
    profile_fn,
    profile_phases,
    steady_timeit,
)
from repro.obs.sink import (
    SINKS,
    CsvSink,
    JsonlSink,
    MemorySink,
    Sink,
    make_sink,
)
from repro.obs.trace import Tracer

__all__ = [
    "DEFAULT_RULES",
    "SCHEMA_VERSION",
    "SINKS",
    "CsvSink",
    "HealthHalt",
    "HealthMonitor",
    "HealthRule",
    "JsonlSink",
    "MemorySink",
    "MetricsBuffer",
    "Sink",
    "Timing",
    "Tracer",
    "append_trajectory",
    "attribution_row",
    "compare",
    "device_env",
    "latest_rows",
    "load_trajectory",
    "make_monitor",
    "make_sink",
    "measured_peak_gbps",
    "metric_keys",
    "packspec_hash",
    "profile_fn",
    "profile_phases",
    "run_manifest",
    "steady_timeit",
    "trajectory_path",
    "write_row",
]
