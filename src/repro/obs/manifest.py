"""The per-run manifest: everything needed to interpret a run log.

One dict, JSON-able, written as the first record of every sink stream
(sink.py) and alongside checkpoints (checkpoint/npz.py): the full config,
the packed meta-plane layout hash (a resume against a different layout is
a different run — the same guard load_state enforces bitwise), the
topology / reducer / elastic settings that decide which metric columns
exist, and the jax / device environment. Optionally the measured
compiled-program numbers from ``roofline.hlo_cost.jit_cost`` (HBM bytes,
peak state, flops) so every run log carries the cost model it ran under.
"""
from __future__ import annotations

import hashlib
import json
import time

import jax

# history: 1 = PR 6 (manifest/step/row kinds); 2 = PR 7 (adds the
# ``alert`` and ``attribution`` record kinds — additive, so v1 readers
# that skip unknown kinds still parse v2 streams, but a v1 VALIDATOR
# must reject them: tools/check_telemetry.py gates on the major);
# 3 = PR 9 (adds the ``fault`` and ``recovery`` record kinds of
# core/supervisor.py plus the optional ``nonfinite_learners`` step
# metric — additive again, same major-gating story);
# 4 = adds the ``robust`` record kind (repro.robust: per-mix clip /
# trim / anomaly-score telemetry repackaged out of the step rows by
# core/trainer.py) — additive
SCHEMA_VERSION = 4


def packspec_hash(spec) -> str | None:
    """Short stable hash of the packed meta-plane layout (repro.pack
    PackSpec) — the identity of the flat-buffer encoding, matching what
    the checkpoint ``__packspec__`` sidecar records."""
    if spec is None:
        return None
    blob = json.dumps(spec.layout_dict(), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def device_env() -> dict:
    devs = jax.devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "devices": [str(d) for d in devs[:8]] + (
            [f"... {len(devs) - 8} more"] if len(devs) > 8 else []
        ),
        "process_index": jax.process_index(),
    }


def run_manifest(*, train_cfg=None, mcfg=None, spec=None, suite=None,
                 jit_cost=None, extra=None) -> dict:
    """Build the manifest dict.

    ``train_cfg``: TrainConfig (trainer runs — carries the MAvgConfig);
    ``mcfg``: bare MAvgConfig (benches that bypass the Trainer);
    ``suite``: bench suite name (bench logs); ``jit_cost``: a
    ``roofline.hlo_cost.JitCost`` of the jitted meta step; ``extra``:
    free-form additions (merged last, so callers can annotate).
    """
    from repro.configs.base import to_dict

    man = {
        "schema_version": SCHEMA_VERSION,
        "created_unix": time.time(),
        **device_env(),
    }
    if suite is not None:
        man["suite"] = str(suite)
    m = train_cfg.mavg if train_cfg is not None else mcfg
    if m is not None:
        man.update(
            algorithm=m.algorithm,
            num_learners=m.num_learners,
            k_steps=m.k_steps,
            topology=m.topology.kind,
            comm_scheme=m.comm.scheme,
            elastic=m.topology.elastic is not None,
            packed=m.packed,
            donate=m.donate,
        )
    if train_cfg is not None:
        cfg_dict = to_dict(train_cfg)
        # the model config may be None in synthetic-loss runs (tests)
        man["config"] = cfg_dict
    elif mcfg is not None:
        man["config"] = to_dict(mcfg)
    h = packspec_hash(spec)
    if h is not None:
        man["packspec_hash"] = h
    if jit_cost is not None:
        man["jit_cost"] = {
            "hbm_bytes": float(jit_cost.hbm_bytes),
            "flops": float(jit_cost.flops),
            "arg_bytes": int(jit_cost.arg_bytes),
            "out_bytes": int(jit_cost.out_bytes),
            "alias_bytes": int(jit_cost.alias_bytes),
            "temp_bytes": int(jit_cost.temp_bytes),
            "peak_state_bytes": int(jit_cost.peak_state_bytes),
        }
    if extra:
        man.update(extra)
    return man
