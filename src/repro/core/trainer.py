"""High-level training driver tying together model, data, meta-optimizer,
telemetry, checkpointing and (optionally) a device mesh.

On a real cluster the same Trainer runs under the production mesh from
``repro.launch.mesh`` (the learner axis sharded over data/pod axes); on CPU
it runs the identical jitted program on one device — the SPMD program is
the same, which is what the multi-pod dry-run proves.

Telemetry (``repro.obs``, DESIGN.md §11): every per-step scalar the meta
step emits is written into an on-device MetricsBuffer ring *inside* the
jitted step, so the host never touches a metric between ``log_every``
boundaries — one bulk ``device_get`` per flush window is the only sync.
Flushed records (plus host-side wall-clock throughput) land in
``self.history`` and, when ``TrainConfig.obs`` selects a sink, in a
structured run log under a per-run manifest.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import load_state, save_state
from repro.configs.base import MAvgConfig, TrainConfig
from repro.core.meta import init_state, make_meta_step
from repro.obs import (
    HealthHalt,
    MetricsBuffer,
    Tracer,
    make_monitor,
    make_sink,
    metric_keys,
    run_manifest,
    write_row,
)

# argnum of the MetricsBuffer ring in the fused ``step(state, batches, lr,
# mbuf, mrow)`` signature — donated unconditionally (the caller never
# re-reads a pre-step ring; see launch/specs.py donate_extra)
_RING_ARGNUM = 3


class Trainer:
    def __init__(
        self,
        train_cfg: TrainConfig,
        loss_fn: Callable,
        init_params_fn: Callable,
        batch_fn: Callable,  # (rng, step) -> batches (L, K, B, ...)
        lr_schedule: Optional[Callable] = None,
        mesh=None,
        state_shardings=None,
    ):
        self.cfg = train_cfg
        self.mcfg: MAvgConfig = train_cfg.mavg
        self.obs_cfg = train_cfg.obs
        self.loss_fn = loss_fn
        self.batch_fn = batch_fn
        self.lr_schedule = lr_schedule
        self.mesh = mesh
        self._state_shardings = state_shardings if mesh is not None else None

        # fault injection (repro.chaos, DESIGN.md §13): compile the
        # schedule once and thread each layer's injector to its layer —
        # the config transform (crash -> elastic membership, straggle ->
        # async profile) BEFORE the topology is built, the batch poisoner
        # around batch_fn, the payload corruptor into the jitted step,
        # and save faults into the checkpoint writer (see run()). With
        # chaos None every one of these is the untouched original object.
        self._chaos_schedule = None
        chaos_corruptor = None
        if train_cfg.chaos is not None:
            from repro.chaos import (
                FaultSchedule,
                PayloadCorruptor,
                apply_chaos,
                wrap_batch_fn,
            )

            self.mcfg = apply_chaos(
                self.mcfg, train_cfg.chaos, salt=train_cfg.data_salt
            )
            self._chaos_schedule = FaultSchedule(
                train_cfg.chaos, self.mcfg.num_learners,
                salt=train_cfg.data_salt,
            )
            self.batch_fn = wrap_batch_fn(batch_fn, self._chaos_schedule)
            if self._chaos_schedule.any_payload_faults:
                chaos_corruptor = PayloadCorruptor(self._chaos_schedule)

        rng = jax.random.PRNGKey(train_cfg.seed)
        self.data_rng, init_rng = jax.random.split(rng)
        if train_cfg.data_salt:
            # supervisor retries redraw the data stream (the transient
            # non-sticky faults already dropped out of the schedule above)
            self.data_rng = jax.random.fold_in(
                self.data_rng, train_cfg.data_salt
            )
        params = init_params_fn(init_rng)
        # one topology instance serves state init, the jitted step, and
        # the host-side effective-samples accounting (work_completed) —
        # async profiles complete fewer K-step blocks per tick than L
        from repro.topology import make_topology

        self._topology = make_topology(self.mcfg)
        self.state = init_state(params, self.mcfg, topology=self._topology)
        self._step_fn = make_meta_step(
            loss_fn, self.mcfg, topology=self._topology,
            chaos=chaos_corruptor,
        )

        # telemetry is built lazily at the first run() iteration: the
        # metric-key set is only known from the step's abstract output
        # (jax.eval_shape — no compile), and the ring must exist before
        # the first fused dispatch
        self._mb: Optional[MetricsBuffer] = None
        self._fused = None
        self._sink = None
        self.manifest: Optional[dict] = None
        self.tracer = Tracer(self.obs_cfg.trace)
        self._restored = False
        self.history: list[dict] = []
        # health watchdogs (obs.health): consume only flushed host
        # floats, so a healthy run is bitwise identical with them on
        self._monitor = (
            make_monitor(halt=self.obs_cfg.health_halt)
            if self.obs_cfg.health else None
        )
        self.attribution: list[dict] = []
        # inline quarantine (repro.robust, DESIGN.md §14): host-side
        # streak counter over the flushed per-learner anomaly scores —
        # a persistently-anomalous learner is masked out of membership
        # right here, without a HealthHalt/supervisor round-trip
        self.robust_records: list[dict] = []
        self.quarantined: dict[int, int] = {}  # learner -> quarantine step
        self._anomaly_streak = None

    # ------------------------------------------------------------------
    # telemetry assembly (lazy, once per Trainer)
    # ------------------------------------------------------------------

    def _init_obs(self, batches, lr):
        """Build the metric ring, fused jitted step, manifest and sink.

        The fused step writes the step's metric scalars into row ``mrow``
        of the donated ring *inside* the jitted program:

            step(state, batches, lr, mbuf, mrow) -> (state', mbuf')

        Metrics therefore reach the host exclusively through
        ``MetricsBuffer.flush`` (one bulk device_get per log window) —
        there is no per-step host read to accidentally sync on, and under
        ``mcfg.donate`` the metric write adds zero copies: both the state
        and the ring are updated in place.
        """
        obs = self.obs_cfg

        def fused(state, b, lr_, mbuf, mrow):
            state, metrics = self._step_fn(state, b, lr=lr_)
            mbuf = write_row(mbuf, mrow, metrics, self._mkeys)
            return state, mbuf

        # abstract eval discovers the metric keys without compiling
        _, metrics_sds = jax.eval_shape(
            lambda s, b, l: self._step_fn(s, b, lr=l), self.state, batches, lr
        )
        self._mkeys = metric_keys(metrics_sds)
        capacity = obs.buffer_capacity or max(self.cfg.log_every, 1)
        self._mb = MetricsBuffer(self._mkeys, capacity)

        from repro.launch.specs import meta_step_jit_kwargs

        kwargs = meta_step_jit_kwargs(
            self.mcfg,
            self._state_shardings,
            n_extra_args=4,
            donate_extra=(_RING_ARGNUM,),
        )
        self._fused = jax.jit(fused, **kwargs)

        jc = None
        if obs.cost_analysis:
            from repro.roofline.hlo_cost import jit_cost

            try:
                # the bare (state, batches, lr) step, not the fused one:
                # the metric ring is telemetry, not part of the training
                # program whose HBM/peak-state cost the manifest records
                jc = jit_cost(
                    lambda s, b, l: self._step_fn(s, b, lr=l),
                    self.state, batches, lr,
                    **({"donate_argnums": (0,)} if self.mcfg.donate else {}),
                )
            except Exception:  # cost analysis is best-effort telemetry
                jc = None
        self.manifest = run_manifest(
            train_cfg=self.cfg,
            mcfg=self.mcfg,
            spec=getattr(self.state, "spec", None),
            jit_cost=jc,
        )
        if obs.sink != "none" and self._sink is None:
            self._sink = make_sink(
                obs.sink, obs.run_dir, resume=self._restored
            )
            self._sink.open_run(self.manifest)
        if obs.attribution:
            # measured-vs-modeled phase attribution, once before step 0:
            # functional (non-donated) copies of the step/phases are
            # steady-state timed and joined against their compiled-HLO
            # modeled bytes — the training state is untouched
            from repro.obs import measured_peak_gbps, profile_phases

            try:
                self.attribution = profile_phases(
                    self.loss_fn, self.mcfg, self.state, batches, lr,
                    iters=5, warmup=2, peak_gbps=measured_peak_gbps(),
                )
            except Exception:  # attribution is best-effort telemetry
                self.attribution = []
            if self._sink is not None:
                for row in self.attribution:
                    self._sink.append(row)

    # ------------------------------------------------------------------
    # driving loop
    # ------------------------------------------------------------------

    def run(self, meta_steps: Optional[int] = None, log=print):
        """Drive ``meta_steps`` jitted steps.

        Metrics stay on-device until a ``log_every`` boundary (or the end
        of the run): the fused step accumulates them into the MetricsBuffer
        ring, and only the boundary pays one bulk device_get — the
        in-between steps are enqueued back-to-back with zero host syncs.
        ``history`` holds plain float dicts afterwards, now including
        wall-clock throughput (``meta_steps_per_sec``, ``samples_per_sec``,
        ``elapsed_s``) computed host-side per flush window.

        Donation contract (``MAvgConfig.donate``): the state handed to
        the fused step is dead the moment the call is dispatched — its
        planes are aliased into the returned state's, and the metric ring
        is likewise donated and rebound every step. Everything in this
        loop therefore works off RETURNED values: the step counter is
        read once before any dispatch, metrics are step outputs flushed
        from the returned ring, the checkpoint cadence is host arithmetic
        on python ints, and ``save_state`` snapshots a returned state
        (never an input a later dispatch may have consumed).
        """
        n = meta_steps if meta_steps is not None else self.cfg.meta_steps
        run_t0 = time.time()
        start = int(self.state.step)  # the only pre-loop host sync
        self._last_flush_t = run_t0
        # samples per completed K-step block; the topology says how many
        # blocks have completed through a given meta step (async learners
        # fire on their own clocks, so blocks/tick varies)
        samples_per_block = self.mcfg.k_steps * self.cfg.batch_per_learner
        samples_per_meta = self.mcfg.num_learners * samples_per_block

        def flush():
            if self._mb is None or not self._mb.count:
                return
            with self.tracer.span("obs.host_flush"):
                recs = self._mb.flush()
            now = time.time()
            dt = max(now - self._last_flush_t, 1e-9)
            self._last_flush_t = now
            msps = len(recs) / dt
            robust_rows = self._extract_robust(recs)
            for r in recs:
                s = r["meta_step"]
                r["samples"] = (
                    self._topology.work_completed(s) * samples_per_block
                )
                r["meta_steps_per_sec"] = msps
                r["samples_per_sec"] = msps * samples_per_meta
                r["elapsed_s"] = now - run_t0
                self.history.append(r)
            self._observe_robust(robust_rows)
            alerts = (
                self._monitor.observe(recs) if self._monitor is not None
                else ()
            )
            if self._sink is not None:
                with self.tracer.span("obs.sink_append"):
                    for r in recs:
                        self._sink.append(r)
                    for rb in robust_rows:
                        self._sink.append(rb)
                    for a in alerts:
                        self._sink.append(a)
                    self._sink.flush()

        def maybe_halt(step):
            # raised ONLY from in-loop flush boundaries (never from the
            # finally-flush — a halt must not mask a real traceback or
            # fire after the loop already ended)
            if self._monitor is None or not self._monitor.halt_requested:
                return
            alert = self._monitor.halt_alert
            ckpt_dir = self.cfg.checkpoint_dir or (
                os.path.join(self.obs_cfg.run_dir, "halt_ckpt")
                if self.obs_cfg.run_dir else None
            )
            path = None
            if ckpt_dir:
                with self.tracer.span("obs.checkpoint_io"):
                    path = save_state(
                        ckpt_dir, self.state, step + 1,
                        manifest=self.manifest,
                    )
            raise HealthHalt(alert, path)

        # trace/profiler lifecycle is exception-safe: the session closes
        # open spans, stops the profiler and exports the Chrome trace on
        # ANY exit — including the final flush below, whose spans land in
        # the exported file
        run_dir = self.obs_cfg.run_dir
        export_path = (
            os.path.join(run_dir, "trace.json")
            if self.obs_cfg.trace and run_dir else None
        )
        profiler_dir = (
            os.path.join(run_dir, "jax_trace")
            if self.obs_cfg.profiler and run_dir else None
        )
        with self.tracer.session(export_path, profiler_dir):
            try:
                for i in range(n):
                    step = start + i
                    rng = jax.random.fold_in(self.data_rng, step)
                    batches = self.batch_fn(rng, step)
                    lr = (
                        self.lr_schedule(step)
                        if self.lr_schedule
                        else jnp.float32(self.mcfg.learner_lr)
                    )
                    if self._mb is None:
                        self._init_obs(batches, lr)
                    if self._mb.full:  # ring smaller than the log window
                        flush()
                        maybe_halt(step - 1)
                    with self.tracer.span("obs.dispatch"):
                        self.state, ring = self._fused(
                            self.state, batches, lr,
                            self._mb.buf, self._mb.row_index(),
                        )
                    self._mb.note(step, ring)
                    if log and (step % self.cfg.log_every == 0):
                        flush()
                        maybe_halt(step)
                        m = self.history[-1]
                        log(
                            f"[{self.mcfg.algorithm}] meta_step={step} "
                            f"loss={m['loss']:.4f} "
                            f"gnorm={m.get('grad_norm', 0):.3f} "
                            f"{m['meta_steps_per_sec']:.2f} steps/s "
                            f"{m['samples_per_sec']:.0f} samples/s "
                            f"({time.time() - run_t0:.1f}s)"
                        )
                    if (
                        self.cfg.checkpoint_dir
                        and self.cfg.checkpoint_every
                        and (step + 1) % self.cfg.checkpoint_every == 0
                    ):
                        fault = (
                            self._chaos_schedule.save_fault(step + 1)
                            if self._chaos_schedule is not None else None
                        )
                        with self.tracer.span("obs.checkpoint_io"):
                            save_state(
                                self.cfg.checkpoint_dir, self.state, step + 1,
                                manifest=self.manifest,
                                keep=self.cfg.checkpoint_keep,
                                fault=fault,
                            )
                flush()  # the final (possibly partial) log window
                maybe_halt(start + n - 1)
            finally:
                flush()  # metrics of completed steps survive an interrupt
                if self._sink is not None:
                    self._sink.flush()
        return self.history

    # ------------------------------------------------------------------
    # robust telemetry + inline quarantine (repro.robust, DESIGN.md §14)
    # ------------------------------------------------------------------

    def _extract_robust(self, recs):
        """Pop the ``robust_*`` metric scalars out of the flushed step
        records and repackage them as ``robust`` records (telemetry
        schema v4) — one per meta step that carried them. Step rows stay
        on the v3 step schema; the robust rows ride the same sink."""
        from repro.robust import ROBUST_METRIC_PREFIX as P

        rows = []
        for r in recs:
            if not any(k.startswith(P) for k in r):
                continue
            rb = {
                "kind": "robust",
                "meta_step": r["meta_step"],
                "clipped_learners": r.pop(P + "clipped_learners", 0.0),
                "clip_budget": r.pop(P + "clip_budget", 0.0),
                "anomaly_score": r.pop(P + "anomaly_score", 0.0),
                "trim_fraction": r.pop(P + "trim_fraction", 0.0),
            }
            scores = []
            while f"{P}score_{len(scores)}" in r:
                scores.append(r.pop(f"{P}score_{len(scores)}"))
            if scores:
                rb["scores"] = scores
            for k in [k for k in r if k.startswith(P)]:
                r.pop(k)
            rows.append(rb)
        self.robust_records.extend(rows)
        return rows

    def _observe_robust(self, rows):
        """The inline quarantine controller: a learner whose windowed
        mean anomaly score exceeds ``score_ratio`` x the peer median for
        ``quarantine_after`` consecutive flush windows is masked out of
        the membership schedule on the spot — graceful degradation with
        no HealthHalt round-trip and no rollback (the robust mix already
        bounded its influence; quarantine just stops paying its wire and
        compute). Needs a membership-capable run (an elastic schedule or
        chaos crash faults) — quietly inert otherwise."""
        import numpy as np

        rcfg = self.mcfg.robust
        if rcfg is None or rcfg.quarantine_after <= 0:
            return
        sc = [row["scores"] for row in rows if "scores" in row]
        if not sc:
            return
        mean = np.asarray(sc, np.float64).mean(axis=0)  # (L,)
        med = float(np.median(mean))
        anomalous = mean > rcfg.score_ratio * max(med, 1e-30)
        if self._anomaly_streak is None:
            self._anomaly_streak = np.zeros(mean.shape[0], np.int64)
        self._anomaly_streak = np.where(
            anomalous, self._anomaly_streak + 1, 0
        )
        hit = [
            j for j in range(mean.shape[0])
            if self._anomaly_streak[j] >= rcfg.quarantine_after
            and j not in self.quarantined
        ]
        topo = self.state.topo
        if not hit or not (isinstance(topo, dict) and "membership" in topo):
            return
        m = np.asarray(topo["membership"], np.float32).copy()
        m[:, hit] = 0.0
        if (m.sum(axis=1) < 1.0).any():
            return  # never quarantine away the last present learner(s)
        step = int(rows[-1]["meta_step"])
        self.set_membership(m)
        for j in hit:
            self.quarantined[j] = step
        rows[-1]["quarantined"] = sorted(self.quarantined)

    def restore(self, path):
        self.state = load_state(path, self.state)
        # a sink opened after restore appends to the existing run log
        # instead of truncating it (resume continues the same run)
        self._restored = True

    def set_membership(self, membership):
        """Replace the elastic membership schedule in-state (the
        supervisor's quarantine lever, DESIGN.md §13): new (period, L)
        0/1 rows are swapped into ``MetaState.topo["membership"]`` —
        masked through the stochastic-complement rewiring like any other
        absence — and the topology's host-side mirror (the async server's
        effective-work replay) is reset to match. Only valid on a run
        that has a membership schedule (an elastic config or chaos crash
        faults); must preserve the schedule's shape."""
        import numpy as np

        topo = self.state.topo
        if not (isinstance(topo, dict) and "membership" in topo):
            raise ValueError(
                "set_membership needs a run with an elastic membership "
                "schedule (TopologyConfig.elastic or chaos crash faults)"
            )
        m = np.asarray(membership, np.float32)
        old = np.asarray(topo["membership"])
        if m.shape != old.shape:
            raise ValueError(
                f"membership shape {m.shape} != schedule shape {old.shape}"
            )
        if (m.sum(axis=1) < 1.0).any():
            raise ValueError(
                "quarantine membership leaves a row with no learner present"
            )
        from dataclasses import replace as _dc_replace

        new_topo = dict(topo)
        new_topo["membership"] = jnp.asarray(m)
        self.state = _dc_replace(self.state, topo=new_topo)
        if getattr(self._topology, "membership", None) is not None:
            self._topology.membership = m
            if hasattr(self._topology, "_sim_clock"):
                # invalidate the async server's completed-work replay —
                # it re-simulates from tick 0 under the new schedule
                self._topology._sim_clock = self._topology.start_clock.copy()
                self._topology._sim_t = 0
                self._topology._sim_cum = []

    def emit(self, record: dict):
        """Append one structured record to the run's telemetry sink (the
        supervisor's fault/recovery records ride the same log as the
        step rows). No-op when no sink is configured/open."""
        if self._sink is not None:
            self._sink.append(record)
            self._sink.flush()

    def close(self):
        """Flush and close the telemetry sink (idempotent)."""
        if self._sink is not None:
            self._sink.close()
            self._sink = None
