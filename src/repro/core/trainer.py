"""High-level training driver tying together model, data, meta-optimizer,
checkpointing and (optionally) a device mesh.

On a real cluster the same Trainer runs under the production mesh from
``repro.launch.mesh`` (the learner axis sharded over data/pod axes); on CPU
it runs the identical jitted program on one device — the SPMD program is
the same, which is what the multi-pod dry-run proves.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import load_state, save_state
from repro.configs.base import MAvgConfig, TrainConfig
from repro.core.meta import init_state, make_meta_step


class Trainer:
    def __init__(
        self,
        train_cfg: TrainConfig,
        loss_fn: Callable,
        init_params_fn: Callable,
        batch_fn: Callable,  # (rng, step) -> batches (L, K, B, ...)
        lr_schedule: Optional[Callable] = None,
        mesh=None,
        state_shardings=None,
    ):
        self.cfg = train_cfg
        self.mcfg: MAvgConfig = train_cfg.mavg
        self.loss_fn = loss_fn
        self.batch_fn = batch_fn
        self.lr_schedule = lr_schedule
        self.mesh = mesh

        rng = jax.random.PRNGKey(train_cfg.seed)
        self.data_rng, init_rng = jax.random.split(rng)
        params = init_params_fn(init_rng)
        self.state = init_state(params, self.mcfg)
        step_fn = make_meta_step(loss_fn, self.mcfg)

        def jit_step(state, batches, lr):
            return step_fn(state, batches, lr=lr)

        # donation + the state in==out sharding pairing come from the one
        # assembly point every launcher uses (launch/specs.py): under
        # mcfg.donate the input MetaState is donated to the step and
        # updated in place (zero-copy meta phase, DESIGN.md §10);
        # everything below (run/metrics/checkpoints/restore) works off
        # the returned state only, never a pre-step one
        from repro.launch.specs import meta_step_jit_kwargs

        kwargs = meta_step_jit_kwargs(
            self.mcfg,
            state_shardings if mesh is not None else None,
            n_extra_args=2,
        )
        self._step = jax.jit(jit_step, **kwargs)
        self.history: list[dict] = []

    def run(self, meta_steps: Optional[int] = None, log=print):
        """Drive ``meta_steps`` jitted steps.

        Metrics stay on-device until a ``log_every`` boundary (or the end
        of the run): materializing ``float(v)`` per step blocks the host
        on device completion and serializes dispatch, so the in-between
        steps are enqueued back-to-back and only the boundary step pays
        the sync. ``history`` still holds plain float dicts afterwards.

        Donation contract (``MAvgConfig.donate``): the state handed to
        ``self._step`` is dead the moment the call is dispatched — its
        planes are aliased into the returned state's. Everything in this
        loop therefore works off the RETURNED state: the step counter is
        read once before any dispatch, metrics are step outputs, the
        checkpoint cadence is host arithmetic on python ints, and
        ``save_state`` snapshots the state a step returned (never an
        input that a later dispatch may have consumed). ``self.state``
        always rebinds to the live returned state, so ``restore``/resume
        and post-run eval see valid buffers.
        """
        n = meta_steps if meta_steps is not None else self.cfg.meta_steps
        t0 = time.time()
        start = int(self.state.step)  # the only pre-loop host sync
        pending: list[tuple[int, dict]] = []

        def flush():
            for s, dev_metrics in pending:
                metrics = {k: float(v) for k, v in dev_metrics.items()}
                metrics["meta_step"] = s
                metrics["samples"] = (
                    (s + 1)
                    * self.mcfg.num_learners
                    * self.mcfg.k_steps
                    * self.cfg.batch_per_learner
                )
                self.history.append(metrics)
            pending.clear()

        try:
            for i in range(n):
                step = start + i
                rng = jax.random.fold_in(self.data_rng, step)
                batches = self.batch_fn(rng, step)
                lr = (
                    self.lr_schedule(step)
                    if self.lr_schedule
                    else jnp.float32(self.mcfg.learner_lr)
                )
                self.state, metrics = self._step(self.state, batches, lr)
                pending.append((step, metrics))
                if log and (step % self.cfg.log_every == 0):
                    flush()
                    m = self.history[-1]
                    log(
                        f"[{self.mcfg.algorithm}] meta_step={step} "
                        f"loss={m['loss']:.4f} "
                        f"gnorm={m.get('grad_norm', 0):.3f} "
                        f"({time.time() - t0:.1f}s)"
                    )
                if (
                    self.cfg.checkpoint_dir
                    and self.cfg.checkpoint_every
                    and (step + 1) % self.cfg.checkpoint_every == 0
                ):
                    save_state(self.cfg.checkpoint_dir, self.state, step + 1)
        finally:
            flush()  # metrics of completed steps survive an interrupt
        return self.history

    def restore(self, path):
        self.state = load_state(path, self.state)
