# The paper's primary contribution: M-AVG (K-step averaging SGD with block
# momentum) and its baselines, as a composable meta-optimizer.
from repro.core.meta import MetaState, init_state, make_meta_step, meta_step
from repro.core.supervisor import (
    RecoveryExhausted,
    RecoveryPlan,
    RecoveryPolicy,
    Supervisor,
)
from repro.core.trainer import Trainer
