"""Supervised auto-recovery: rollback to the verified checkpoint chain
plus bounded-retry policies (DESIGN.md §13).

The Trainer already turns a sick run into a structured event: a fatal
health rule (obs.health) halts at a flush boundary with a resumable
checkpoint and a ``HealthHalt`` carrying the triggering alert. The
``Supervisor`` closes the loop — it is the process-level analogue of the
in-step finite guard:

    halt/verify-failure -> roll back to the newest VERIFIED snapshot
    strictly BEFORE the fault step (one snapshot further back per retry
    that stalls without progress — see ``run``) -> apply a recovery
    policy -> rebuild the trainer -> run the REMAINING steps (equal
    effective samples by construction) -> repeat, at most
    ``RecoveryPolicy.max_retries`` times -> ``RecoveryExhausted``.

Recovery policies compose per retry:

* **re-salt the data stream** (``TrainConfig.data_salt``): the replayed
  batches are redrawn, and the chaos ``FaultSchedule`` drops its
  non-sticky faults — a transient fault does not recur, which is exactly
  how real rollback-recovery behaves (the re-read batch is clean).
* **quarantine the suspect learner** through the elastic membership mask
  (``Trainer.set_membership`` — the absence is re-wired around via the
  stochastic complement like any other churn, §8), for
  ``quarantine_steps`` of probation, then readmit.
* **exponential lr / momentum backoff** (``lr_backoff`` /
  ``momentum_backoff`` multiply ``RecoveryPlan.lr_scale`` /
  ``momentum_scale`` per retry — the trainer factory applies them).

Every transition is emitted into the run's telemetry sink as a schema-
valid ``fault`` / ``recovery`` record (tools/check_telemetry.py; the
``recovery`` record is also the checker's marker that the trajectory
legitimately rewound). The ROADMAP's K/mu autotuner consumes these the
same way it consumes alerts: machine-readable "what broke, what the
supervisor did about it".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.checkpoint import (
    CheckpointVerifyError,
    checkpoint_step,
    verified_checkpoints,
    verify_checkpoint,
)
from repro.obs import HealthHalt, make_monitor


@dataclass(frozen=True)
class RecoveryPolicy:
    """What the supervisor is allowed to do per retry.

    max_retries        bounded: retry N times, then RecoveryExhausted
    lr_backoff         RecoveryPlan.lr_scale multiplier per retry
    momentum_backoff   RecoveryPlan.momentum_scale multiplier per retry
    quarantine_steps   probation window (meta steps) a suspect learner is
                       masked out of membership after rollback; 0 = never
                       quarantine
    readmit_clean_windows  quarantine hysteresis: the learner must sit
                       out M consecutive clean probation windows before
                       readmission — the total mask spans
                       ``quarantine_steps * M`` steps. 1 (the default)
                       is the single-window behavior, bit-for-bit.
    resalt_data        bump TrainConfig.data_salt per retry (redraw the
                       replayed batches; transient chaos faults drop out)
    """

    max_retries: int = 3
    lr_backoff: float = 0.5
    momentum_backoff: float = 1.0
    quarantine_steps: int = 0
    readmit_clean_windows: int = 1
    resalt_data: bool = True

    def __post_init__(self):
        assert self.max_retries >= 0, self.max_retries
        assert 0.0 < self.lr_backoff <= 1.0, self.lr_backoff
        assert 0.0 < self.momentum_backoff <= 1.0, self.momentum_backoff
        assert self.quarantine_steps >= 0, self.quarantine_steps
        assert self.readmit_clean_windows >= 1, self.readmit_clean_windows


@dataclass(frozen=True)
class RecoveryPlan:
    """One attempt's inputs — what the supervisor hands the trainer
    factory. Attempt 0 is the identity plan (scales 1.0, salt 0, no
    quarantine, fresh start)."""

    attempt: int = 0
    lr_scale: float = 1.0
    momentum_scale: float = 1.0
    data_salt: int = 0
    quarantine: tuple = field(default_factory=tuple)
    resume_path: Optional[str] = None


class RecoveryExhausted(RuntimeError):
    """The retry budget ran out (a sticky fault re-fired on every
    attempt). Carries the last fault record."""

    def __init__(self, fault: dict, attempts: int):
        self.fault = dict(fault)
        self.attempts = attempts
        super().__init__(
            f"supervised recovery exhausted after {attempts} attempt(s); "
            f"last fault: {fault.get('fault')!r} at meta_step "
            f"{fault.get('meta_step')}"
        )


class Supervisor:
    """Wraps ``Trainer.run`` in the rollback/retry loop.

    make_trainer   ``RecoveryPlan -> Trainer`` factory. Must honor the
                   plan: ``data_salt`` into TrainConfig, ``lr_scale`` /
                   ``momentum_scale`` into the lr schedule / mu. The
                   supervisor itself handles ``resume_path`` (restore)
                   and ``quarantine`` (set_membership).
    target_steps   the run completes when ``state.step`` reaches this —
                   each attempt runs only the REMAINING steps, so the
                   supervised run consumes equal effective samples.
    checkpoint_dir the verified chain rollback scans. The factory's
                   TrainConfig should checkpoint into the same directory.
    policy         RecoveryPolicy (default: 3 retries, lr halving,
                   re-salt, no quarantine).
    suspect_fn     optional ``meta_step -> learner | None`` attribution
                   hook for quarantine; defaults to the trainer's chaos
                   schedule oracle when one is attached (see
                   FaultSchedule.suspect).
    """

    def __init__(self, make_trainer: Callable[[RecoveryPlan], "object"], *,
                 target_steps: int, checkpoint_dir: Optional[str],
                 policy: Optional[RecoveryPolicy] = None,
                 suspect_fn: Optional[Callable[[int], Optional[int]]] = None):
        self.make_trainer = make_trainer
        self.target_steps = int(target_steps)
        self.checkpoint_dir = checkpoint_dir
        self.policy = policy or RecoveryPolicy()
        self.suspect_fn = suspect_fn
        # the supervisor's own watchdog surface: checkpoint-verify
        # failures and retry exhaustion become the same schema-valid
        # alert records every other failure mode gets (obs.health rules
        # checkpoint_verify_failed / recovery_exhausted), emitted into
        # the run log next to the fault/recovery records. halt=False:
        # the supervisor IS the halt handler.
        self.monitor = make_monitor(halt=False)
        self.records: list[dict] = []  # fault/recovery/alert, in order

    # ------------------------------------------------------------------
    def _emit(self, trainer, record: dict) -> None:
        self.records.append(dict(record))
        trainer.emit(record)

    def _alert(self, trainer, meta_step: int, metric: str) -> None:
        fired = self.monitor.observe([{"meta_step": meta_step, metric: 1.0}])
        for a in fired:
            self._emit(trainer, a)

    def _suspect(self, trainer, fault_step: int) -> Optional[int]:
        if self.suspect_fn is not None:
            return self.suspect_fn(fault_step)
        sched = getattr(trainer, "_chaos_schedule", None)
        return sched.suspect(fault_step) if sched is not None else None

    def _quarantine(self, trainer, learners, start: int) -> None:
        """Mask ``learners`` out of membership for the probation span
        ``[start, start + quarantine_steps * readmit_clean_windows)``,
        keeping every row at least one learner strong; rows after the
        span are untouched, so the learner is readmitted automatically
        only after sitting out M consecutive clean windows (hysteresis —
        a marginal learner doesn't flap in and out every window). Skipped
        (with a note in the recovery record) on runs without a membership
        schedule."""
        import numpy as np

        topo = trainer.state.topo
        if not (isinstance(topo, dict) and "membership" in topo):
            return
        m = np.array(np.asarray(topo["membership"]), np.float32)
        T = m.shape[0]
        span = self.policy.quarantine_steps * self.policy.readmit_clean_windows
        for s in range(start, start + span):
            row = m[s % T].copy()
            row[list(learners)] = 0.0
            if row.sum() >= 1.0:  # never quarantine the last learner
                m[s % T] = row
        trainer.set_membership(m)

    # ------------------------------------------------------------------
    def run(self, log=print):
        """Drive attempts until ``target_steps`` is reached. Returns
        ``(trainer, history)`` — the final (open) trainer and the
        concatenated flushed metric records of every attempt. Raises
        ``RecoveryExhausted`` when the retry budget runs out."""
        policy = self.policy
        plan = RecoveryPlan()
        history: list[dict] = []
        # rollback-point selection state: faults that recur without
        # forward progress deepen the walk-back (see below)
        walkback = 0
        last_fault_step: Optional[int] = None
        while True:
            trainer = self.make_trainer(plan)
            if plan.resume_path is not None:
                trainer.restore(plan.resume_path)
            elif plan.attempt > 0:
                # scratch retry (no verified snapshot yet): still append
                # to the same run log — the recovery record documents the
                # rewind to step 0
                trainer._restored = True
            start = int(trainer.state.step)
            remaining = self.target_steps - start
            if remaining <= 0:
                return trainer, history
            if plan.quarantine:
                self._quarantine(trainer, plan.quarantine, start)
            if plan.attempt > 0 and history and \
                    getattr(trainer, "_monitor", None) is not None:
                # arm the retry's rel_* watchdogs with the pre-rollback
                # medians: a rebuilt trainer's monitor starts empty, and
                # a short retry can diverge to garbage entirely inside
                # ``min_history`` — seeding the healthy history below the
                # resume step makes loss_divergence fire on the FIRST
                # replayed step of a still-sick state
                trainer._monitor.seed(
                    r for r in history
                    if r.get("meta_step", self.target_steps) < start
                )
            try:
                trainer.run(remaining, log=log)
                history.extend(trainer.history)
                return trainer, history
            except (HealthHalt, CheckpointVerifyError) as e:
                history.extend(trainer.history)
                fault_step = int(trainer.state.step)
                attempt = plan.attempt + 1
                if isinstance(e, HealthHalt):
                    fault = {
                        "kind": "fault",
                        "fault": e.alert.get("rule"),
                        "layer": "health",
                        "meta_step": fault_step,
                        "attempt": plan.attempt,
                        "metric": e.alert.get("metric"),
                        "value": e.alert.get("value"),
                    }
                    # the halt snapshot of a sick state may itself be
                    # unverifiable (NaN planes) — probe it so the
                    # checkpoint_verify_failed watchdog has signal
                    if e.checkpoint_path is not None:
                        try:
                            verify_checkpoint(e.checkpoint_path)
                        except CheckpointVerifyError:
                            self._alert(
                                trainer, fault_step, "ckpt_verify_failed"
                            )
                else:
                    fault = {
                        "kind": "fault",
                        "fault": "checkpoint_verify_failed",
                        "layer": "checkpoint",
                        "meta_step": fault_step,
                        "attempt": plan.attempt,
                        "detail": str(e),
                    }
                    self._alert(trainer, fault_step, "ckpt_verify_failed")
                suspect = self._suspect(trainer, fault_step)
                if suspect is not None:
                    fault["learner"] = suspect
                self._emit(trainer, fault)

                if attempt > policy.max_retries:
                    self._alert(trainer, fault_step, "recovery_exhausted")
                    trainer.close()
                    raise RecoveryExhausted(fault, plan.attempt + 1) from e

                # Rollback target: the newest VERIFIED snapshot strictly
                # BEFORE the fault step. Integrity alone is not enough —
                # the emergency halt snapshot of a diverged-but-finite
                # state (e.g. a mis-scaled payload that blew the params
                # up without minting a NaN) verifies cleanly, and naive
                # latest-verified would "roll back" INTO it, replaying
                # the sick state on every retry. And when a retry halts
                # again without progressing past the previous fault, the
                # snapshot it resumed from is itself suspect (the
                # corruption landed before it was cut): walk one snapshot
                # further back per stalled retry, down to a scratch
                # restart.
                if last_fault_step is not None and \
                        fault_step <= last_fault_step:
                    walkback += 1
                else:
                    walkback = 0
                last_fault_step = fault_step
                chain = (
                    verified_checkpoints(
                        self.checkpoint_dir, before_step=fault_step
                    )
                    if self.checkpoint_dir else []
                )
                if walkback:
                    chain = chain[:-walkback] if walkback < len(chain) else []
                resume = chain[-1] if chain else None
                resume_step = 0 if resume is None else checkpoint_step(resume)
                quarantine = plan.quarantine
                actions = ["rollback"]
                if policy.quarantine_steps > 0 and suspect is not None:
                    quarantine = tuple(sorted(set(quarantine) | {suspect}))
                    actions.append("quarantine")
                if policy.lr_backoff < 1.0:
                    actions.append("lr_backoff")
                if policy.momentum_backoff < 1.0:
                    actions.append("momentum_backoff")
                if policy.resalt_data:
                    actions.append("resalt")
                plan = RecoveryPlan(
                    attempt=attempt,
                    lr_scale=plan.lr_scale * policy.lr_backoff,
                    momentum_scale=(
                        plan.momentum_scale * policy.momentum_backoff
                    ),
                    data_salt=(
                        plan.data_salt + 1 if policy.resalt_data
                        else plan.data_salt
                    ),
                    quarantine=quarantine,
                    resume_path=resume,
                )
                self._emit(trainer, {
                    "kind": "recovery",
                    "policy": "+".join(actions),
                    "attempt": attempt,
                    "meta_step": resume_step,
                    "resume_path": resume,
                    "lr_scale": plan.lr_scale,
                    "momentum_scale": plan.momentum_scale,
                    "data_salt": plan.data_salt,
                    "quarantine": list(quarantine),
                })
                trainer.close()
                if log:
                    log(
                        f"[supervisor] {fault['fault']} at meta_step "
                        f"{fault_step}; attempt {attempt}/"
                        f"{policy.max_retries}: {'+'.join(actions)} -> "
                        f"resume at {resume_step}"
                    )
