"""The paper's contribution: M-AVG (Algorithm 1) and its baselines, as one
composable meta-optimizer over an arbitrary loss function.

Algorithms
----------
mavg         Algorithm 1: K local SGD steps per learner, then
             a = mean_j w_j; d = a - w~; v = mu v + d; w~ += v; reset.
kavg         Zhou & Cong 2017 (the paper's baseline): mavg with mu = 0.
sync         synchronous MSGD: mavg with K = 1 (identical math, kept as an
             explicit alias so benchmarks can name it).
mavg_mlocal  beyond-paper / the paper's section-V note: learner-level MSGD
             inside the K-step loop, block momentum on top.
eamsgd       Zhang et al. 2015 elastic averaging with center momentum
             (the paper's strongest baseline in section IV) — an alias
             onto the async server's elastic update rule
             (repro.topology.async_server, DESIGN.md §12).
downpour     Dean et al. 2012, simulated with deterministic bounded
             staleness (true async is unexpressible under SPMD; staleness
             is the quantity the convergence analyses bound — DESIGN.md
             §4/§12) — an alias onto the async server's staleness-decayed
             update with decay 1.0.

This module contains NO per-algorithm meta-update branches: every
algorithm, legacy baselines included, routes through the Topology
protocol (repro.topology.make_topology resolves the aliases).

The learner dimension is a leading pytree axis of size L = P (the paper's
number of processors). Under pjit that axis is sharded over the mesh's
learner axes, so the K inner steps emit no cross-learner collectives and
the meta averaging is one all-reduce — the paper's communication model.
That all-reduce is owned by a pluggable ``repro.comm`` Reducer (dense /
int8 / fp8 / top-k, with optional error feedback whose residual rides in
``MetaState.comm_residual`` — DESIGN.md §5), selected via
``MAvgConfig.comm`` or injected into ``meta_step``/``make_meta_step``.
*Which* learners average with which, and how often, is owned by the
``repro.topology`` subsystem (flat all-reduce / hierarchical two-level
M-AVG / decentralized gossip — DESIGN.md §7), selected via
``MAvgConfig.topology``; its buffers ride in ``MetaState.topo``.

Under ``MAvgConfig.packed`` (the default) the whole meta plane is the
packed flat buffer of ``repro.pack`` (DESIGN.md §9): every state field is
one lane-aligned (rows, 128) array (stacked (L, rows, 128) along the
learner axis) and the model pytree exists only inside ``_local_phase``.
Because a raw array is itself a pytree, all the meta algebra below runs
unchanged on either representation — what changes is the cost: one
whole-model kernel pass per op instead of one per leaf.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MAvgConfig
from repro.pack import make_pack_spec
from repro.utils import (
    tree_broadcast_learners,
    tree_cast,
    tree_norm,
    tree_zeros_like,
)

LossFn = Callable[..., tuple[jnp.ndarray, dict]]  # (params, batch) -> (loss, aux)


@jax.tree_util.register_dataclass
@dataclass
class MetaState:
    """Full state of the distributed trainer.

    global_params: w~ (meta dtype, f32)
    momentum:      v, the block-momentum buffer (mavg/eamsgd) or None
    learners:      stacked learner copies, leading axis L
    local_momentum: learner-level momentum stacks (mavg_mlocal) or None
    step:          meta iteration n
    comm_residual: per-learner error-feedback residual e_j of the comm
                   reducer (L, ...) f32, or None when EF is off
    topo:          topology buffer pytree (repro.topology — group params /
                   momentum under hierarchical, per-learner params /
                   momentum under gossip, logical clocks + anchor planes
                   under the async server), or None under flat
    spec:          STATIC repro.pack.PackSpec of the packed flat
                   meta-plane, or None on the legacy per-leaf path. When
                   set, every plane above is a single lane-aligned
                   (rows, 128) buffer (stacked (L, rows, 128) along the
                   learner axis) instead of a parameter pytree; the model
                   pytree exists only inside the local phase
                   (DESIGN.md §9). Static: part of the pytree structure,
                   not a leaf — jit caches on it and checkpoints skip it.
    """

    global_params: Any
    momentum: Any
    learners: Any
    local_momentum: Any
    step: jnp.ndarray
    comm_residual: Any = None
    topo: Any = None
    spec: Any = field(default=None, metadata=dict(static=True))


def init_state(params, cfg: MAvgConfig, reducer=None,
               topology=None) -> MetaState:
    """Meta state (w~, v) in cfg.meta_dtype (f32 — Theorem 1's momentum
    variance is precision-sensitive); learner copies in cfg.compute_dtype
    (bf16 on TPU: halves every weight collective and the L-fold copy
    memory; the meta average casts back up to f32).

    Pass the same ``reducer``/``topology`` you inject into
    meta_step/make_meta_step (if any) so the matching error-feedback /
    topology buffers are allocated; otherwise ``cfg.comm``/``cfg.topology``
    decide.

    Under ``cfg.packed`` (the default) the param pytree is packed once
    into the flat meta-plane here, and every state buffer below is a
    single (rows, 128) / (L, rows, 128) array; the static PackSpec rides
    in ``MetaState.spec`` so meta_step can unpack at the learner
    boundary and eval code can recover the model pytree
    (repro.pack.unpack_params).
    """
    spec = None
    if cfg.packed:
        spec = make_pack_spec(params, dtype=cfg.meta_dtype)
        params = spec.pack(params)
    # the state must OWN its buffers: a same-dtype astype is a no-op that
    # aliases the caller's param arrays, and under cfg.donate the jitted
    # step would then delete the caller's buffers with the donated state
    # (caught by tests/test_zero_copy.py). jnp.array copies
    # unconditionally; one extra whole-model copy, once per run.
    gp = jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.dtype(cfg.meta_dtype)), params
    )
    learners = tree_broadcast_learners(
        tree_cast(gp, cfg.compute_dtype), cfg.num_learners
    )
    if topology is None:
        from repro.topology import make_topology

        topology = make_topology(cfg, reducer)
    comm_residual, topo = topology.init_buffers(gp, cfg)
    if cfg.robust is not None and cfg.robust.clip_mult > 0.0:
        # the norm clip's trailing-median budget ring (repro.robust,
        # DESIGN.md §14) rides in MetaState.topo regardless of topology —
        # merged here so the layout changes only when the feature is on
        from repro.robust import robust_ring_buffers

        topo = {**(topo or {}), **robust_ring_buffers(cfg.robust)}
    return MetaState(
        global_params=gp,
        momentum=tree_zeros_like(gp),
        learners=learners,
        local_momentum=(
            tree_zeros_like(learners) if cfg.algorithm == "mavg_mlocal" else None
        ),
        step=jnp.zeros((), jnp.int32),
        comm_residual=comm_residual,
        topo=topo,
        spec=spec,
    )


# ---------------------------------------------------------------------------
# local phase: K SGD/MSGD steps per learner, no cross-learner communication
# ---------------------------------------------------------------------------


def _local_phase(loss_fn: LossFn, learners, local_mom, batches, cfg: MAvgConfig,
                 lr, steps=None, spec=None):
    """batches: pytree with leaves (L, K, B_local, ...).

    ``steps``: optional (L,) int32 active-step counts (heterogeneous
    per-group K_g / elastic membership — repro.topology): learner j
    applies only the first steps[j] of the K scanned updates, the rest
    are masked with ``where`` so the compiled SPMD program is identical
    for every schedule (an absent learner runs 0 steps). Loss/grad-norm
    means count active steps only. ``steps`` may be traced (membership
    is step-indexed).

    ``spec``: the packed meta-plane layout (repro.pack). The local phase
    is the ONLY place the model pytree exists under packing: each
    learner's (rows, 128) buffer is unpacked to the param tree here
    (loss_fn needs structure), the K-step scan runs on the tree exactly
    as on the per-leaf path (bit-identical update math), and the result
    is repacked once after the scan. Leaves stay in the learner plane's
    compute dtype through the round trip.

    Returns (new learners, new local momentum, mean loss, mean grad-norm,
    per-learner mean loss (L,)) — the per-learner vector feeds the
    ``loss_spread`` telemetry metric (repro.obs): data-heterogeneity and
    straggler divergence show up as spread before they show up in the
    mean.
    """
    if spec is not None:
        ldt = _ldtype(learners)
        unpack = lambda b: spec.unpack(b, dtype=b.dtype)
        repack = lambda t: spec.pack(t, dtype=ldt)
    else:
        unpack = repack = lambda t: t

    def sgd_update(w, mom, g):
        # update math in f32, stored back in the learner dtype (bf16
        # learner copies keep collectives/memory at half cost)
        if cfg.local_momentum > 0.0:
            mom = jax.tree.map(
                lambda m, gi: (
                    cfg.local_momentum * m.astype(jnp.float32)
                    - lr * gi.astype(jnp.float32)
                ).astype(m.dtype),
                mom, g,
            )
            w = jax.tree.map(
                lambda wi, m: (wi + m.astype(wi.dtype)), w, mom
            )
        else:
            w = jax.tree.map(
                lambda wi, gi: (
                    wi.astype(jnp.float32) - lr * gi.astype(jnp.float32)
                ).astype(wi.dtype),
                w, g,
            )
        return w, mom

    def one_learner(w, mom, bks):
        def step(carry, b):
            w, mom = carry
            (loss, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(w, b)
            gnorm = tree_norm(g)
            w, mom = sgd_update(w, mom, g)
            return (w, mom), (loss, gnorm)

        w, mom = unpack(w), unpack(mom)
        (w, mom), (losses, gnorms) = lax.scan(step, (w, mom), bks)
        return repack(w), repack(mom), losses.mean(), gnorms.mean()

    def one_learner_masked(w, mom, bks, s):
        k = jax.tree.leaves(bks)[0].shape[0]

        def step(carry, xs):
            w, mom = carry
            b, i = xs
            (loss, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(w, b)
            gnorm = tree_norm(g)
            w_upd, mom_upd = sgd_update(w, mom, g)
            keep = i < s
            w = jax.tree.map(lambda n, o: jnp.where(keep, n, o), w_upd, w)
            mom = jax.tree.map(lambda n, o: jnp.where(keep, n, o), mom_upd, mom)
            return (w, mom), (loss, gnorm, keep.astype(jnp.float32))

        w, mom = unpack(w), unpack(mom)
        (w, mom), (losses, gnorms, act) = lax.scan(
            step, (w, mom), (bks, jnp.arange(k))
        )
        return (repack(w), repack(mom),
                (losses * act).sum(), (gnorms * act).sum(), act.sum())

    mom_in = tree_zeros_like(learners) if local_mom is None else local_mom
    if steps is None:
        w, mom, loss_l, gnorm = jax.vmap(one_learner)(learners, mom_in, batches)
        loss, gnorm = loss_l.mean(), gnorm.mean()
    else:
        w, mom, lsum, gsum, asum = jax.vmap(one_learner_masked)(
            learners, mom_in, batches, steps
        )
        denom = jnp.maximum(asum.sum(), 1.0)
        loss, gnorm = lsum.sum() / denom, gsum.sum() / denom
        # per-learner mean over that learner's ACTIVE steps; an absent
        # learner (0 active steps) reports 0 and is masked out of the
        # spread metric by the caller via the active counts
        loss_l = lsum / jnp.maximum(asum, 1.0)
    active = None if steps is None else (asum > 0)
    return (w, (mom if local_mom is not None else None), loss, gnorm,
            loss_l, active)


def _learner_finite_mask(tree):
    """(L,) bool — True where every float element of learner j's planes is
    finite. None when the tree has no float leaves."""
    flags = None
    for x in jax.tree.leaves(tree):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            continue
        ok = jnp.all(
            jnp.isfinite(x.astype(jnp.float32)).reshape(x.shape[0], -1),
            axis=1,
        )
        flags = ok if flags is None else (flags & ok)
    return flags


def _tree_where_learners(ok, new, old):
    """Leafwise select on the (L,) mask broadcast over trailing dims."""

    def sel(n, o):
        m = ok.reshape((n.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(sel, new, old)


def _finite_guard(learners, local_mom, gp, metrics, L):
    """The in-step skip-and-decay barrier (DESIGN.md §13): a learner whose
    post-local-phase planes (or local momentum) carry NaN/Inf is reset to
    the broadcast global params — zero displacement into the mix, so the
    poisoned block is skipped and (with every learner bad) the block
    momentum pure-decays — and its local momentum is zeroed. This is the
    structural guarantee that a non-finite value can never cross from the
    learner plane into ``MetaState.global_params``: the mean of finite
    planes is finite. On a clean step the mask is all-true and every
    ``where`` returns its first argument bitwise (pinned)."""
    ok = _learner_finite_mask(learners)
    if local_mom is not None:
        mok = _learner_finite_mask(local_mom)
        if mok is not None:
            ok = mok if ok is None else (ok & mok)
    if ok is None:
        return learners, local_mom, metrics
    clean = tree_broadcast_learners(tree_cast_like(gp, learners), L)
    learners = _tree_where_learners(ok, learners, clean)
    if local_mom is not None:
        zeros = jax.tree.map(jnp.zeros_like, local_mom)
        local_mom = _tree_where_learners(ok, local_mom, zeros)
    metrics["nonfinite_learners"] = (
        jnp.float32(L) - ok.sum().astype(jnp.float32)
    )
    return learners, local_mom, metrics


def tree_cast_like(tree, like):
    """``tree`` cast leafwise to the dtypes of ``like``'s leaves (shapes
    may differ — only dtype is taken)."""
    like_leaves = jax.tree.leaves(like)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return jax.tree_util.tree_unflatten(
        treedef,
        [x.astype(y.dtype) for x, y in zip(leaves, like_leaves)],
    )


def _loss_spread(loss_l, active):
    """max - min of the per-learner mean losses, over ACTIVE learners only
    (elastic membership: an absent learner ran 0 steps and reports no
    loss). 0 when fewer than one learner is active. The telemetry signal
    for data heterogeneity / straggler divergence (repro.obs)."""
    if active is None:
        return jnp.max(loss_l) - jnp.min(loss_l)
    hi = jnp.max(jnp.where(active, loss_l, -jnp.inf))
    lo = jnp.min(jnp.where(active, loss_l, jnp.inf))
    return jnp.where(jnp.any(active), hi - lo, 0.0)


# ---------------------------------------------------------------------------
# meta updates
# ---------------------------------------------------------------------------


def meta_step(state: MetaState, batches, *, loss_fn: LossFn, cfg: MAvgConfig,
              lr=None, reducer=None, topology=None,
              chaos=None) -> tuple[MetaState, dict]:
    """One meta-iteration n -> n+1 of Algorithm 1 (or a baseline).

    batches: pytree with leaves (L, K, B_local, ...) — K local mini-batches
    for each of the L learners. ``reducer`` overrides the comm scheme
    built from ``cfg.comm`` (repro.comm.make_reducer); ``topology``
    overrides the mixing structure built from ``cfg.topology``
    (repro.topology.make_topology). Prefer make_meta_step, which builds
    both once per trace.

    ``chaos``: optional payload corruptor (repro.chaos.PayloadCorruptor)
    called on the post-local-phase learner planes — the comm-layer fault
    injection point, placed exactly where the reducer picks the payload
    up. ``cfg.finite_guard`` then screens the (possibly corrupted)
    planes before the mix (see ``_finite_guard``).
    """
    lr = jnp.float32(cfg.learner_lr) if lr is None else lr
    if topology is None:
        from repro.topology import make_topology

        topology = make_topology(cfg, reducer)
    # synchrony is the topology's axis (DESIGN.md §12): it may mask
    # trailing local steps per learner (per-group K_g, elastic
    # membership) or mask whole K-blocks (the async server's clocks —
    # a learner runs its K steps only on the tick it fires)
    steps = topology.local_steps(state.topo, state.step)
    with jax.named_scope("obs.local_phase"):
        learners, local_mom, loss, gnorm, loss_l, active = _local_phase(
            loss_fn, state.learners, state.local_momentum, batches, cfg, lr,
            steps=steps, spec=state.spec,
        )
    gp, v = state.global_params, state.momentum
    comm_res = state.comm_residual
    topo = state.topo
    metrics = {
        "loss": loss,
        "grad_norm": gnorm,
        "loss_spread": _loss_spread(loss_l, active),
    }

    if chaos is not None:
        with jax.named_scope("chaos.payload"):
            learners = chaos(learners, state.step)
    if cfg.finite_guard:
        with jax.named_scope("chaos.finite_guard"):
            learners, local_mom, metrics = _finite_guard(
                learners, local_mom, gp, metrics, cfg.num_learners
            )

    with jax.named_scope("obs.meta_mix"):
        gp, v, learners, comm_res, topo, topo_metrics = topology.mix(
            learners, gp, v, comm_res, topo, step=state.step
        )
    metrics.update(topo_metrics)
    if state.spec is not None:
        # reducers see the packed plane and model their value bytes
        # over its element count, which includes alignment/tail
        # padding; rescale all byte metrics to the real parameter
        # count so packed and per-leaf runs report comparable wire
        # payloads (scale/index bytes are approximated by the same
        # factor — chunk geometry differs between layouts anyway)
        f = sum(state.spec.sizes) / state.spec.total
        for k in list(metrics):
            if k.startswith("comm_bytes"):
                metrics[k] = metrics[k] * f

    state = MetaState(
        global_params=gp, momentum=v, learners=learners,
        local_momentum=local_mom,
        step=state.step + 1, comm_residual=comm_res, topo=topo,
        spec=state.spec,
    )
    return state, metrics


def _ldtype(learners):
    return jax.tree.leaves(learners)[0].dtype


def make_meta_step(loss_fn: LossFn, cfg: MAvgConfig, reducer=None,
                   topology=None, chaos=None):
    """Returns a jit-able ``step(state, batches) -> (state, metrics)``.

    The topology (and through it the comm reducer(s), plus the effective
    block-momentum coefficient — kavg forces mu = 0) is resolved once
    here, not per meta_step call, so every trace reuses the same objects.
    ``chaos`` (a PayloadCorruptor or None) is likewise baked into the
    closure — its schedule arrays become jit constants.
    """
    if topology is None:
        from repro.topology import make_topology

        topology = make_topology(cfg, reducer)
    return partial(meta_step, loss_fn=loss_fn, cfg=cfg, topology=topology,
                   chaos=chaos)


# position of the MetaState argument in every ``step(state, batches, ...)``
# signature this repo jits — the single constant Trainer / launch/specs.py
# thread into jax.jit(donate_argnums=...)
STATE_ARGNUM = 0


def make_jit_meta_step(loss_fn: LossFn, cfg: MAvgConfig, reducer=None,
                       topology=None, chaos=None, *, donate=None,
                       **jit_kwargs):
    """``make_meta_step`` wrapped in ``jax.jit`` with MetaState donation.

    Under ``cfg.donate`` (override with ``donate=``) the input state is
    donated to the step: XLA aliases every (rows, 128) plane of the input
    MetaState to the corresponding output plane and updates it in place,
    so the meta phase holds ONE copy of the state live instead of two —
    peak meta-phase HBM at the 405B packed config drops ~2x (DESIGN.md
    §10, measured in benchmarks/pack_bench.py). Numerics are unchanged:
    donation is pure buffer aliasing.

    The contract the caller signs: the state passed in is DEAD after the
    call (jax raises on re-use). Work off the returned state only —
    metrics, checkpointing, resume (core/trainer.py is the reference
    consumer). Extra ``jit_kwargs`` (in_shardings/out_shardings from
    launch/specs.py) pass through; the state's in_shardings must equal
    its out_shardings or XLA cannot alias the donated buffers.
    """
    step_fn = make_meta_step(loss_fn, cfg, reducer, topology, chaos)
    if cfg.donate if donate is None else donate:
        jit_kwargs.setdefault("donate_argnums", (STATE_ARGNUM,))
    return jax.jit(step_fn, **jit_kwargs)
