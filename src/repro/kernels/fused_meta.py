"""Fused block-momentum + learner-broadcast — the whole packed meta
update of Algorithm 1 (v' = mu v + eta d; w~' = w~ + v'; w_j <- w~' for
every learner j) in a single Pallas pass (DESIGN.md §10).

After the packed block-momentum kernel (block_momentum.py) wrote w~',
``tree_broadcast_learners`` still re-read the full (rows, 128) meta plane
to materialize the (L, rows, 128) learner-dtype reset plane — one extra
whole-model HBM read per meta step that XLA cannot fuse away on TPU
because the momentum update is an opaque pallas_call. This kernel emits
the learner broadcast directly from the VMEM tile that just computed w~':

    block_momentum alone:  read w, v, a       write w', v'      (3R + 2W)
    + tree_broadcast:      read w'            write (L, ...)    (1R + LW)
    fused (this kernel):   read w, v, a       write w', v', (L, ...)
                                                                (3R + (2+L)W)

i.e. one full-plane read fewer per meta step, and the broadcast cast to
the learner compute dtype (bf16 on TPU: half-width writes) happens
in-register. The math is bit-identical to block_momentum_2d followed by
astype + broadcast — the jnp oracle in ref.py shares the exact op order,
so the packed/per-leaf dense parity stays bitwise (tests/test_pack.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _kernel(w_ref, v_ref, a_ref, mu_ref, eta_ref, w_out, v_out, l_out, *,
            nesterov: bool):
    mu = mu_ref[0, 0]
    eta = eta_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    d = a - w
    v_new = mu * v + eta * d
    if nesterov:
        w_new = w + mu * v_new + eta * d
    else:
        w_new = w + v_new
    w_out[...] = w_new.astype(w_out.dtype)
    v_out[...] = v_new.astype(v_out.dtype)
    # the learner reset: every learner's plane gets the cast copy of w~'
    # straight from VMEM — w~' is never re-read from HBM
    l_out[...] = jnp.broadcast_to(
        w_new.astype(l_out.dtype)[None], l_out.shape
    )


def fused_momentum_broadcast_2d(w, v, a, mu, eta, num_learners: int,
                                ldtype, *, nesterov: bool = False,
                                interpret: bool = False,
                                block: int | None = None):
    """w, v, a: (rows, 128) with rows % 8 == 0.

    Returns (w', v', learners) with learners an (L, rows, 128) ``ldtype``
    plane — every learner reset to the new meta params.
    """
    rows, lanes = w.shape
    assert lanes == LANES and rows % 8 == 0, w.shape
    assert v.shape == w.shape and a.shape == w.shape, (v.shape, a.shape)
    L = int(num_learners)
    if block is None:
        block = min(BLOCK_ROWS, rows)
        while rows % block:
            block //= 2
    assert rows % block == 0, (rows, block)
    grid = (rows // block,)
    spec = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    l_spec = pl.BlockSpec((L, block, LANES), lambda i: (0, i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    mu_arr = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, nesterov=nesterov),
        grid=grid,
        in_specs=[spec, spec, spec, scalar_spec, scalar_spec],
        out_specs=[spec, spec, l_spec],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
            jax.ShapeDtypeStruct((L,) + w.shape, jnp.dtype(ldtype)),
        ],
        interpret=interpret,
    )(w, v, a, mu_arr, eta_arr)
