"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled kernels run natively; everywhere else
(this CPU container, tests) they run in ``interpret=True`` mode, which
executes the same kernel body per-block in Python/XLA — bit-comparable
logic, no TPU required. The pure-jnp oracles live in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_momentum as _bm
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_meta as _fm
from repro.kernels import local_sgd as _sgd
from repro.kernels import neighbor_mix as _nm
from repro.kernels import pack_update as _pu
from repro.kernels import quantize as _q
from repro.kernels import ref as _ref
from repro.kernels import robust_reduce as _rr

LANES = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# layout helpers: leaf <-> (rows, 128) padded 2-D
# ---------------------------------------------------------------------------


def _layout(n: int) -> tuple[int, int]:
    """(rows, pad) of the (rows, 128) wire layout for an n-element leaf —
    computed once per call site; same-shaped operands share it."""
    rows = -(-n // LANES)
    rows = -(-rows // 8) * 8  # sublane multiple
    return rows, rows * LANES - n


def _to_2d_as(x, rows: int, pad: int):
    """Apply a precomputed layout to one operand."""
    return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, LANES)


def _to_2d(x):
    rows, pad = _layout(x.size)
    return _to_2d_as(x, rows, pad), x.shape, x.size


def _from_2d(x2, shape, n):
    return x2.reshape(-1)[:n].reshape(shape)


def is_packed_plane(x) -> bool:
    """Is ``x`` one lane-aligned (rows, 128) plane — the packed flat
    meta-plane layout every kernel here takes (repro.pack)? The single
    dispatch predicate: ops' fast paths skip the reshape/pad round trip
    on it, and repro.topology routes packed states through the fused
    kernels with it (the shape check, not just the type, keeps bare-array
    param pytrees that don't carry the wire layout on the generic
    per-leaf path)."""
    return (isinstance(x, jax.Array) and x.ndim == 2
            and x.shape[1] == LANES and x.shape[0] % 8 == 0)


# ---------------------------------------------------------------------------
# block momentum
# ---------------------------------------------------------------------------


def block_momentum(w, v, a, *, mu, eta=1.0, nesterov=False, interpret=None):
    """Fused meta update on one array. Returns (w', v')."""
    interpret = _default_interpret() if interpret is None else interpret
    if is_packed_plane(w):  # packed meta plane: feed the kernel directly
        return _bm.block_momentum_2d(
            w, v, a, mu, eta, nesterov=nesterov, interpret=interpret
        )
    rows, pad = _layout(w.size)  # w/v/a are same-shaped: one layout
    w2, v2, a2 = (_to_2d_as(t, rows, pad) for t in (w, v, a))
    w2n, v2n = _bm.block_momentum_2d(
        w2, v2, a2, mu, eta, nesterov=nesterov, interpret=interpret
    )
    return _from_2d(w2n, w.shape, w.size), _from_2d(v2n, v.shape, v.size)


def block_momentum_tree(gp, v, avg, *, mu, eta=1.0, nesterov=False,
                        interpret=None):
    """Apply the fused update leaf-wise over a parameter pytree."""
    flat_gp, treedef = jax.tree_util.tree_flatten(gp)
    flat_v = treedef.flatten_up_to(v)
    flat_avg = treedef.flatten_up_to(avg)
    new_w, new_v = [], []
    for wi, vi, ai in zip(flat_gp, flat_v, flat_avg):
        wn, vn = block_momentum(
            wi, vi, ai, mu=mu, eta=eta, nesterov=nesterov, interpret=interpret
        )
        new_w.append(wn)
        new_v.append(vn)
    return (
        jax.tree_util.tree_unflatten(treedef, new_w),
        jax.tree_util.tree_unflatten(treedef, new_v),
    )


# ---------------------------------------------------------------------------
# gossip neighbor mix (repro.topology)
# ---------------------------------------------------------------------------


# the single stack-selection implementation lives next to the kernel
mixing_matrix_at = _nm.mixing_matrix_at


def _resolve_matrix(w, step):
    if w.ndim == 3:
        if step is None:
            raise ValueError(
                "got a (T, L, L) mixing-matrix stack but no step= — the "
                "time-varying graphs are step-indexed; pass the meta step "
                "(silently using step 0 would freeze the graph)"
            )
        return mixing_matrix_at(w, step)
    return w


def neighbor_mix(x, w, *, interpret=None, step=None):
    """Mix one (L, ...) learner stack with the (L, L) matrix w — or, for
    the time-varying graphs, a (T, L, L) stack indexed by ``step`` — in a
    single HBM pass. Returns sum_k w_jk x_k, same shape/dtype as x."""
    interpret = _default_interpret() if interpret is None else interpret
    w = _resolve_matrix(w, step)
    L = x.shape[0]
    flat = x.astype(jnp.float32).reshape(L, -1)
    n = flat.shape[1]
    rows = -(-n // LANES)
    rows = -(-rows // 8) * 8
    x3 = jnp.pad(flat, ((0, 0), (0, rows * LANES - n))).reshape(L, rows, LANES)
    mixed = _nm.neighbor_mix_3d(x3, w, interpret=interpret)
    return mixed.reshape(L, -1)[:, :n].reshape(x.shape).astype(x.dtype)


def neighbor_mix_tree(tree, w, *, use_pallas=True, interpret=None, step=None):
    """Apply the gossip mix leaf-wise over a stacked (L, ...) pytree.

    ``w`` may be a (T, L, L) stack (time-varying graph, requires
    ``step``); the step's matrix is selected once here, not per leaf.
    """
    w = _resolve_matrix(w, step)
    if not use_pallas:
        return jax.tree.map(lambda x: _ref.neighbor_mix_ref(x, w), tree)
    return jax.tree.map(
        lambda x: neighbor_mix(x, w, interpret=interpret), tree
    )


# ---------------------------------------------------------------------------
# fused SGD apply
# ---------------------------------------------------------------------------


def sgd_apply(w, g, lr, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    if is_packed_plane(w):  # packed meta plane: feed the kernel directly
        return _sgd.sgd_apply_2d(w, g, lr, interpret=interpret)
    rows, pad = _layout(w.size)  # w/g are same-shaped: one layout
    out = _sgd.sgd_apply_2d(
        _to_2d_as(w, rows, pad), _to_2d_as(g, rows, pad), lr,
        interpret=interpret,
    )
    return _from_2d(out, w.shape, w.size)


# ---------------------------------------------------------------------------
# displacement quantization (repro.comm wire compression)
# ---------------------------------------------------------------------------


def quantize(x, key, *, qmax=127, block=None, use_pallas=True, interpret=None):
    """Quantize any-shaped ``x`` to (q int8 2-D, per-chunk scales).

    Returns (q, scales, shape, n) — feed the last three to ``dequantize``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    x2, shape, n = _to_2d(x.astype(jnp.float32))
    b = _q.choose_block(x2.shape[0], block)
    u2 = jax.random.uniform(key, x2.shape, jnp.float32)
    if use_pallas:
        q, s = _q.quantize_2d(x2, u2, qmax=qmax, block=b, interpret=interpret)
    else:
        q, s = _ref.quantize_ref(x2, u2, qmax, b)
    return q, s, shape, n


def dequantize(q, scales, shape, n, *, use_pallas=True, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    if use_pallas:
        dq = _q.dequantize_2d(q, scales, interpret=interpret)
    else:
        dq = _ref.dequantize_ref(q, scales)
    return _from_2d(dq, shape, n)


def quant_dequant(x, key, *, dtype="int8", block=None, use_pallas=True,
                  interpret=None):
    """Round-trip wire compression of one leaf.

    Returns (x-like f32 after quant->dequant, n_scale_chunks). ``dtype``:
    int8 | int4 (stochastic-rounding Pallas kernels) | fp8 (jnp
    per-chunk-scaled e4m3 cast).
    """
    if dtype == "fp8":
        x2, shape, n = _to_2d(x.astype(jnp.float32))
        b = _q.choose_block(x2.shape[0], block)
        return _from_2d(_ref.fp8_roundtrip_ref(x2, b), shape, n), x2.shape[0] // b
    qmax = {"int8": 127, "int4": 7}[dtype]
    q, s, shape, n = quantize(x, key, qmax=qmax, block=block,
                              use_pallas=use_pallas, interpret=interpret)
    return dequantize(q, s, shape, n, use_pallas=use_pallas,
                      interpret=interpret), s.shape[0]


# ---------------------------------------------------------------------------
# fused packed-plane compressed displacement (repro.pack meta step)
# ---------------------------------------------------------------------------


def pack_update(w, g, e, u, *, qmax=127, block=None, use_pallas=True,
                interpret=None):
    """Fused displacement + EF add + stochastic-rounding quantize over the
    packed (L, rows, 128) learner plane against the (rows, 128) meta
    params — one HBM pass instead of the per-leaf path's three
    (kernels/pack_update.py; jnp oracle in ref.py shares the dither and
    chunk geometry, so the two routes agree to one scale ulp with
    bit-identical rounding decisions).

    Returns (c, err, scales) — see pack_update_3d.
    """
    interpret = _default_interpret() if interpret is None else interpret
    L, rows, lanes = w.shape
    b = _q.choose_block(rows, block)
    if use_pallas:
        return _pu.pack_update_3d(w, g, e, u, qmax=qmax, block=b,
                                  interpret=interpret)
    return _ref.pack_update_ref(w, g, e, u, qmax, b)


def pack_compress(d, u, *, qmax=127, block=None, with_err=True,
                  use_pallas=True, interpret=None):
    """Compress-only variant of ``pack_update`` for an already-formed
    (L, rows, 128) displacement plane — the gossip / masked-hierarchical
    compress-stage path. Skips the gp-plane read (the caller had to
    synthesize zeros just to satisfy pack_update's signature), and under
    ``with_err=False`` (no error feedback: nobody reads the residual)
    also skips the err-plane write: 2R+3W or 2R+2W instead of 3R+3W,
    bitwise-identical outputs.

    Returns (c, err, scales) — ``err`` is the EF residual computed in the
    same pass (delta - c), so the error-feedback route needs no extra
    subtraction pass either; None when ``with_err`` is off.
    """
    interpret = _default_interpret() if interpret is None else interpret
    L, rows, lanes = d.shape
    b = _q.choose_block(rows, block)
    if use_pallas:
        return _pu.pack_compress_3d(d, u, qmax=qmax, block=b,
                                    with_err=with_err, interpret=interpret)
    return _ref.pack_compress_ref(d, u, qmax, b, with_err=with_err)


# ---------------------------------------------------------------------------
# robust learner-stack reduction (repro.robust)
# ---------------------------------------------------------------------------


median_trim = _rr.median_trim


def robust_reduce(x, *, trim=0, block=None, use_pallas=True, interpret=None):
    """Coordinate-wise trimmed mean over the leading (learner) axis of a
    stacked plane: drop the ``trim`` largest and smallest values per
    coordinate, average the rest. ``trim=0`` is bitwise the plain mean
    (the parity contract every existing invariant rides on);
    ``trim=median_trim(L)`` is the coordinate-wise median.

    Packed (L, rows, 128) stacks route through the fused Pallas kernel
    (one HBM pass, the sort stays in VMEM); everything else takes the jnp
    oracle, which is also the per-leaf path for unpacked pytrees.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if (use_pallas and x.ndim == 3 and x.shape[2] == LANES
            and x.shape[1] % 8 == 0):
        b = _q.choose_block(x.shape[1], block)
        return _rr.robust_reduce_3d(x, trim=trim, block=b,
                                    interpret=interpret)
    return _ref.robust_reduce_ref(x, trim)


def robust_reduce_tree(tree, *, trim=0, use_pallas=True, interpret=None):
    """Apply the robust reduction leaf-wise over a stacked (L, ...) pytree."""
    return jax.tree.map(
        lambda x: robust_reduce(x, trim=trim, use_pallas=use_pallas,
                                interpret=interpret),
        tree,
    )


# ---------------------------------------------------------------------------
# fused momentum -> learner broadcast (repro.pack meta step)
# ---------------------------------------------------------------------------


def fused_momentum_broadcast(w, v, a, *, mu, eta=1.0, num_learners,
                             ldtype=None, nesterov=False, use_pallas=True,
                             interpret=None):
    """Block momentum + learner reset on the packed (rows, 128) meta
    plane in one HBM pass: v' = mu v + eta (a - w); w' = w + v'; and the
    (L, rows, 128) learner plane w'.astype(ldtype) emitted directly from
    the update's VMEM tile (kernels/fused_meta.py) — eliminating
    tree_broadcast_learners' re-read of the meta params.

    Returns (w', v', learners). Bit-identical to block_momentum followed
    by astype + broadcast (the jnp oracle shares the op order).
    """
    interpret = _default_interpret() if interpret is None else interpret
    assert is_packed_plane(w), w.shape
    ldtype = w.dtype if ldtype is None else ldtype
    if use_pallas:
        return _fm.fused_momentum_broadcast_2d(
            w, v, a, mu, eta, num_learners, ldtype, nesterov=nesterov,
            interpret=interpret,
        )
    return _ref.fused_momentum_broadcast_ref(
        w, v, a, mu, eta, num_learners, ldtype, nesterov=nesterov
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, sliding_window=0,
                    prefix_global=0, interpret=None):
    """q: (B, S, H, D); k, v: (B, S, KV, D) -> (B, S, H, D).

    Pads D to a lane multiple and S to a block multiple; GQA is handled
    inside the kernel via BlockSpec index maps (no repeated K/V).
    """
    interpret = _default_interpret() if interpret is None else interpret
    B, S, H, D = q.shape
    KV = k.shape[2]
    scale = 1.0 / (D ** 0.5)

    d_pad = -(-D // LANES) * LANES
    bq = min(_fa.DEFAULT_BLOCK_Q, max(8, S))
    while S % bq:
        bq //= 2
    bk = min(_fa.DEFAULT_BLOCK_K, max(8, S))
    while S % bk:
        bk //= 2

    def prep(x, nh):
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
        return x.transpose(0, 2, 1, 3).reshape(B * nh, S, d_pad)

    out = _fa.flash_attention_bhsd(
        prep(q, H), prep(k, KV), prep(v, KV),
        causal=causal, sliding_window=sliding_window,
        prefix_global=prefix_global, scale=scale,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    out = out.reshape(B, H, S, d_pad).transpose(0, 2, 1, 3)[..., :D]
    return out
