"""Fused block-momentum meta-update — the paper's Algorithm 1 meta step —
as a Pallas TPU kernel.

Naively the meta update
    d = a - w;  v' = mu v + eta d;  w' = w + v'        (Nesterov variant:
    w' = w + mu v' + eta d)
is four pytree-wide elementwise passes = 4 reads + 2 writes of the full
parameter set from HBM. The update is purely memory-bound (zero FLOP/byte
reuse), so the only lever is touching HBM once: this kernel streams
(8,128)-aligned VMEM tiles of (w, v, a) and emits (w', v') in a single
pass — 3 reads + 2 writes, and XLA cannot re-split it.

Layout: callers flatten each parameter leaf to (rows, 128) with rows a
multiple of 8 (ops.py pads); the grid walks row-blocks of 256 rows so the
working set (5 tiles x 256 x 128 x 4B = 640 KiB) sits comfortably in the
~16 MiB VMEM budget while remaining large enough to saturate HBM DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _kernel(w_ref, v_ref, a_ref, mu_ref, eta_ref, w_out_ref, v_out_ref, *,
            nesterov: bool):
    mu = mu_ref[0, 0]
    eta = eta_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    d = a - w
    v_new = mu * v + eta * d
    if nesterov:
        w_new = w + mu * v_new + eta * d
    else:
        w_new = w + v_new
    w_out_ref[...] = w_new.astype(w_out_ref.dtype)
    v_out_ref[...] = v_new.astype(v_out_ref.dtype)


def block_momentum_2d(w, v, a, mu, eta, *, nesterov: bool = False,
                      interpret: bool = False, block: int | None = None):
    """w, v, a: (rows, 128) with rows % 8 == 0. Returns (w', v')."""
    rows, lanes = w.shape
    assert lanes == LANES and rows % 8 == 0, w.shape
    if block is None:
        block = min(BLOCK_ROWS, rows)
        while rows % block:
            block //= 2
    assert rows % block == 0, (rows, block)
    grid = (rows // block,)
    spec = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    mu_arr = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, nesterov=nesterov),
        grid=grid,
        in_specs=[spec, spec, spec, scalar_spec, scalar_spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        interpret=interpret,
    )(w, v, a, mu_arr, eta_arr)
