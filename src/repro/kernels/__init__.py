# Pallas TPU kernels for the paper's compute hot-spots, each with a jit'd
# wrapper (ops.py) and a pure-jnp oracle (ref.py). Validated on CPU with
# interpret=True; compiled natively on TPU.
from repro.kernels import ops, ref
