"""Fused packed-displacement update — displacement + error-feedback add +
stochastic-rounding quantize of the whole packed meta-plane in a single
HBM pass — as a Pallas TPU kernel (DESIGN.md §9).

On the per-leaf path the compressed meta average was three separate
pytree-wide passes per leaf (CompressedReducer.reduce + ops.quantize):

    delta = w_j - w~        read w, gp        write delta
    delta += e_j            read delta, e     write delta
    q, s = Q(delta); c = q*s; e' = delta - c   (quantize + dequantize +
                                                residual: 3 more passes)

Every pass is memory-bound with zero FLOP/byte reuse, so like
block_momentum.py the only lever is touching HBM once. This kernel
streams one (block, 128) VMEM tile of the learner plane per grid step and
emits the *dequantized* compressed displacement c = Q(w - w~ + e) and the
new EF residual e' = (w - w~ + e) - c in the same pass: 3-4 reads
(w, gp, u, optionally e) + 2-3 writes (c, scales, optionally e') of the
packed plane, and XLA cannot re-split it. gp is read once per learner
block via the BlockSpec index map — no (L, rows, 128) broadcast of the
meta params ever materializes in HBM.

Quantization semantics are identical to kernels/quantize.py: per-chunk
max-abs f32 scales over ``block`` rows x 128 lanes, unbiased stochastic
floor q = floor(x/s + u) with caller-supplied uniforms (shared with the
jnp oracle in ref.py, so the quantization decisions q are bit-identical
and c/err/scales agree to one scale ulp — see quantize.py for why the
dither is streamed in rather than drawn on-core). Chunks are
per-learner (the grid is (L, rows // block)), so every learner's
displacement is scaled independently of its peers, matching the wire
model where each learner ships its own payload.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 64  # scale-chunk rows, matching quantize.py's wire layout
LANES = 128
EPS = 1e-12  # all-zero chunks (e.g. pure padding): finite scale, q = 0


def _kernel(w_ref, g_ref, *rest, qmax: int, has_residual: bool):
    if has_residual:
        e_ref, u_ref, c_ref, err_ref, s_ref = rest
    else:
        u_ref, c_ref, err_ref, s_ref = rest
    d = w_ref[...].astype(jnp.float32) - g_ref[...].astype(jnp.float32)[None]
    if has_residual:
        d = d + e_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(d)), EPS) / qmax
    s_ref[0, 0] = scale
    q = jnp.clip(jnp.floor(d / scale + u_ref[...]), -qmax, qmax)
    c = q * scale
    c_ref[...] = c
    err_ref[...] = d - c


def pack_update_3d(w, g, e, u, *, qmax: int = 127, block: int | None = None,
                   interpret: bool = False):
    """w: (L, rows, 128) learner plane (any float dtype); g: (rows, 128)
    meta params; e: (L, rows, 128) f32 EF residual or None; u: (L, rows,
    128) U[0,1) dither.

    Returns (c, err, scales):
      c       (L, rows, 128) f32 — dequantized compressed displacement
              Q(w - g [+ e]), what crosses the wire
      err     (L, rows, 128) f32 — quantization error (the next EF
              residual when error feedback is on; the comm_error_norm
              metric either way)
      scales  (L, rows // block) f32 — per-chunk wire scales
    """
    L, rows, lanes = w.shape
    assert lanes == LANES and rows % 8 == 0, w.shape
    assert g.shape == (rows, LANES), (g.shape, w.shape)
    b = min(BLOCK_ROWS if block is None else block, rows)
    # callers resolve the chunk height via quantize.choose_block (see
    # ops.pack_update); failing loudly here keeps the kernel and the
    # jnp oracle on identical chunk geometry instead of silently
    # shrinking the block on one side only
    assert rows % b == 0, (rows, b)
    grid = (L, rows // b)
    spec = pl.BlockSpec((1, b, LANES), lambda l, i: (l, i, 0))
    g_spec = pl.BlockSpec((b, LANES), lambda l, i: (i, 0))
    s_spec = pl.BlockSpec((1, 1), lambda l, i: (l, i))
    in_specs = [spec, g_spec] + ([spec] if e is not None else []) + [spec]
    args = (w, g) + ((e,) if e is not None else ()) + (u,)
    c, err, scales = pl.pallas_call(
        functools.partial(_kernel, qmax=qmax, has_residual=e is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[spec, spec, s_spec],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, jnp.float32),
            jax.ShapeDtypeStruct(w.shape, jnp.float32),
            jax.ShapeDtypeStruct((L, rows // b), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return c, err, scales


# ---------------------------------------------------------------------------
# compress-only variant (the gossip / masked-hierarchical-inner path)
# ---------------------------------------------------------------------------


def _compress_kernel(d_ref, u_ref, *out, qmax: int, with_err: bool):
    if with_err:
        c_ref, err_ref, s_ref = out
    else:
        c_ref, s_ref = out
    d = d_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(d)), EPS) / qmax
    s_ref[0, 0] = scale
    q = jnp.clip(jnp.floor(d / scale + u_ref[...]), -qmax, qmax)
    c = q * scale
    c_ref[...] = c
    if with_err:
        err_ref[...] = d - c


def pack_compress_3d(d, u, *, qmax: int = 127, block: int | None = None,
                     with_err: bool = True, interpret: bool = False):
    """Quantize an already-formed (L, rows, 128) displacement plane.

    The compress-stage routes (gossip neighbor exchange, the masked
    hierarchical inner average — topology.gossip.compress_stack) hand the
    reducer a displacement delta_j = w_j - x_j they computed themselves;
    running those through pack_update_3d meant synthesizing a zero gp
    plane just so the kernel could subtract it — one full-plane HBM read
    of zeros per mix. This variant reads (d, u) and writes (c, scales)
    plus, under ``with_err``, the EF residual err = d - c the same pass
    already computed: 2R + 3W (error feedback, which keeps err as the
    next residual) or 2R + 2W (no EF — an output of an opaque
    pallas_call cannot be DCE'd by XLA, so the err plane must not exist
    at all when nobody reads it) instead of pack_update's 3R + 3W.

    Bitwise-identical to ``pack_update_3d(d, zeros, None, u)`` (d - 0 is
    exact), same chunk geometry and dither contract — so the fused-reduce
    vs compress-only consistency invariants (DESIGN.md §9) survive, now
    pinned in tests/test_zero_copy.py. Returns (c, err, scales) with
    err=None when ``with_err`` is off.
    """
    L, rows, lanes = d.shape
    assert lanes == LANES and rows % 8 == 0, d.shape
    b = min(BLOCK_ROWS if block is None else block, rows)
    assert rows % b == 0, (rows, b)
    grid = (L, rows // b)
    spec = pl.BlockSpec((1, b, LANES), lambda l, i: (l, i, 0))
    s_spec = pl.BlockSpec((1, 1), lambda l, i: (l, i))
    plane = jax.ShapeDtypeStruct(d.shape, jnp.float32)
    scales = jax.ShapeDtypeStruct((L, rows // b), jnp.float32)
    out = pl.pallas_call(
        functools.partial(_compress_kernel, qmax=qmax, with_err=with_err),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec, s_spec] if with_err else [spec, s_spec],
        out_shape=[plane, plane, scales] if with_err else [plane, scales],
        interpret=interpret,
    )(d, u)
    if with_err:
        return out
    c, s = out
    return c, None, s
