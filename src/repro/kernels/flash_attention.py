"""Flash attention (blocked online-softmax) as a Pallas TPU kernel.

TPU adaptation of the standard flash algorithm:
* grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is the minor
  (sequential) grid axis, so the VMEM scratch accumulator persists across
  kv blocks of a fixed (bh, qi) pair — TPU grids are sequential loops, not
  CUDA thread blocks (DESIGN.md §4, hardware adaptation).
* BlockSpec index maps implement GQA natively: each query-head block pulls
  its kv block from head ``h // n_rep`` — no materialised repeat of K/V.
* Block shapes default to (128, head_dim) — sublane-aligned (8) and MXU-
  shaped; head_dim is padded to a lane multiple (128) by ops.py.
* Supports causal, sliding-window, and Hymba's globally-visible prefix
  (meta tokens), plus a kv-length mask for padded sequences.

Validated against ref.py (pure-jnp oracle) with interpret=True on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, prefix, kv_len, block_q, block_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < kv_len
    if causal:
        mask &= qpos >= kpos
    if window:
        win = qpos - kpos < window
        if prefix:
            win |= kpos < prefix
        mask &= win
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention_bhsd(q, k, v, *, causal=True, sliding_window=0,
                         prefix_global=0, kv_len=None, scale=None,
                         block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                         interpret=False):
    """q: (BH, Sq, d); k, v: (BKV, Sk, d), BH = BKV * n_rep.

    Sq/Sk must be multiples of block_q/block_k; d should be lane-aligned
    (ops.py pads). kv_len masks padded key positions.
    """
    BH, Sq, d = q.shape
    BKV, Sk, _ = k.shape
    assert BH % BKV == 0, (BH, BKV)
    n_rep = BH // BKV
    kv_len = Sk if kv_len is None else kv_len
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0

    grid = (BH, Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _kernel,
        scale=scale if scale is not None else 1.0 / (d ** 0.5),
        causal=causal,
        window=sliding_window,
        prefix=prefix_global,
        kv_len=kv_len,
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec(
                (1, block_k, d), lambda bh, qi, ki, n_rep=n_rep: (bh // n_rep, ki, 0)
            ),
            pl.BlockSpec(
                (1, block_k, d), lambda bh, qi, ki, n_rep=n_rep: (bh // n_rep, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, d), jnp.float32),
            _vmem((block_q, 1), jnp.float32),
            _vmem((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    try:  # TPU backend
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        import jax.experimental.pallas as pl_mod

        return pl_mod.MemoryRef(shape, dtype)
