"""Fused gossip neighbor-mix — one mixing-matrix application over the
learner stack in a single HBM pass — as a Pallas TPU kernel.

The gossip meta step replaces the global all-reduce with a sparse
doubly-stochastic mix: out_j = sum_k W_jk x_k over the L learner copies
(repro.topology.gossip, DESIGN.md §7). Done naively per learner that is L
reads of the full stack; like block_momentum.py the op has essentially no
FLOP/byte reuse at small L, so the kernel streams one (L, block, 128)
VMEM tile of the whole stack per grid step and applies the (L, L) matrix
as a tiny contraction over the learner dim — every stacked value is read
once and written once (1 read + 1 write of the L-fold stack).

Layout: callers flatten each (L, ...) leaf to (L, rows, 128) with rows a
multiple of 8 (ops.py pads); the grid walks row-blocks. The working set is
2 x L x block x 128 x 4 B (block=256, L=16 -> 4 MiB) inside the ~16 MiB
VMEM budget. W rides along in full each step — L x L f32 is negligible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _kernel(w_ref, x_ref, out_ref):
    w = w_ref[...]  # (L, L) f32
    x = x_ref[...].astype(jnp.float32)  # (L, block, 128)
    L, b, lanes = x.shape
    mixed = jax.lax.dot_general(
        w, x.reshape(L, b * lanes), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = mixed.reshape(L, b, lanes).astype(out_ref.dtype)


def neighbor_mix_3d(x, w, *, interpret: bool = False,
                    block: int | None = None):
    """x: (L, rows, 128) with rows % 8 == 0; w: (L, L) row-stochastic.

    Returns the mixed stack, same shape/dtype as x.
    """
    L, rows, lanes = x.shape
    assert lanes == LANES and rows % 8 == 0, x.shape
    assert w.shape == (L, L), (w.shape, L)
    if block is None:
        block = min(BLOCK_ROWS, rows)
        while rows % block:
            block //= 2
    assert rows % block == 0, (rows, block)
    grid = (rows // block,)
    spec = pl.BlockSpec((L, block, LANES), lambda i: (0, i, 0))
    w_spec = pl.BlockSpec((L, L), lambda i: (0, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[w_spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(w.astype(jnp.float32), x)


def mixing_matrix_at(w_or_stack, step):
    """Select the meta step's mixing matrix.

    ``w_or_stack`` is either a static (L, L) matrix (returned as-is) or a
    precomputed (T_period, L, L) stack of the time-varying graphs
    (one-peer exponential), indexed by ``step % T`` — one cheap dynamic
    slice, the stack is tiny. ``step`` may be traced; the T=1 case folds
    to the constant.
    """
    if w_or_stack.ndim == 2:
        return w_or_stack
    T = w_or_stack.shape[0]
    if T == 1:
        return w_or_stack[0]
    return jax.lax.dynamic_index_in_dim(
        w_or_stack, step % T, axis=0, keepdims=False
    )


def neighbor_mix_3d_stepped(x, w_stack, step, *, interpret: bool = False,
                            block: int | None = None):
    """Time-varying variant: select W_t = w_stack[step % T] out of the
    precomputed (T, L, L) stack, then run the fused mix."""
    return neighbor_mix_3d(x, mixing_matrix_at(w_stack, step),
                           interpret=interpret, block=block)
