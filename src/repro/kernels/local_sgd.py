"""Fused learner-level SGD apply ``w <- w - lr * g`` as a Pallas kernel.

Same memory-bound reasoning as block_momentum.py: one VMEM streaming pass
per (8,128)-aligned tile instead of separate scale + subtract HLO ops.
Used for the inner K-step loop of Algorithm 1 when ``use_pallas`` is on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _kernel(w_ref, g_ref, lr_ref, out_ref):
    lr = lr_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (w - lr * g).astype(out_ref.dtype)


def sgd_apply_2d(w, g, lr, *, interpret: bool = False, block: int | None = None):
    rows, lanes = w.shape
    assert lanes == LANES and rows % 8 == 0, w.shape
    if block is None:
        block = min(BLOCK_ROWS, rows)
        while rows % block:
            block //= 2
    assert rows % block == 0
    spec = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=(rows // block,),
        in_specs=[spec, spec, scalar_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=interpret,
    )(w, g, lr_arr)
