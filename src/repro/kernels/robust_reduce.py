"""Fused robust reduction of the packed learner stack — coordinate-wise
trimmed mean / median over the L axis in a single HBM pass — as a Pallas
TPU kernel (DESIGN.md §14).

The trusting meta average reads the (L, rows, 128) learner plane once and
sums it; the robust estimators need an order statistic per coordinate
(sort L values, drop the ``trim`` largest and smallest, average the
rest). Done naively that is a full-plane sort materialized in HBM plus a
second reduction pass. This kernel streams one (L, block, 128) VMEM tile
per grid step — the whole learner axis is resident, which is exactly why
the learner axis is the leading one in the packed layout — sorts along L
in-register, and writes only the (block, 128) aggregate: one read of the
stack, one write of the result, and XLA cannot re-split it.

``trim=0`` takes a static branch that skips the sort entirely and emits
``sum / L`` in the same reduction order as ``jnp.mean(x, axis=0)`` — the
bitwise ``trim=0 == mean`` parity every existing topology/async/elastic
invariant rides on (pinned in tests/test_robust.py). The jnp oracle
(ref.robust_reduce_ref) shares the op order, so kernel and reference
agree bit-for-bit in interpret mode and to float-associativity on TPU.

The coordinate-wise median is the maximal trim: ``trim = (L - 1) // 2``
leaves one value for odd L and the mean of the two middle values for
even L — callers resolve it via ``median_trim``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 64
LANES = 128


def median_trim(L: int) -> int:
    """The trim that turns the trimmed mean into the coordinate-wise
    median: keeps 1 value for odd L, the 2 middle values for even L."""
    return (L - 1) // 2


def _kernel(x_ref, o_ref, *, trim: int):
    x = x_ref[...].astype(jnp.float32)  # (L, block, 128)
    L = x.shape[0]
    if trim == 0:
        # same reduction order as jnp.mean(x, axis=0): sum then divide —
        # the bitwise mean-parity contract
        o_ref[...] = jnp.sum(x, axis=0) / L
    else:
        s = jnp.sort(x, axis=0)
        kept = jnp.sum(s[trim:L - trim], axis=0)
        o_ref[...] = kept / (L - 2 * trim)


def robust_reduce_3d(x, *, trim: int = 0, block: int | None = None,
                     interpret: bool = False):
    """x: (L, rows, 128) learner stack (any float dtype).

    Returns the (rows, 128) f32 coordinate-wise trimmed mean over the L
    axis: drop the ``trim`` largest and smallest values per coordinate,
    average the remaining ``L - 2*trim``.
    """
    L, rows, lanes = x.shape
    assert lanes == LANES and rows % 8 == 0, x.shape
    assert 0 <= 2 * trim < L, (trim, L)
    b = min(BLOCK_ROWS if block is None else block, rows)
    assert rows % b == 0, (rows, b)
    return pl.pallas_call(
        functools.partial(_kernel, trim=trim),
        grid=(rows // b,),
        in_specs=[pl.BlockSpec((L, b, LANES), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((b, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(x)
