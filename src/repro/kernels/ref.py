"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import full_attention


def block_momentum_ref(w, v, a, mu, eta, *, nesterov: bool = False):
    """Four-pass reference of the fused meta update."""
    w32, v32, a32 = (x.astype(jnp.float32) for x in (w, v, a))
    d = a32 - w32
    v_new = mu * v32 + eta * d
    if nesterov:
        w_new = w32 + mu * v_new + eta * d
    else:
        w_new = w32 + v_new
    return w_new.astype(w.dtype), v_new.astype(v.dtype)


def sgd_apply_ref(w, g, lr):
    return (w.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(w.dtype)


def fused_momentum_broadcast_ref(w, v, a, mu, eta, num_learners: int, ldtype,
                                 *, nesterov: bool = False):
    """Oracle of fused_meta.fused_momentum_broadcast_2d: the block-momentum
    update followed by the learner-dtype broadcast of the new meta params.

    Exactly block_momentum_ref + astype + broadcast in that op order, so
    the fused path is bit-identical to the unfused two-step path
    (block_momentum then tree_broadcast_learners) it replaces.
    """
    w_new, v_new = block_momentum_ref(w, v, a, mu, eta, nesterov=nesterov)
    learners = jnp.broadcast_to(
        w_new.astype(ldtype)[None], (num_learners,) + w_new.shape
    )
    return w_new, v_new, learners


def quantize_ref(x, u, qmax: int, block: int):
    """Oracle of quantize.quantize_2d: x, u (rows, 128); per-chunk scales.

    Same math as the kernel, so with a shared ``u`` the outputs are
    bit-identical, not just statistically close.
    """
    rows, lanes = x.shape
    nchunks = rows // block
    xb = x.astype(jnp.float32).reshape(nchunks, block * lanes)
    scales = jnp.maximum(jnp.abs(xb).max(axis=1), 1e-12) / qmax  # (nchunks,)
    s_full = jnp.repeat(scales, block)[:, None]  # (rows, 1)
    q = jnp.floor(x.astype(jnp.float32) / s_full + u)
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8), scales.reshape(-1, 1)


def dequantize_ref(q, scales):
    rows = q.shape[0]
    block = rows // scales.shape[0]
    s_full = jnp.repeat(scales.reshape(-1), block)[:, None]
    return q.astype(jnp.float32) * s_full


def fp8_roundtrip_ref(x, block: int):
    """Per-chunk-scaled float8_e4m3 cast (deterministic round-to-nearest;
    fp8's mantissa makes stochastic dither unnecessary at these ranges)."""
    rows, lanes = x.shape
    nchunks = rows // block
    xb = x.astype(jnp.float32).reshape(nchunks, block * lanes)
    scales = jnp.maximum(jnp.abs(xb).max(axis=1), 1e-12) / 448.0  # e4m3 max
    s_full = jnp.repeat(scales, block)[:, None]
    x8 = (x.astype(jnp.float32) / s_full).astype(jnp.float8_e4m3fn)
    return x8.astype(jnp.float32) * s_full


def pack_update_ref(w, g, e, u, qmax: int, block: int):
    """Oracle of pack_update.pack_update_3d: fused displacement + EF add +
    stochastic-rounding quantize over the packed (L, rows, 128) plane.

    Same math and chunk geometry (per-learner ``block``-row scale chunks)
    as the kernel, so with a shared ``u`` the rounding decisions are
    bit-identical (outputs agree to one scale ulp).
    Returns (c, err, scales) — see pack_update_3d.
    """
    L, rows, lanes = w.shape
    d = w.astype(jnp.float32) - g.astype(jnp.float32)[None]
    if e is not None:
        d = d + e.astype(jnp.float32)
    nchunks = rows // block
    db = d.reshape(L, nchunks, block * lanes)
    scales = jnp.maximum(jnp.abs(db).max(axis=2), 1e-12) / qmax  # (L, nchunks)
    s_full = jnp.repeat(scales, block, axis=1).reshape(L, rows, 1)
    q = jnp.clip(jnp.floor(d / s_full + u), -qmax, qmax)
    c = q * s_full
    return c, d - c, scales


def pack_compress_ref(d, u, qmax: int, block: int, with_err: bool = True):
    """Oracle of pack_update.pack_compress_3d: quantize an already-formed
    (L, rows, 128) displacement plane — pack_update_ref without the gp
    subtraction (d - 0 is exact, so the two agree bitwise on a zero gp).
    Returns (c, err, scales); err is None when ``with_err`` is off (the
    non-EF route, where the kernel never writes the err plane)."""
    L, rows, lanes = d.shape
    d = d.astype(jnp.float32)
    nchunks = rows // block
    db = d.reshape(L, nchunks, block * lanes)
    scales = jnp.maximum(jnp.abs(db).max(axis=2), 1e-12) / qmax  # (L, nchunks)
    s_full = jnp.repeat(scales, block, axis=1).reshape(L, rows, 1)
    q = jnp.clip(jnp.floor(d / s_full + u), -qmax, qmax)
    c = q * s_full
    return c, (d - c if with_err else None), scales


def robust_reduce_ref(x, trim: int = 0):
    """Oracle of robust_reduce.robust_reduce_3d on any (L, ...) learner
    stack: coordinate-wise trimmed mean over axis 0, f32 math.

    ``trim=0`` is sum/L in jnp.mean's reduction order (the bitwise mean-
    parity contract); ``trim = (L-1)//2`` is the coordinate-wise median.
    """
    L = x.shape[0]
    assert 0 <= 2 * trim < L, (trim, L)
    x32 = x.astype(jnp.float32)
    if trim == 0:
        return jnp.sum(x32, axis=0) / L
    s = jnp.sort(x32, axis=0)
    return jnp.sum(s[trim:L - trim], axis=0) / (L - 2 * trim)


def neighbor_mix_ref(x, w):
    """Oracle of neighbor_mix.neighbor_mix_3d on an unflattened learner
    stack: x (L, ...), w (L, L) -> sum_k w_jk x_k, f32 math."""
    L = x.shape[0]
    mixed = jnp.einsum(
        "jk,kn->jn", w.astype(jnp.float32),
        x.astype(jnp.float32).reshape(L, -1),
    )
    return mixed.reshape(x.shape).astype(x.dtype)


def neighbor_mix_stepped_ref(x, w_stack, step):
    """Oracle of neighbor_mix.neighbor_mix_3d_stepped: select the step's
    matrix out of the (T, L, L) stack, then mix."""
    T = w_stack.shape[0]
    return neighbor_mix_ref(x, w_stack[step % T])


def flash_attention_ref(q, k, v, *, causal=True, sliding_window=0,
                        prefix_global=0):
    """q: (B, S, H, D); k, v: (B, S, KV, D). Full-softmax oracle."""
    return full_attention(
        q, k, v, causal=causal, sliding_window=sliding_window,
        prefix_global=prefix_global,
    )
