"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import full_attention


def block_momentum_ref(w, v, a, mu, eta, *, nesterov: bool = False):
    """Four-pass reference of the fused meta update."""
    w32, v32, a32 = (x.astype(jnp.float32) for x in (w, v, a))
    d = a32 - w32
    v_new = mu * v32 + eta * d
    if nesterov:
        w_new = w32 + mu * v_new + eta * d
    else:
        w_new = w32 + v_new
    return w_new.astype(w.dtype), v_new.astype(v.dtype)


def sgd_apply_ref(w, g, lr):
    return (w.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(w.dtype)


def flash_attention_ref(q, k, v, *, causal=True, sliding_window=0,
                        prefix_global=0):
    """q: (B, S, H, D); k, v: (B, S, KV, D). Full-softmax oracle."""
    return full_attention(
        q, k, v, causal=causal, sliding_window=sliding_window,
        prefix_global=prefix_global,
    )
