from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    MAvgConfig,
    ModelConfig,
    TrainConfig,
    all_configs,
    get_config,
)
