"""xLSTM 350M [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,              # xLSTM blocks carry their own up/down projections
    vocab_size=50304,
    ssm_expand=2,
    slstm_every=4,       # every 4th block is an sLSTM block (1:3 ratio)
    citation="arXiv:2405.04517",
)
