"""Config system: model architecture + input shapes + run settings.

Every assigned architecture has a module ``repro/configs/<id>.py`` exposing
``CONFIG`` (the exact full-scale config from the assignment table, with the
source citation) and smoke tests use ``CONFIG.reduced()``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 500000.0
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0  # routed expert hidden size
    moe_aux_coef: float = 0.01
    first_dense_layers: int = 0  # deepseek-moe: leading dense FFN layers
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    slstm_every: int = 0  # xlstm: every k-th block is an sLSTM block
    # --- attention variants ---
    sliding_window: int = 0  # 0 = full; >0 = sliding-window attention
    causal: bool = True  # False for encoder-only (hubert)
    # --- modality frontends (stubs per spec) ---
    input_mode: str = "tokens"  # tokens | embeddings | tokens+patches
    num_patches: int = 256  # VLM stub patch count per image
    meta_tokens: int = 0  # hymba learnable prefix tokens
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, (
            f"{self.name}: num_heads={self.num_heads} not a multiple of "
            f"num_kv_heads={self.num_kv_heads}"
        )

    # ------------------------------------------------------------------
    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_decode(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """Can this config serve 500k-token contexts?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/features, tiny dims.

        Per spec: 2 layers, d_model <= 512, <= 4 experts.
        """
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, n_heads * self.num_kv_heads // self.num_heads)
        updates = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_patches=min(self.num_patches, 16),
            meta_tokens=min(self.meta_tokens, 8),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
        )
        if self.num_experts:
            updates.update(
                num_experts=4,
                num_shared_experts=min(self.num_shared_experts, 1),
                moe_top_k=min(self.moe_top_k, 2),
                d_expert=min(self.d_expert, 128),
                first_dense_layers=min(self.first_dense_layers, 1),
            )
        if self.slstm_every:
            updates["slstm_every"] = 2
        return replace(self, **updates)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * hd
        if self.family == "ssm":  # xlstm: mLSTM/sLSTM blocks, no attn/ffn
            d_in = self.ssm_expand * d
            mlstm = 2 * d * d_in + 3 * d_in * d_in // 1 + d_in * d  # rough
            return self.num_layers * mlstm + 2 * self.vocab_size * d
        ffn = 3 * d * self.d_ff if self.d_ff else 0
        moe = 0
        if self.num_experts:
            routed = self.num_experts * 3 * d * self.d_expert
            shared = self.num_shared_experts * 3 * d * self.d_expert
            router = d * self.num_experts
            n_moe = self.num_layers - self.first_dense_layers
            moe = n_moe * (routed + shared + router)
            ffn = self.first_dense_layers * ffn
            per_layer = attn
            total = self.num_layers * per_layer + moe + ffn
        else:
            total = self.num_layers * (attn + ffn)
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            ssm = self.num_layers * (2 * d * d_in + d_in * self.ssm_state * 2)
            total += ssm
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(total + embed)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k experts count)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        n_moe = self.num_layers - self.first_dense_layers
        inactive = (
            n_moe * (self.num_experts - self.moe_top_k) * 3 * d * self.d_expert
        )
        return int(self.param_count() - inactive)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "llama3-405b",
    "kimi-k2-1t-a32b",
    "qwen3-1.7b",
    "qwen1.5-110b",
    "xlstm-350m",
    "deepseek-moe-16b",
    "hubert-xlarge",
    "qwen2-7b",
    "internvl2-76b",
    "hymba-1.5b",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_")
    )
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# algorithms whose meta step is a plain average — the ones the repro.comm
# reducer owns (eamsgd/downpour ship their own update structure through
# the async server topology instead)
AVERAGING_ALGOS = ("mavg", "kavg", "sync", "mavg_mlocal")

# every algorithm the stack implements — the single source the CLI
# `choices` are derived from (launch/train.py). eamsgd/downpour are
# aliases onto the async bounded-staleness server (repro.topology.
# async_server): core/meta.py itself has no per-algorithm branches.
ALGORITHMS = AVERAGING_ALGOS + ("eamsgd", "downpour")

COMM_SCHEMES = ("dense", "int8", "fp8", "topk", "int8_topk")

# meta-level mixing topologies (the repro.topology subsystem)
TOPOLOGIES = ("flat", "hierarchical", "gossip", "async")

# one_peer_exponential is *time-varying*: step t uses only the +/-2^(t mod
# ceil(log2 L)) offsets (a perfect XOR matching when L is a power of two),
# matching the static exponential graph's consensus rate at degree <= 2
# (Takezawa et al. 2022)
GOSSIP_GRAPHS = ("ring", "exponential", "complete", "one_peer_exponential")


@dataclass(frozen=True)
class CommConfig:
    """Meta-communication compression knobs (the ``repro.comm`` subsystem).

    The meta average is the paper's one communication event per K local
    steps; these knobs select how each learner's displacement w_j - w~ is
    compressed on the wire (DESIGN.md §5).

    scheme          dense | int8 | fp8 | topk | int8_topk
    k_frac          kept fraction for the top-k schemes
    error_feedback  carry the compression residual e_j in MetaState so the
                    block-momentum update stays unbiased (EF-SGD)
    chunk_rows      rows of the (rows, 128) wire layout sharing one f32
                    quantization scale (chunk = chunk_rows * 128 values)
    use_pallas      route quant/dequant through the Pallas kernels
                    (interpret mode off-TPU) instead of the jnp reference
    seed            stochastic-rounding PRNG stream
    """

    scheme: str = "dense"
    k_frac: float = 0.1
    error_feedback: bool = True
    chunk_rows: int = 64
    use_pallas: bool = False
    seed: int = 0

    def __post_init__(self):
        assert self.scheme in COMM_SCHEMES, (
            f"unknown comm scheme {self.scheme!r}; choose from {COMM_SCHEMES}"
        )


@dataclass(frozen=True)
class ElasticConfig:
    """Deterministic learner dropout/join schedule (elastic execution).

    Real elastic clusters race wall clocks; under SPMD the same quantity
    — which learners participate in a given meta step — is simulated with
    a deterministic, checkpointable schedule instead (the downpour move,
    DESIGN.md §4/§8). The (period, L) 0/1 membership mask rides in
    ``MetaState.topo["membership"]`` and indexes by ``step % period``.

    period      schedule length T in meta steps (cycles)
    drop_frac   target fraction of learners absent at each scheduled step
                (0.0 = everyone always present — must reproduce the static
                topology bit-for-bit, pinned in tests/test_elastic.py)
    seed        PRNG stream the schedule is drawn from; every group keeps
                at least one present learner regardless
    schedule    explicit (period, L) 0/1 rows overriding the drawn
                schedule — how repro.chaos maps crash windows (and the
                supervisor maps quarantine) onto membership. When set,
                ``period`` must equal ``len(schedule)`` and every row
                must keep at least one learner present; drop_frac/seed
                are ignored.
    """

    period: int = 8
    drop_frac: float = 0.25
    seed: int = 0
    schedule: Optional[tuple] = None

    def __post_init__(self):
        assert self.period >= 1, self.period
        assert 0.0 <= self.drop_frac < 1.0, self.drop_frac
        if self.schedule is not None:
            rows = tuple(
                tuple(float(v) for v in row) for row in self.schedule
            )
            object.__setattr__(self, "schedule", rows)
            assert len(rows) == self.period, (
                f"explicit membership schedule has {len(rows)} rows for "
                f"period={self.period}"
            )
            L = len(rows[0])
            for t, row in enumerate(rows):
                assert len(row) == L, (t, len(row), L)
                assert all(v in (0.0, 1.0) for v in row), (t, row)
                assert sum(row) >= 1.0, (
                    f"membership schedule row {t} has no present learner"
                )


ASYNC_UPDATES = ("mavg", "elastic")

# robust aggregation estimators over the learner stack (repro.robust,
# DESIGN.md §14) — the single source the CLI choices derive from.
# 'mean' keeps the plain average (clipping/scoring may still be on).
ROBUST_ESTIMATORS = ("mean", "trimmed", "median")


@dataclass(frozen=True)
class RobustConfig:
    """Byzantine-tolerant meta aggregation (``repro.robust``, DESIGN.md §14).

    The paper's block-momentum update trusts the plain mean over learner
    displacements; one learner shipping finite-but-corrupt payloads
    poisons the global momentum for everyone. These knobs bound each
    learner's influence on the consensus instead of trusting it.
    ``MAvgConfig.robust=None`` (the default) leaves every code path
    untouched — bitwise-identical to a build without the subsystem.

    estimator        mean | trimmed | median — the aggregation rule that
                     replaces the learner-stack mean inside mean-based
                     reducers (flat all-reduce, hierarchical inner+outer).
                     'trimmed' drops the ``trim`` largest and smallest
                     values per coordinate; 'median' is the maximal trim.
                     Gossip/async have weighted partial means instead of
                     an L-way mean, so there the influence bound is the
                     norm clip (below) — the estimator is ignored.
    trim             coordinates trimmed per side (estimator='trimmed');
                     trim=0 is bitwise the plain mean (pinned in tests)
    clip_mult        per-learner displacement norm clip: each learner's
                     displacement is scaled down to at most
                     ``clip_mult x median(trailing clip_window per-step
                     median norms)``. 0.0 = clipping off. Clipped-away
                     mass is REJECTED — it never enters the error-
                     feedback residual (not deferred to later rounds).
    clip_window      trailing-median ring length (meta steps); no
                     clipping until the ring has filled once (warmup)
    score            compute Krum-style per-learner anomaly scores each
                     mix (nearest-neighbor distance sums from the
                     learner-stack Gram matrix) and stream them through
                     repro.obs as ``robust`` records (schema v4)
    score_neighbors  neighbors summed per score; 0 = auto (L - 2)
    quarantine_after M consecutive anomalous flush windows before the
                     Trainer quarantines a learner inline through the
                     elastic membership mask — no HealthHalt round-trip,
                     no rollback. 0 = inline quarantine off. Requires a
                     membership-capable topology (hierarchical/gossip/
                     async).
    score_ratio      a learner is anomalous in a window when its mean
                     score exceeds ``score_ratio x`` the median of its
                     peers' scores
    """

    estimator: str = "trimmed"
    trim: int = 1
    clip_mult: float = 0.0
    clip_window: int = 8
    score: bool = True
    score_neighbors: int = 0
    quarantine_after: int = 0
    score_ratio: float = 4.0

    def __post_init__(self):
        assert self.estimator in ROBUST_ESTIMATORS, (
            f"unknown robust estimator {self.estimator!r}; choose from "
            f"{ROBUST_ESTIMATORS}"
        )
        assert self.trim >= 0, self.trim
        assert self.clip_mult >= 0.0, self.clip_mult
        assert self.clip_window >= 1, self.clip_window
        assert self.score_neighbors >= 0, self.score_neighbors
        assert self.quarantine_after >= 0, self.quarantine_after
        assert self.score_ratio > 1.0, self.score_ratio


@dataclass(frozen=True)
class AsyncConfig:
    """The async bounded-staleness meta server (``repro.topology.
    async_server``, DESIGN.md §12).

    True asynchrony is unexpressible under SPMD (every program step is
    collective), so — exactly like elastic membership and the retired
    downpour queue — *when each learner reaches its K* becomes a
    deterministic, checkpointable schedule: learner j needs
    ``step_time[j]`` meta ticks per K-step block, pushes its displacement
    when its logical clock fills, and pulls the current w~ without
    waiting for anyone. Staleness (center updates between a learner's
    pull and its push) is bounded by construction:
    ``max(step_time) - 1 <= staleness``.

    staleness      tau: the staleness bound. 0 forces a uniform profile —
                   the synchronous degenerate case, bitwise-identical to
                   FlatAllReduce (pinned in tests/test_async.py)
    step_time      per-learner ticks per K-step block (length L, each
                   >= 1); () derives a profile from ``skew``/``seed``
    skew           when step_time is empty: deterministic profile drawn
                   over {1..skew} (seeded permutation of an even spread)
    seed           PRNG stream of the derived profile
    update         'mavg' — applied displacements are weighted by the
                   staleness-decayed block momentum (decay^tau); or
                   'elastic' — Zhang's EASGD elastic force toward the
                   current center, same decay weighting
    decay          per-round staleness decay of an applied displacement
                   (weight decay^tau); None -> the effective block
                   momentum mu (the mu^tau rule of Yu et al.)
    elastic_alpha  elastic-force coupling; None -> MAvgConfig.elastic_alpha
    """

    staleness: int = 0
    step_time: tuple = ()
    skew: int = 1
    seed: int = 0
    update: str = "mavg"
    decay: Optional[float] = None
    elastic_alpha: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(
            self, "step_time", tuple(int(m) for m in self.step_time)
        )
        assert self.staleness >= 0, self.staleness
        assert self.skew >= 1, self.skew
        assert self.update in ASYNC_UPDATES, (
            f"unknown async update {self.update!r}; choose from "
            f"{ASYNC_UPDATES}"
        )
        assert all(m >= 1 for m in self.step_time), self.step_time
        slowest = max(self.step_time) if self.step_time else self.skew
        if slowest - 1 > self.staleness:
            raise ValueError(
                f"step-time profile (slowest learner: {slowest} ticks per "
                f"K-step block) can push displacements up to {slowest - 1} "
                f"center updates stale, beyond the staleness bound "
                f"tau={self.staleness} — raise staleness or flatten the "
                f"profile"
            )
        if self.decay is not None:
            assert 0.0 <= self.decay <= 1.0, self.decay


@dataclass(frozen=True)
class TopologyConfig:
    """Who averages with whom, how often (the ``repro.topology`` subsystem).

    The paper's flat model — every learner averages with every other
    learner each meta step — is one point in a family (DESIGN.md §7):

    kind             flat | hierarchical | gossip
    groups           G: learners partitioned into G groups (hierarchical)
    outer_every      H: cross-group average every H meta steps, so the
                     slow inter-node links are touched once per K·H local
                     steps while intra-node averaging stays at every K
    outer_momentum   mu_out: block momentum of the outer (cross-group)
                     level; the inner level uses MAvgConfig.momentum
    graph            gossip mixing graph: ring | exponential | complete
                     (all doubly stochastic, so the learner mean is
                     preserved exactly)
    momentum_tracking  gossip: also mix the per-learner momentum buffers
                     with the same matrix (Takezawa et al. 2022)
    inner_comm       Reducer for the intra-group / neighbor edge class
                     (None -> MAvgConfig.comm)
    outer_comm       Reducer for the cross-group edge class — where the
                     inter-node byte savings land (None -> MAvgConfig.comm)
    group_k          hierarchical: per-group local-step counts K_g (length
                     G, each 1..k_steps). Groups behind slow inter-node
                     links can run more local steps than fast intra-node
                     groups; the extra steps of low-K_g groups are masked
                     inside the static K-step scan so the SPMD program
                     never changes shape. None -> every group runs k_steps.
    elastic          deterministic learner dropout/join schedule
                     (ElasticConfig); absent learners run zero local steps
                     and are masked out of the mixing with the matrix
                     re-wired to stay doubly stochastic. Under the async
                     server an absent learner simply cannot push — drop
                     and lag are one staleness axis. None -> off.
    server           async bounded-staleness server knobs (AsyncConfig);
                     only for kind='async'. None -> AsyncConfig() (the
                     synchronous degenerate case).
    """

    kind: str = "flat"
    groups: int = 1
    outer_every: int = 1
    outer_momentum: float = 0.0
    graph: str = "ring"
    momentum_tracking: bool = False
    inner_comm: Optional[CommConfig] = None
    outer_comm: Optional[CommConfig] = None
    group_k: Optional[tuple] = None
    elastic: Optional[ElasticConfig] = None
    server: Optional[AsyncConfig] = None

    def __post_init__(self):
        assert self.kind in TOPOLOGIES, (
            f"unknown topology {self.kind!r}; choose from {TOPOLOGIES}"
        )
        assert self.graph in GOSSIP_GRAPHS, (
            f"unknown gossip graph {self.graph!r}; choose from {GOSSIP_GRAPHS}"
        )
        assert self.groups >= 1 and self.outer_every >= 1
        if self.group_k is not None:
            # normalize to a hashable tuple (configs are frozen/hashable)
            object.__setattr__(self, "group_k", tuple(int(k) for k in self.group_k))
            assert self.kind == "hierarchical", (
                f"group_k only applies to the hierarchical topology, "
                f"not {self.kind!r}"
            )
            assert len(self.group_k) == self.groups, (
                f"group_k has {len(self.group_k)} entries for "
                f"groups={self.groups}"
            )
            assert all(k >= 1 for k in self.group_k), self.group_k
        if self.elastic is not None:
            assert self.kind in ("hierarchical", "gossip", "async"), (
                f"elastic membership masks the hierarchical/gossip mixing "
                f"(or the async server's push schedule); topology "
                f"{self.kind!r} has no mixing rows to mask"
            )
        if self.server is not None:
            assert self.kind == "async", (
                f"AsyncConfig only applies to the async topology, "
                f"not {self.kind!r}"
            )


@dataclass(frozen=True)
class MAvgConfig:
    """Hyper-parameters of the paper's Algorithm 1 (+ baselines)."""

    algorithm: str = "mavg"  # mavg | kavg | sync | eamsgd | downpour | mavg_mlocal
    num_learners: int = 4  # P in the paper
    k_steps: int = 4  # K: local steps between averaging
    learner_lr: float = 0.1  # gamma_n
    meta_lr: float = 1.0  # eta_n scaling of the displacement d
    momentum: float = 0.7  # mu: block momentum
    local_momentum: float = 0.0  # learner-level momentum (mavg_mlocal)
    nesterov: bool = False  # beyond-paper: Nesterov block momentum
    # EAMSGD
    elastic_alpha: float = 0.05
    # Downpour (simulated bounded staleness)
    staleness: int = 1
    # numerics: meta state always f32 (Theorem 1 variance); learner copies
    # default f32 for CPU experiments, bf16 for TPU launch configs
    meta_dtype: str = "float32"
    compute_dtype: str = "float32"
    use_pallas: bool = False  # Pallas kernels on TPU; jnp ref elsewhere
    # packed flat meta-plane (repro.pack, DESIGN.md §9): the whole param
    # pytree rides as ONE lane-aligned (rows, 128) buffer, so every
    # meta-phase op is a constant number of whole-model kernel passes
    # instead of one per leaf. False = the legacy per-leaf path, kept as
    # the parity oracle and for resuming per-leaf checkpoints.
    packed: bool = True
    # donate the MetaState input buffers to the jitted meta step
    # (jax.jit(donate_argnums=...)): every state plane is updated in
    # place instead of functionally rebuilt, halving the meta phase's
    # peak state HBM (DESIGN.md §10). Numerics are identical (aliasing
    # only); False keeps the input state alive after a step, which the
    # interactive/debug paths (and any caller that re-reads the
    # pre-step state) need.
    donate: bool = True
    # in-step finite guard (repro.chaos / DESIGN.md §13): after the local
    # phase (and any injected payload corruption), learners whose planes
    # carry NaN/Inf are reset to the broadcast global params (zero
    # displacement — the poisoned block is skipped, momentum pure-decays
    # when every learner is bad) and counted in the nonfinite_learners
    # metric, so a non-finite value can never reach MetaState's global
    # params through the mix. Off (default) the code path is untouched;
    # on with a clean run the guard is bitwise-invisible (where on an
    # all-true mask) — both pinned in tests/test_chaos.py.
    finite_guard: bool = False
    # meta-communication compression (repro.comm); dense = exact average
    comm: CommConfig = field(default_factory=CommConfig)
    # meta-level mixing topology (repro.topology); flat = all-reduce
    topology: TopologyConfig = field(default_factory=TopologyConfig)
    # Byzantine-tolerant meta aggregation (repro.robust, DESIGN.md §14);
    # None = off — every existing code path is bitwise untouched
    robust: Optional[RobustConfig] = None

    def __post_init__(self):
        if self.comm.scheme != "dense" and self.algorithm not in AVERAGING_ALGOS:
            raise ValueError(
                f"comm scheme {self.comm.scheme!r} only applies to the "
                f"averaging algorithms {AVERAGING_ALGOS}; "
                f"{self.algorithm!r} communicates through its own update"
            )
        t = self.topology
        if t.kind not in ("flat", "async") and self.algorithm not in AVERAGING_ALGOS:
            raise ValueError(
                f"topology {t.kind!r} only applies to the averaging "
                f"algorithms {AVERAGING_ALGOS}; {self.algorithm!r} is an "
                f"alias onto the async server (topology 'async')"
            )
        if t.kind == "async":
            if self.comm.scheme != "dense":
                raise ValueError(
                    f"the async server ships dense displacement planes; "
                    f"comm scheme {self.comm.scheme!r} is not supported on "
                    f"the async path"
                )
            server = t.server if t.server is not None else AsyncConfig()
            if server.step_time and len(server.step_time) != self.num_learners:
                raise ValueError(
                    f"async step_time profile has {len(server.step_time)} "
                    f"entries for num_learners={self.num_learners}"
                )
        if t.kind == "hierarchical" and self.num_learners % t.groups:
            raise ValueError(
                f"num_learners={self.num_learners} not divisible into "
                f"groups={t.groups}"
            )
        if t.group_k is not None and max(t.group_k) > self.k_steps:
            raise ValueError(
                f"group_k={t.group_k} exceeds k_steps={self.k_steps} — the "
                f"heterogeneous schedule masks steps *within* the static "
                f"K-step scan, so every K_g must be <= k_steps"
            )
        if self.robust is not None:
            r = self.robust
            if r.estimator == "trimmed" and r.trim > 0:
                # the smallest L-way mean the trimmed estimator replaces:
                # within-group size for hierarchical, L for flat
                width = (
                    self.num_learners // t.groups
                    if t.kind == "hierarchical" else self.num_learners
                )
                if 2 * r.trim >= width:
                    raise ValueError(
                        f"robust trim={r.trim} removes 2*trim={2 * r.trim} "
                        f"of {width} values per coordinate — the trimmed "
                        f"mean needs 2*trim < the aggregation width"
                    )
            if r.quarantine_after > 0 and t.kind == "flat":
                raise ValueError(
                    "robust inline quarantine masks learners through the "
                    "elastic membership schedule; the flat topology has no "
                    "membership rows — use hierarchical/gossip/async, or "
                    "set quarantine_after=0"
                )


# sink kinds of the repro.obs subsystem (DESIGN.md §11) — the single
# source the CLI choices derive from
OBS_SINKS = ("none", "jsonl", "csv", "memory")


@dataclass(frozen=True)
class ObsConfig:
    """Telemetry knobs (the ``repro.obs`` subsystem, DESIGN.md §11).

    sink             none | jsonl | csv | memory — where flushed metric
                     records and the run manifest go. Metrics stay on
                     device between ``log_every`` boundaries regardless
                     (the MetricsBuffer ring); the sink only sees already-
                     flushed host floats, so enabling it adds no syncs.
    run_dir          directory of the run log (run.jsonl / run.csv) and
                     trace exports; required for the file sinks
    buffer_capacity  rows of the device metric ring (0 -> sized to
                     max(log_every, 1), the flush cadence)
    trace            phase span timers (dispatch / host_flush /
                     checkpoint_io / sink) + Chrome-trace export to
                     ``run_dir/trace.json`` at the end of each run
    profiler         capture a jax.profiler device trace of the run into
                     ``run_dir/jax_trace`` (best-effort; needs profiler
                     support in the jax build)
    cost_analysis    record the compiled meta step's measured HBM /
                     peak-state / flops numbers (roofline.hlo_cost
                     .jit_cost) into the run manifest — one extra AOT
                     compile of the step at first dispatch
    health           run-health watchdogs (obs.health): declarative rules
                     evaluated over each flushed metric window, emitting
                     structured ``alert`` records into the sink. Consumes
                     only already-flushed host floats — a healthy run is
                     bitwise unaffected (pinned in tests)
    health_halt      fatal rules (NaN loss, divergence) halt the run with
                     a resumable checkpoint + HealthHalt; False records
                     the alerts but never stops
    attribution      measured-vs-modeled phase attribution (obs.profile):
                     at init, steady-state-time the jitted step / local
                     phase / meta mix against their compiled-HLO modeled
                     bytes and record achieved-GB/s rows into the sink —
                     a few extra untimed compiles + timing iterations
                     before step 0, nothing in the loop
    """

    sink: str = "none"
    run_dir: Optional[str] = None
    buffer_capacity: int = 0
    trace: bool = False
    profiler: bool = False
    cost_analysis: bool = False
    health: bool = False
    health_halt: bool = True
    attribution: bool = False

    def __post_init__(self):
        assert self.sink in OBS_SINKS, (
            f"unknown obs sink {self.sink!r}; choose from {OBS_SINKS}"
        )
        assert self.buffer_capacity >= 0, self.buffer_capacity
        if self.sink in ("jsonl", "csv") and self.run_dir is None:
            raise ValueError(
                f"ObsConfig(sink={self.sink!r}) needs run_dir for the run log"
            )


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    mavg: MAvgConfig = field(default_factory=MAvgConfig)
    batch_per_learner: int = 8
    seq_len: int = 128
    meta_steps: int = 10
    seed: int = 0
    log_every: int = 1
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    # retention: keep the last N sidecar-verified snapshots as the
    # rollback chain (checkpoint.prune_checkpoints); 0 keeps everything
    checkpoint_keep: int = 0
    # deterministic fault injection (repro.chaos): a ChaosConfig whose
    # FaultSchedule the Trainer compiles and threads through the batch
    # stream, the jitted step and the checkpoint writer; None = off
    # (typed loosely to keep configs free of a chaos import)
    chaos: Optional[object] = None
    # supervisor retry salt: folded into the data stream so a rolled-back
    # attempt redraws the poisoned block's batches (and FaultSchedule
    # drops non-sticky faults); 0 on every first attempt
    data_salt: int = 0
    # telemetry (repro.obs): sink/tracing knobs; the device metric ring is
    # always on (it IS the metrics path), the knobs decide where it lands
    obs: ObsConfig = field(default_factory=ObsConfig)


def to_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)
