"""InternVL2 76B [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].
Vision encoder + projector are STUBS: input_specs() provides precomputed
patch embeddings (B, num_patches, d_model) alongside text tokens."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    input_mode="tokens+patches",
    num_patches=256,
    rope_theta=1000000.0,
    citation="arXiv:2404.16821",
)
