"""Qwen3 1.7B [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    # sliding-window serve variant enables the long_500k demonstration
    # (see DESIGN.md section 7); training/prefill use full causal attention.
    citation="hf:Qwen/Qwen3-8B",
)
