"""DeepSeekMoE 16B [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,          # first layer is a dense FFN (DeepSeekMoE design)
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    moe_top_k=6,
    d_expert=1408,
    first_dense_layers=1,
    rope_theta=10000.0,
    citation="arXiv:2401.06066",
)
