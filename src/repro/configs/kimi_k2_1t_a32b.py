"""Kimi K2 — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,          # dense-FFN size for the leading dense layer(s)
    vocab_size=163840,
    num_experts=384,
    num_shared_experts=1,
    moe_top_k=8,
    d_expert=2048,
    first_dense_layers=1,
    rope_theta=50000.0,
    citation="arXiv:2501.kimi2 (paper-table)",
)
