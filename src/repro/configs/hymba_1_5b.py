"""Hymba 1.5B [hybrid] — parallel attention + mamba heads, meta tokens
[arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    sliding_window=2048,   # SWA on most layers (global on a few, cf. paper)
    meta_tokens=128,
    rope_theta=10000.0,
    citation="arXiv:2411.13676",
)
