"""HuBERT X-Large [audio] — encoder-only, wav2vec2 backbone
[arXiv:2106.07447]. Conv/mel frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, T, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,      # masked-prediction codebook
    causal=False,        # encoder-only: bidirectional attention, no decode
    input_mode="embeddings",
    rope_theta=10000.0,
    citation="arXiv:2106.07447",
)
