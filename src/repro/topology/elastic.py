"""Elastic learner membership: deterministic dropout/join schedules and
the masked, renormalized mixing algebra (DESIGN.md §8).

Real elastic clusters decide membership by wall-clock racing (a straggler
misses the sync window, a preempted VM rejoins later). Under SPMD that is
unexpressible — every program step is collective — so membership becomes
the same kind of controlled knob downpour staleness already is (§4): a
deterministic (period, L) 0/1 schedule, drawn once from a seed, carried
in ``MetaState.topo["membership"]`` so a resumed run replays the exact
same churn.

An absent learner at meta step n:
  * runs zero local steps (its slots in the static K-step scan are
    masked — the SPMD program never changes shape),
  * ships nothing and receives nothing (its row/column of the mixing
    matrix is masked), and
  * keeps its params / momentum / error-feedback residual frozen.

``mask_mixing_matrix`` keeps the masked W doubly stochastic by
*re-wiring around* absent learners (the stochastic complement / Markov
censoring of the absent block) instead of dumping the lost edge mass on
the diagonal: a present learner that lost its neighbor inherits that
neighbor's connections, weighted by how the censored chain would have
flowed through it —

    W'_pp = W_pp + W_pa (I - W_aa)^{-1} W_ap

For a symmetric doubly-stochastic W this preserves both row and column
sums over the present subset (censoring preserves stationarity), while
absent rows become identity rows (frozen learners), so the all-learner
mean is exactly preserved through churn. Unlike diagonal
renormalization — which makes the surviving chain *lazier* and shrinks
the spectral gap — censoring keeps the graph connected through the
hole, which is the churn-aware spectral-gap improvement pinned in
tests/test_elastic.py. With an all-present mask the correction term is
exactly zero and the arithmetic is the identity on W bit-for-bit
(`x * 1.0` and `x + 0.0` are exact), which keeps the ``drop_frac=0`` ≡
static-topology invariant a bitwise statement rather than an allclose
one.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ElasticConfig


def membership_schedule(L: int, elastic: ElasticConfig, *,
                        groups: int = 1) -> np.ndarray:
    """(period, L) f32 0/1 mask, deterministic in ``elastic.seed``.

    Per scheduled step, ``round(drop_frac * L)`` learners are absent,
    chosen by seeded permutation subject to every group keeping at least
    one present member (a fully-absent group has no average to take).

    An explicit ``elastic.schedule`` (how repro.chaos maps crash windows
    and the supervisor maps quarantine onto membership) wins over the
    drawn schedule verbatim — same validation: row length L, every group
    keeps >= 1 present member per row.
    """
    assert L >= 1 and L % groups == 0, (L, groups)
    S = L // groups
    if elastic.schedule is not None:
        sched = np.asarray(elastic.schedule, np.float32)
        assert sched.shape == (elastic.period, L), (
            f"explicit elastic schedule has shape {sched.shape}, expected "
            f"(period={elastic.period}, L={L})"
        )
        per_group = sched.reshape(elastic.period, groups, S).sum(axis=2)
        assert (per_group >= 1.0).all(), (
            "explicit elastic schedule leaves a group with no present "
            "learner in some row"
        )
        return sched
    rng = np.random.RandomState(elastic.seed)
    n_drop = min(int(round(elastic.drop_frac * L)), L - 1)
    sched = np.ones((elastic.period, L), np.float32)
    for t in range(elastic.period):
        dropped_per_group = [0] * groups
        dropped = []
        for j in rng.permutation(L):
            if len(dropped) == n_drop:
                break
            g = int(j) // S
            if dropped_per_group[g] < S - 1:  # keep >= 1 present per group
                dropped.append(int(j))
                dropped_per_group[g] += 1
        sched[t, dropped] = 0.0
    return sched


def membership_at(membership, step):
    """Step-indexed (L,) mask out of the (T, L) schedule (traced-step ok)."""
    T = membership.shape[0]
    return jnp.take(membership, step % T, axis=0)


def mask_mixing_matrix(W, m):
    """Mask a symmetric doubly-stochastic W by the (L,) 0/1 mask ``m``.

    Present rows are re-wired through their absent neighbors via the
    stochastic complement ``W_pp + W_pa (I - W_aa)^{-1} W_ap`` (Markov
    censoring); absent rows become identity rows (frozen learners).
    Returns a W' that is doubly stochastic restricted to the present
    subset, and bitwise equal to W when m is all ones (the correction is
    exactly zero then).

    jit-friendly: the p/a partition is expressed with diagonal masks, so
    shapes are static. ``I - diag(1-m) W diag(1-m)`` is block diagonal —
    identity on present coordinates, ``I - W_aa`` on absent ones — so
    one full-size solve computes ``(I - W_aa)^{-1} W_ap`` embedded.
    """
    L = W.shape[0]
    a = 1.0 - m
    eye = jnp.eye(L, dtype=W.dtype)
    W_pp = W * (m[:, None] * m[None, :])
    W_pa = W * (m[:, None] * a[None, :])
    W_ap = W * (a[:, None] * m[None, :])
    W_aa = W * (a[:, None] * a[None, :])
    # censor the absent block: routes that passed through absent learners
    # are summed over all lengths, Sum_k W_aa^k = (I - W_aa)^{-1}
    flow = jnp.linalg.solve(eye - W_aa, W_ap)
    correction = W_pa @ flow
    # the product of nonnegative factors; the solve can leave -eps where
    # an entry is exactly zero
    correction = jnp.maximum(correction, 0.0)
    return W_pp + correction + eye * a[:, None]


def present_edge_count(W, m):
    """Directed present-to-present edges of W (self loops excluded) — the
    step's wire multiplier under churn (degree-over-time accounting)."""
    L = W.shape[0]
    adj = (W > 0).astype(jnp.float32) * (1.0 - jnp.eye(L, dtype=jnp.float32))
    return jnp.sum(adj * (m[:, None] * m[None, :]))


def tree_where_mask(m, new, old):
    """Leafwise ``where`` with the (L,) mask broadcast over trailing dims:
    present learners take ``new``, absent keep ``old``."""
    import jax

    def sel(n, o):
        mm = m.reshape((m.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(mm != 0, n, o)

    return jax.tree.map(sel, new, old)
