"""Decentralized gossip: sparse doubly-stochastic mixing, no global state.

Instead of one all-reduce onto shared meta params, every learner keeps
its *own* meta params x_j and mixes with its graph neighbors each meta
step: m_j = sum_k W_jk x_k. Because W is doubly stochastic the learner
mean is preserved exactly (the consensus the convergence analyses track),
and Takezawa et al. 2022 (Momentum Tracking, PAPERS.md) show block-style
momentum survives — and helps — under such sparse mixing; the optional
``momentum_tracking`` flag additionally mixes the per-learner momentum
buffers with the same W.

State (MetaState.topo):
    params      x_j (L, ...) f32 — per-learner meta params
    momentum    v_j (L, ...) f32 — per-learner block momentum
    residual    per-learner error-feedback residual or None
    membership  (period, L) 0/1 elastic schedule (only when
                TopologyConfig.elastic is on — see topology/elastic.py:
                absent learners run 0 local steps, their mixing rows are
                masked with the matrix renormalized to stay doubly
                stochastic, and their state is frozen)

Per meta step (after the K local steps produce w_j from x_j):
    delta_j = w_j - x_j            (+ EF residual)
    m_j     = sum_k W_jk (x_k + C(delta_k))     -- the gossip exchange
    v_j     = mu v_j + eta (m_j - x_j)          [then v <- W v if tracking]
    x_j    += v_j ; learner j resets to x_j

``MetaState.global_params`` tracks mean_j x_j (what checkpoints/eval
see); with the complete graph and mu = 0 the update is exactly kavg's
all-reduce average (pinned in tests/test_topology.py). The mix itself is
the fused one-HBM-pass Pallas kernel (kernels/neighbor_mix.py) under
``use_pallas``, jnp oracle otherwise.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm import (
    CompressedReducer,
    DenseReducer,
    ErrorFeedback,
    dense_bytes,
    make_reducer_for,
)
from repro.configs.base import MAvgConfig
from repro.topology.base import (
    Topology,
    block_momentum_update,
    effective_momentum,
    learner_dtype,
)
from repro.topology.elastic import (
    mask_mixing_matrix,
    membership_at,
    membership_schedule,
    present_edge_count,
    tree_where_mask,
)
from repro.utils import (
    tree_add,
    tree_cast,
    tree_mean_axis0,
    tree_norm,
    tree_sub,
    tree_zeros_like,
)


# ---------------------------------------------------------------------------
# mixing matrices (all symmetric -> doubly stochastic; one_peer_exponential
# is time-varying with period ceil(log2 L))
# ---------------------------------------------------------------------------


def mixing_period(graph: str, L: int) -> int:
    """Number of distinct step-indexed matrices before the graph repeats
    (1 for the static graphs)."""
    if graph != "one_peer_exponential" or L <= 2:
        return 1
    return max(1, int(np.ceil(np.log2(L))))


def _neighbor_offsets(graph: str, L: int, step: int = 0) -> set[int]:
    if L <= 1:
        return set()
    if graph == "complete":
        return set(range(1, L))
    if graph == "ring":
        return {1 % L, (L - 1) % L} - {0}
    if graph == "exponential":
        offs = set()
        p = 1
        while p < L:
            offs.add(p)
            offs.add((L - p) % L)
            p *= 2
        return offs - {0}
    if graph == "one_peer_exponential":
        # step t keeps only the +/- 2^(t mod period) offsets of the
        # exponential graph (Takezawa et al. 2022: alternating one-peer
        # matrices reach the static graph's consensus rate at degree <= 2)
        o = 1 << (step % mixing_period(graph, L))
        return {o % L, (L - o) % L} - {0}
    raise ValueError(f"unknown gossip graph {graph!r}")


def mixing_matrix(graph: str, L: int, step: int = 0) -> np.ndarray:
    """(L, L) symmetric doubly-stochastic W with uniform edge weights
    1/(deg+1) over self + graph neighbors, at meta step ``step`` (the
    static graphs ignore it).

    ``one_peer_exponential`` with L a power of two uses the XOR perfect
    matching j <-> j ^ 2^(step mod period): exactly one peer per learner
    per step, weight 1/2 — the degree-1 regime of the paper.
    """
    if graph == "one_peer_exponential" and L > 1 and (L & (L - 1)) == 0:
        o = 1 << (step % mixing_period(graph, L))
        W = np.zeros((L, L), np.float32)
        for j in range(L):
            W[j, j] += 0.5
            W[j, j ^ o] += 0.5
        return W
    offs = _neighbor_offsets(graph, L, step)
    w = 1.0 / (len(offs) + 1)
    W = np.zeros((L, L), np.float32)
    for j in range(L):
        W[j, j] = w
        for o in offs:
            W[j, (j + o) % L] += w
    return W


def mixing_matrix_stack(graph: str, L: int) -> np.ndarray:
    """(period, L, L) stack of the step-indexed matrices — precomputed
    once and threaded through the fused neighbor-mix kernel, which
    selects W_t = stack[step % period] per meta step."""
    return np.stack(
        [mixing_matrix(graph, L, t) for t in range(mixing_period(graph, L))]
    )


def graph_degree(graph: str, L: int, step: int = 0) -> int:
    """Out-degree (neighbors excluding self) at ``step`` — the wire-bytes
    multiplier. Derived from the actual matrix so the XOR-matching and
    circulant variants can't drift from the model."""
    return int((mixing_matrix(graph, L, step)[0] > 0).sum()) - 1


def avg_graph_degree(graph: str, L: int) -> float:
    """Mean out-degree over one period — the degree-over-time wire model
    for the time-varying graphs (equals graph_degree for static ones)."""
    T = mixing_period(graph, L)
    return sum(graph_degree(graph, L, t) for t in range(T)) / T


def spectral_gap(W, mask=None) -> jnp.ndarray:
    """1 - |lambda_2| of a symmetric doubly-stochastic W — the consensus
    rate of one gossip round (gap 1 = complete graph / exact averaging,
    gap -> 0 = disconnected). Traceable (jnp.linalg.eigvalsh), so it
    works on the per-step masked matrices of elastic schedules, where the
    gap is the health metric that says whether churn broke mixing
    (telemetry, DESIGN.md §11).

    ``mask``: (L,) 0/1 present mask of an elastic-masked W
    (mask_mixing_matrix). Absent learners are identity rows — each a
    spurious eigenvalue 1 that would report gap 0 under ANY churn — so
    they are deflated to eigenvalue 0 (their diagonal 1 is subtracted),
    leaving the gap of the present-subset mixing block, which is the
    consensus rate of the learners actually exchanging this step.
    """
    W = jnp.asarray(W, jnp.float32)
    if W.shape[0] < 2:
        return jnp.float32(1.0)
    if mask is not None:
        W = W - jnp.diag(1.0 - jnp.asarray(mask, jnp.float32))
    lam = jnp.sort(jnp.abs(jnp.linalg.eigvalsh(W)))
    return 1.0 - lam[-2]


# ---------------------------------------------------------------------------
# per-learner compression (the reducer's compress stage without the mean)
# ---------------------------------------------------------------------------


def compress_stack(reducer, delta, residual, *, step, learners):
    """C(delta_j) per learner + EF residual algebra, without averaging.

    Gossip ships each learner's displacement to its neighbors instead of
    into a global mean, so it needs the reducer's compression stage alone.
    Returns (c, residual', wire_bytes).
    """
    if isinstance(reducer, ErrorFeedback):
        if residual is None:
            raise ValueError(
                "ErrorFeedback gossip reducer got residual=None — build the "
                "MetaState with the same topology (init_state allocates the "
                "residual in MetaState.topo)."
            )
        delta = tree_add(delta, residual)
        # _compress_residual returns the compression error of the same
        # pass (on the packed plane: computed in-register by the
        # compress-only kernel) — bitwise what tree_sub(delta, c) gives,
        # without another full-plane subtraction
        c, err, wire = reducer.inner._compress_residual(delta, step)
        return c, err, wire
    if isinstance(reducer, CompressedReducer):
        c, wire = reducer._compress(delta, step)
        return c, residual, wire
    assert isinstance(reducer, DenseReducer), reducer
    return delta, residual, dense_bytes(learners)


class Gossip(Topology):
    name = "gossip"

    def __init__(self, cfg: MAvgConfig, reducer=None):
        from repro.robust import make_robust

        t = cfg.topology
        self.cfg = cfg
        self.mu = effective_momentum(cfg)
        self.graph = t.graph
        self.momentum_tracking = t.momentum_tracking
        self.elastic = t.elastic
        # gossip has no L-way mean to replace (the neighbor mix is a
        # weighted exchange), so the robust influence bound here is the
        # per-learner displacement clip + anomaly scoring; the trimmed/
        # median estimator applies to the mean-based topologies
        self.robust = make_robust(cfg)
        self.reducer = (
            reducer if reducer is not None
            else make_reducer_for(t.inner_comm or cfg.comm, cfg.meta_dtype)
        )
        self.period = mixing_period(t.graph, cfg.num_learners)
        self.W_stack = mixing_matrix_stack(t.graph, cfg.num_learners)
        self.W = self.W_stack[0]  # step-0 matrix (static graphs: the matrix)
        self.degree = graph_degree(t.graph, cfg.num_learners)
        self.avg_degree = avg_graph_degree(t.graph, cfg.num_learners)
        # per-step-matrix spectral gaps of the static schedule; elastic
        # masks recompute the gap in-trace on the masked matrix
        # (spectral_gap) since W then varies by mask. Kept as jnp ops —
        # topologies may be constructed inside a trace (make_topology is
        # called per trace), where a host float() would leak the tracer
        self.gap_stack = jnp.stack([spectral_gap(W) for W in self.W_stack])

    # ------------------------------------------------------------------
    def init_buffers(self, gp, cfg: MAvgConfig):
        L = cfg.num_learners
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape)
            .astype(jnp.dtype(cfg.meta_dtype)), gp
        )
        topo = {
            "params": params,
            "momentum": tree_zeros_like(params),
            "residual": self.reducer.init_residual(gp, L),
        }
        if self.elastic is not None:
            topo["membership"] = jnp.asarray(
                membership_schedule(L, self.elastic)
            )
        return None, topo

    # ------------------------------------------------------------------
    def local_steps(self, topo, step):
        if self.elastic is None:
            return None
        m = membership_at(topo["membership"], step)
        return (jnp.int32(self.cfg.k_steps) * m).astype(jnp.int32)

    # ------------------------------------------------------------------
    def _mix_tree(self, tree, W):
        from repro.kernels import ops as kops

        return kops.neighbor_mix_tree(tree, W, use_pallas=self.cfg.use_pallas)

    def mix(self, learners, gp, v, comm_residual, topo, *, step):
        cfg = self.cfg
        L = cfg.num_learners
        ldt = learner_dtype(learners)
        xp = topo["params"]  # (L, ...) f32

        from repro.kernels import ops as kops

        W = kops.mixing_matrix_at(jnp.asarray(self.W_stack), step)
        mask = None
        if self.elastic is not None:
            mask = membership_at(topo["membership"], step)
            W = mask_mixing_matrix(W, mask)

        delta = jax.tree.map(
            lambda w, x: w.astype(jnp.float32) - x.astype(jnp.float32),
            learners, xp,
        )
        rmetrics = {}
        if self.robust is not None:
            # clip each learner's displacement BEFORE compression: the
            # neighbors (and the EF residual) only ever see the clipped
            # payload — over-budget mass is rejected, not deferred
            delta, topo, rmetrics = self.robust.clip_stack(delta, topo)
        c, residual, wire = compress_stack(
            self.reducer, delta, topo["residual"], step=step,
            learners=learners,
        )
        x_hat = tree_add(tree_cast(xp, jnp.float32), c)
        mixed = tree_cast(self._mix_tree(x_hat, W), cfg.meta_dtype)

        vL = topo["momentum"]
        xp_new, vL = block_momentum_update(
            xp, vL, mixed, mu=self.mu, eta=cfg.meta_lr,
            nesterov=cfg.nesterov, use_pallas=cfg.use_pallas,
        )
        if self.momentum_tracking:
            # momentum-tracking correction: mix the momentum buffers with
            # the same W so the momentum consensus follows the param one
            vL = self._mix_tree(vL, W)
        if mask is not None:
            # absent learners are frozen in place: params, momentum and
            # EF residual all keep their pre-step values (their masked W
            # row is the identity, but the momentum recursion would still
            # decay v and the EF algebra would still consume the residual)
            xp_new = tree_where_mask(mask, xp_new, xp)
            vL = tree_where_mask(mask, vL, topo["momentum"])
            if residual is not None:
                residual = tree_where_mask(mask, residual, topo["residual"])

        learners = tree_cast(xp_new, ldt)
        gp_new = tree_cast(tree_mean_axis0(xp_new), cfg.meta_dtype)

        db = dense_bytes(learners)
        consensus = tree_norm(
            tree_sub(xp_new, jax.tree.map(
                lambda m, x: jnp.broadcast_to(m[None], x.shape), gp_new, xp_new
            ))
        )
        membership = topo.get("membership")
        # the clip ring (robust_ring/robust_count, already advanced by
        # clip_stack above) must survive the rebuild or the jit carry
        # structure breaks
        carried = {
            k: topo[k] for k in ("robust_ring", "robust_count") if k in topo
        }
        topo = {"params": xp_new, "momentum": vL, "residual": residual,
                **carried}
        if membership is not None:
            topo["membership"] = membership  # the schedule rides unchanged
        # every learner ships its (compressed) displacement along each of
        # its live graph edges this step — all inter-node. The edge count
        # is taken from the step's actual matrix (time-varying graphs) and
        # mask (elastic membership): the degree-over-time wire model.
        edges = present_edge_count(
            W, jnp.ones((L,), jnp.float32) if mask is None else mask
        )
        comm_bytes = (wire / L) * edges
        comm_dense = (db / L) * edges
        # mixing-matrix health: the static schedule's gap is precomputed
        # per step matrix; under elastic masking the gap of the ACTUAL
        # masked W is the live signal that churn kept the graph mixing
        gap = (
            spectral_gap(W, mask) if mask is not None
            else jnp.take(self.gap_stack, step % self.period)
        )
        metrics = {
            "v_norm": tree_norm(vL),
            "displacement_norm": tree_norm(tree_sub(mixed, xp)),
            "consensus_dist": consensus,
            "mixing_spectral_gap": gap,
            "comm_bytes": comm_bytes,
            "comm_bytes_dense": comm_dense,
            "comm_compression": jnp.where(
                comm_bytes > 0, comm_dense / jnp.maximum(comm_bytes, 1.0),
                jnp.float32(1.0),
            ),
        }
        metrics.update(rmetrics)
        if mask is not None:
            metrics["present_count"] = jnp.sum(mask)
        return gp_new, v, learners, comm_residual, topo, metrics
