"""Decentralized gossip: sparse doubly-stochastic mixing, no global state.

Instead of one all-reduce onto shared meta params, every learner keeps
its *own* meta params x_j and mixes with its graph neighbors each meta
step: m_j = sum_k W_jk x_k. Because W is doubly stochastic the learner
mean is preserved exactly (the consensus the convergence analyses track),
and Takezawa et al. 2022 (Momentum Tracking, PAPERS.md) show block-style
momentum survives — and helps — under such sparse mixing; the optional
``momentum_tracking`` flag additionally mixes the per-learner momentum
buffers with the same W.

State (MetaState.topo):
    params    x_j (L, ...) f32 — per-learner meta params
    momentum  v_j (L, ...) f32 — per-learner block momentum
    residual  per-learner error-feedback residual or None

Per meta step (after the K local steps produce w_j from x_j):
    delta_j = w_j - x_j            (+ EF residual)
    m_j     = sum_k W_jk (x_k + C(delta_k))     -- the gossip exchange
    v_j     = mu v_j + eta (m_j - x_j)          [then v <- W v if tracking]
    x_j    += v_j ; learner j resets to x_j

``MetaState.global_params`` tracks mean_j x_j (what checkpoints/eval
see); with the complete graph and mu = 0 the update is exactly kavg's
all-reduce average (pinned in tests/test_topology.py). The mix itself is
the fused one-HBM-pass Pallas kernel (kernels/neighbor_mix.py) under
``use_pallas``, jnp oracle otherwise.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm import (
    CompressedReducer,
    DenseReducer,
    ErrorFeedback,
    dense_bytes,
    make_reducer_for,
)
from repro.configs.base import MAvgConfig
from repro.topology.base import (
    Topology,
    block_momentum_update,
    effective_momentum,
    learner_dtype,
)
from repro.utils import (
    tree_add,
    tree_cast,
    tree_mean_axis0,
    tree_norm,
    tree_sub,
    tree_zeros_like,
)


# ---------------------------------------------------------------------------
# mixing matrices (all symmetric circulant -> doubly stochastic)
# ---------------------------------------------------------------------------


def _neighbor_offsets(graph: str, L: int) -> set[int]:
    if L <= 1:
        return set()
    if graph == "complete":
        return set(range(1, L))
    if graph == "ring":
        return {1 % L, (L - 1) % L} - {0}
    if graph == "exponential":
        offs = set()
        p = 1
        while p < L:
            offs.add(p)
            offs.add((L - p) % L)
            p *= 2
        return offs - {0}
    raise ValueError(f"unknown gossip graph {graph!r}")


def graph_degree(graph: str, L: int) -> int:
    """Out-degree (neighbors excluding self) — the wire-bytes multiplier."""
    return len(_neighbor_offsets(graph, L))


def mixing_matrix(graph: str, L: int) -> np.ndarray:
    """(L, L) symmetric doubly-stochastic W with uniform edge weights
    1/(deg+1) over self + graph neighbors."""
    offs = _neighbor_offsets(graph, L)
    w = 1.0 / (len(offs) + 1)
    W = np.zeros((L, L), np.float32)
    for j in range(L):
        W[j, j] = w
        for o in offs:
            W[j, (j + o) % L] += w
    return W


# ---------------------------------------------------------------------------
# per-learner compression (the reducer's compress stage without the mean)
# ---------------------------------------------------------------------------


def compress_stack(reducer, delta, residual, *, step, learners):
    """C(delta_j) per learner + EF residual algebra, without averaging.

    Gossip ships each learner's displacement to its neighbors instead of
    into a global mean, so it needs the reducer's compression stage alone.
    Returns (c, residual', wire_bytes).
    """
    if isinstance(reducer, ErrorFeedback):
        if residual is None:
            raise ValueError(
                "ErrorFeedback gossip reducer got residual=None — build the "
                "MetaState with the same topology (init_state allocates the "
                "residual in MetaState.topo)."
            )
        delta = tree_add(delta, residual)
        c, wire = reducer.inner._compress(delta, step)
        return c, tree_sub(delta, c), wire
    if isinstance(reducer, CompressedReducer):
        c, wire = reducer._compress(delta, step)
        return c, residual, wire
    assert isinstance(reducer, DenseReducer), reducer
    return delta, residual, dense_bytes(learners)


class Gossip(Topology):
    name = "gossip"

    def __init__(self, cfg: MAvgConfig, reducer=None):
        t = cfg.topology
        self.cfg = cfg
        self.mu = effective_momentum(cfg)
        self.graph = t.graph
        self.momentum_tracking = t.momentum_tracking
        self.reducer = (
            reducer if reducer is not None
            else make_reducer_for(t.inner_comm or cfg.comm, cfg.meta_dtype)
        )
        self.W = mixing_matrix(t.graph, cfg.num_learners)
        self.degree = graph_degree(t.graph, cfg.num_learners)

    # ------------------------------------------------------------------
    def init_buffers(self, gp, cfg: MAvgConfig):
        L = cfg.num_learners
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape)
            .astype(jnp.dtype(cfg.meta_dtype)), gp
        )
        topo = {
            "params": params,
            "momentum": tree_zeros_like(params),
            "residual": self.reducer.init_residual(gp, L),
        }
        return None, topo

    # ------------------------------------------------------------------
    def _mix_tree(self, tree):
        from repro.kernels import ops as kops

        return kops.neighbor_mix_tree(
            tree, jnp.asarray(self.W), use_pallas=self.cfg.use_pallas
        )

    def mix(self, learners, gp, v, comm_residual, topo, *, step):
        cfg = self.cfg
        ldt = learner_dtype(learners)
        xp = topo["params"]  # (L, ...) f32

        delta = jax.tree.map(
            lambda w, x: w.astype(jnp.float32) - x.astype(jnp.float32),
            learners, xp,
        )
        c, residual, wire = compress_stack(
            self.reducer, delta, topo["residual"], step=step,
            learners=learners,
        )
        x_hat = tree_add(tree_cast(xp, jnp.float32), c)
        mixed = tree_cast(self._mix_tree(x_hat), cfg.meta_dtype)

        vL = topo["momentum"]
        xp_new, vL = block_momentum_update(
            xp, vL, mixed, mu=self.mu, eta=cfg.meta_lr,
            nesterov=cfg.nesterov, use_pallas=cfg.use_pallas,
        )
        if self.momentum_tracking:
            # momentum-tracking correction: mix the momentum buffers with
            # the same W so the momentum consensus follows the param one
            vL = self._mix_tree(vL)

        learners = tree_cast(xp_new, ldt)
        gp_new = tree_cast(tree_mean_axis0(xp_new), cfg.meta_dtype)

        db = dense_bytes(learners)
        consensus = tree_norm(
            tree_sub(xp_new, jax.tree.map(
                lambda m, x: jnp.broadcast_to(m[None], x.shape), gp_new, xp_new
            ))
        )
        topo = {"params": xp_new, "momentum": vL, "residual": residual}
        metrics = {
            "v_norm": tree_norm(vL),
            "displacement_norm": tree_norm(tree_sub(mixed, xp)),
            "consensus_dist": consensus,
            # every learner ships its (compressed) displacement to each of
            # its `degree` neighbors, every meta step — all inter-node
            "comm_bytes": wire * self.degree,
            "comm_bytes_dense": db * self.degree,
        }
        return gp_new, v, learners, comm_residual, topo, metrics
