"""The Topology protocol: who averages with whom, how often.

The paper's meta step is one *flat* all-reduce every K local steps —
every learner averages with every other learner. This subsystem makes
that structure a first-class, swappable object (DESIGN.md §7), the same
way ``repro.comm`` did for what goes on the wire:

    mix(learners, gp, v, comm_residual, topo, step=n)
        -> (gp', v', learners', comm_residual', topo', metrics)

``learners`` is the stacked (L, ...) learner pytree after the K local
steps; ``gp``/``v`` are the meta params w~ and block momentum; ``topo``
is the topology's own buffer pytree riding in ``MetaState.topo`` (group
params/momentum for Hierarchical, per-learner params/momentum for
Gossip; None for flat). Each topology owns its Reducer(s), so every edge
class can carry its own compression scheme — dense intra-group,
int8_topk cross-group is where the inter-node byte savings land.

Topologies are built once per trace by ``make_topology`` (see
``repro.topology``), which also resolves the *effective* block-momentum
coefficient (kavg is mavg with mu forced to 0 — Remark 2) at
construction instead of per meta_step call.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MAvgConfig
# the packed-plane dispatch predicate lives with the kernels it routes to
# (same layout constants) — re-exported here for the topologies
from repro.kernels.ops import is_packed_plane
from repro.utils import (
    tree_broadcast_learners,
    tree_cast,
    tree_norm,
    tree_sub,
)


def effective_momentum(cfg: MAvgConfig) -> float:
    """mu actually applied by the meta update: kavg is mavg with mu = 0."""
    return 0.0 if cfg.algorithm == "kavg" else cfg.momentum


def block_momentum_update(gp, v, avg, *, mu, eta=1.0, nesterov=False,
                          use_pallas=False):
    """v <- mu v + eta d ; w~ <- w~ + v  (+ optional Nesterov lookahead).

    Works on plain pytrees and on (G, ...)/(L, ...) stacked trees — the
    update is elementwise. ``use_pallas`` routes through the fused
    single-HBM-pass kernel (kernels/block_momentum.py).
    """
    import jax.numpy as jnp

    if use_pallas:
        from repro.kernels import ops as kops

        return kops.block_momentum_tree(
            gp, v, avg, mu=mu, eta=eta, nesterov=nesterov
        )
    d = tree_sub(avg, gp)
    v = jax.tree.map(lambda vi, di: mu * vi + eta * di, v, d)
    if nesterov:
        gp = jax.tree.map(
            lambda w, vi, di: w + mu * vi + eta * di, gp, v, d
        )
    else:
        gp = jax.tree.map(jnp.add, gp, v)
    return gp, v


def learner_dtype(learners):
    return jax.tree.leaves(learners)[0].dtype




def fused_momentum_broadcast_update(gp, v, avg, *, mu, eta, num_learners,
                                    ldtype, nesterov=False,
                                    use_pallas=False):
    """The packed meta plane's whole meta update in one pass: block
    momentum + the (L, rows, 128) learner-reset broadcast emitted
    directly from the update (kernels/fused_meta.py) instead of
    re-reading w~' through tree_broadcast_learners — one full-plane HBM
    read fewer per meta step (DESIGN.md §10). Bit-identical to
    ``block_momentum_update`` followed by cast + broadcast.

    Returns (gp', v', learners).
    """
    from repro.kernels import ops as kops

    return kops.fused_momentum_broadcast(
        gp, v, avg, mu=mu, eta=eta, num_learners=num_learners,
        ldtype=ldtype, nesterov=nesterov, use_pallas=use_pallas,
    )


class Topology:
    """Base: one meta-level mixing step over the learner stack.

    Synchrony itself is part of the protocol (DESIGN.md §12): the clock
    hooks below describe *when* learners reach their K-step boundary.
    Synchronous topologies are the tau=0 degenerate case — every learner
    fires every meta tick — which is what the defaults encode; the async
    bounded-staleness server (``topology/async_server.py``) overrides
    them with a deterministic per-learner step-time profile.
    """

    name = "topology"

    def init_buffers(self, gp, cfg: MAvgConfig) -> tuple[Any, Any]:
        """(comm_residual, topo) buffers for MetaState (None = unused)."""
        return None, None

    def fire_mask(self, topo, step):
        """(L,) bool: which learners push a finished K-step block at this
        meta tick. None = all of them (the synchronous barrier)."""
        return None

    def work_completed(self, step) -> int:
        """Cumulative K-step blocks completed through meta step ``step``
        (host-side, deterministic): the trainer's effective-samples
        accounting. Synchronous topologies complete L blocks per tick."""
        cfg = getattr(self, "cfg", None)
        return (int(step) + 1) * (cfg.num_learners if cfg is not None else 1)

    def local_steps(self, topo, step):
        """(L,) int32 active local-step counts for this meta step, or None
        when every learner runs the full cfg.k_steps.

        Heterogeneous execution hooks in here: per-group K_g (hierarchical
        ``group_k``) and elastic membership (absent learners run zero
        steps) both reduce to masking trailing iterations of the static
        K-step scan in ``core.meta._local_phase`` — the SPMD program never
        changes shape. ``step`` may be traced (membership is step-indexed).
        """
        return None

    def mix(self, learners, gp, v, comm_residual, topo, *, step):
        raise NotImplementedError


class FlatAllReduce(Topology):
    """Current behavior, extracted: one global average + block momentum.

    All traffic is a single all-reduce over every learner — under the
    wire model every byte crosses the slow inter-node links.
    """

    name = "flat"

    def __init__(self, cfg: MAvgConfig, reducer=None):
        from repro.comm import make_reducer
        from repro.robust import make_robust

        self.cfg = cfg
        self.mu = effective_momentum(cfg)
        self.robust = make_robust(cfg)
        agg = (
            self.robust.aggregate
            if self.robust is not None and self.robust.aggregates else None
        )
        self.reducer = (
            make_reducer(cfg, aggregate=agg) if reducer is None else reducer
        )

    def init_buffers(self, gp, cfg: MAvgConfig):
        return self.reducer.init_residual(gp, cfg.num_learners), None

    def mix(self, learners, gp, v, comm_residual, topo, *, step):
        cfg = self.cfg
        metrics = {}
        if self.robust is not None:
            # score + norm-clip the displacement stack BEFORE the reducer:
            # the wire compressor (and so the EF residual) only ever sees
            # the clipped displacement — clipped-away mass is rejected,
            # not deferred (DESIGN.md §14)
            learners, topo, rmetrics = self.robust.clip_learners(
                learners, gp, topo
            )
            metrics.update(rmetrics)
        avg, comm_residual, comm_metrics = self.reducer.reduce(
            learners, gp, comm_residual, step=step
        )
        avg = tree_cast(avg, cfg.meta_dtype)
        # pre-reset learner consensus: how far the K local steps drove the
        # learners apart before this average pulled them back — the
        # quantity the K/mu trade-off analyses bound (telemetry, DESIGN.md
        # §11; after the reset below consensus is identically zero)
        consensus = tree_norm(
            jax.tree.map(lambda w, a: w.astype(jnp.float32) - a[None],
                         learners, tree_cast(avg, jnp.float32))
        )
        if is_packed_plane(gp):
            # packed meta plane: momentum + learner reset in one pass
            gp_new, v, learners = fused_momentum_broadcast_update(
                gp, v, avg, mu=self.mu, eta=cfg.meta_lr,
                num_learners=cfg.num_learners,
                ldtype=learner_dtype(learners), nesterov=cfg.nesterov,
                use_pallas=cfg.use_pallas,
            )
        else:
            gp_new, v = block_momentum_update(
                gp, v, avg, mu=self.mu, eta=cfg.meta_lr,
                nesterov=cfg.nesterov, use_pallas=cfg.use_pallas,
            )
            learners = tree_broadcast_learners(
                tree_cast(gp_new, learner_dtype(learners)), cfg.num_learners
            )
        metrics.update({
            "v_norm": tree_norm(v),
            "displacement_norm": tree_norm(tree_sub(avg, gp)),
            "consensus_dist": consensus,
        })
        metrics.update(comm_metrics)
        return gp_new, v, learners, comm_residual, topo, metrics
