"""Two-level M-AVG: learners partitioned into G groups.

Real pods are hierarchical — fast intra-node links, slow inter-node
links. This topology averages *within* each group every meta step (K
local steps) and *across* groups only every H meta steps, so the slow
edge class is touched once per K·H local steps. Each level runs its own
block momentum (mu_in = MAvgConfig.momentum on the group params, mu_out
= TopologyConfig.outer_momentum on the global params) — the two-level
momentum recursion of DESIGN.md §7 — and its own Reducer, so the
cross-group displacement can ship int8_topk while intra-group stays
dense.

State (MetaState.topo):
    group_params    w~_g (G, ...) f32 — per-group meta params
    group_momentum  v_g  (G, ...) f32 — inner block momentum
    inner_residual  per-group error-feedback stacks (G, S, ...) or None
    outer_residual  cross-group EF residual (G, ...) or None

The outer update applies the displacement A - w~ with unit step
(eta_out = 1), so outer_every=1 + outer_momentum=0 is an exact
pass-through of the inner level: Hierarchical(groups=1) reproduces flat
mavg bit-for-bit at any meta_lr (pinned in tests/test_topology.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm import dense_bytes, make_reducer_for
from repro.configs.base import MAvgConfig
from repro.topology.base import (
    Topology,
    block_momentum_update,
    effective_momentum,
    learner_dtype,
)
from repro.utils import tree_cast, tree_norm, tree_sub, tree_zeros_like


class Hierarchical(Topology):
    name = "hierarchical"

    def __init__(self, cfg: MAvgConfig, reducer=None):
        t = cfg.topology
        assert cfg.num_learners % t.groups == 0, (cfg.num_learners, t.groups)
        self.cfg = cfg
        self.G = t.groups
        self.S = cfg.num_learners // t.groups
        self.H = t.outer_every
        self.mu_in = effective_momentum(cfg)
        self.mu_out = t.outer_momentum
        self.inner_reducer = (
            reducer if reducer is not None
            else make_reducer_for(t.inner_comm or cfg.comm, cfg.meta_dtype)
        )
        self.outer_reducer = make_reducer_for(
            t.outer_comm or cfg.comm, cfg.meta_dtype
        )

    # ------------------------------------------------------------------
    def init_buffers(self, gp, cfg: MAvgConfig):
        G = self.G
        gparams = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape)
            .astype(jnp.dtype(cfg.meta_dtype)), gp
        )
        inner_res = self.inner_reducer.init_residual(gp, self.S)
        if inner_res is not None:  # stack the per-group EF residuals
            inner_res = jax.tree.map(
                lambda x: jnp.zeros((G,) + x.shape, x.dtype), inner_res
            )
        topo = {
            "group_params": gparams,
            "group_momentum": tree_zeros_like(gparams),
            "inner_residual": inner_res,
            "outer_residual": self.outer_reducer.init_residual(gp, G),
        }
        return None, topo

    # ------------------------------------------------------------------
    def mix(self, learners, gp, v, comm_residual, topo, *, step):
        cfg = self.cfg
        G, S = self.G, self.S
        ldt = learner_dtype(learners)
        gparams = topo["group_params"]
        gmom = topo["group_momentum"]

        # ---- inner level: per-group average + block momentum (every K) --
        grouped = jax.tree.map(
            lambda x: x.reshape((G, S) + x.shape[1:]), learners
        )

        def inner(lrn_g, gp_g, res_g):
            avg, res, m = self.inner_reducer.reduce(
                lrn_g, gp_g, res_g, step=step
            )
            # bytes are python floats (static); lift so vmap can broadcast
            return avg, res, {k: jnp.asarray(mv, jnp.float32)
                              for k, mv in m.items()}

        avg_g, inner_res, im = jax.vmap(inner)(
            grouped, gparams, topo["inner_residual"]
        )
        avg_g = tree_cast(avg_g, cfg.meta_dtype)
        inner_disp = tree_norm(tree_sub(avg_g, gparams))
        gparams, gmom = block_momentum_update(
            gparams, gmom, avg_g, mu=self.mu_in, eta=cfg.meta_lr,
            nesterov=cfg.nesterov, use_pallas=cfg.use_pallas,
        )

        # ---- outer level: cross-group average + block momentum (every H) —
        # under lax.cond so the quantize/top-k/momentum work runs only on
        # the 1-in-H steps where it fires, not computed-and-discarded
        do_outer = ((step + 1) % self.H) == 0
        gparams_inner = gparams

        def _outer_fire(_):
            A, ores, om = self.outer_reducer.reduce(
                gparams_inner, gp, topo["outer_residual"], step=step
            )
            A = tree_cast(A, cfg.meta_dtype)
            gp_out, v_out = block_momentum_update(
                gp, v, A, mu=self.mu_out, eta=1.0, nesterov=False,
                use_pallas=cfg.use_pallas,
            )
            gpar = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), gp_out
            )
            # bytes are static python floats inside the trace; lift them so
            # both branches return the same pytree
            return gp_out, v_out, gpar, ores, jnp.float32(om["comm_bytes"])

        def _outer_hold(_):
            return gp, v, gparams_inner, topo["outer_residual"], jnp.float32(0)

        gp_new, v_new, gparams, outer_res_new, outer_bytes = lax.cond(
            do_outer, _outer_fire, _outer_hold, None
        )

        # ---- reset learners to their group's params ---------------------
        learners = jax.tree.map(
            lambda g: jnp.broadcast_to(
                g[:, None], (G, S) + g.shape[1:]
            ).reshape((G * S,) + g.shape[1:]).astype(ldt),
            gparams,
        )

        topo = {
            "group_params": gparams,
            "group_momentum": gmom,
            "inner_residual": inner_res,
            "outer_residual": outer_res_new,
        }
        metrics = {
            "v_norm": tree_norm(v_new),
            "group_v_norm": tree_norm(gmom),
            "displacement_norm": inner_disp,
            "outer_fired": do_outer.astype(jnp.float32),
            # per-edge-class modeled wire traffic (intra every step,
            # inter only when the outer level fires)
            "comm_bytes_intra": jnp.sum(im["comm_bytes"]),
            "comm_bytes_inter": outer_bytes,
            "comm_bytes": jnp.sum(im["comm_bytes"]) + outer_bytes,
            "comm_bytes_dense": (
                jnp.sum(im["comm_bytes_dense"]) + dense_bytes(gparams_inner)
            ),
        }
        return gp_new, v_new, learners, comm_residual, topo, metrics
