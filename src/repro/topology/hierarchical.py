"""Two-level M-AVG: learners partitioned into G groups.

Real pods are hierarchical — fast intra-node links, slow inter-node
links. This topology averages *within* each group every meta step (K
local steps) and *across* groups only every H meta steps, so the slow
edge class is touched once per K·H local steps. Each level runs its own
block momentum (mu_in = MAvgConfig.momentum on the group params, mu_out
= TopologyConfig.outer_momentum on the global params) — the two-level
momentum recursion of DESIGN.md §7 — and its own Reducer, so the
cross-group displacement can ship int8_topk while intra-group stays
dense.

State (MetaState.topo):
    group_params    w~_g (G, ...) f32 — per-group meta params
    group_momentum  v_g  (G, ...) f32 — inner block momentum
    inner_residual  per-group error-feedback stacks (G, S, ...) or None
    outer_residual  cross-group EF residual (G, ...) or None
    membership      (period, L) 0/1 elastic schedule (only when
                    TopologyConfig.elastic is on): absent learners run 0
                    local steps and the group average renormalizes over
                    the present count (topology/elastic.py, DESIGN.md §8)

Heterogeneous K (TopologyConfig.group_k): group g applies only the first
K_g of the K scanned local updates — masked inside the static scan via
``local_steps``, so uniform group_k reproduces scalar K bit-for-bit.

The outer update applies the displacement A - w~ with unit step
(eta_out = 1), so outer_every=1 + outer_momentum=0 is an exact
pass-through of the inner level: Hierarchical(groups=1) reproduces flat
mavg bit-for-bit at any meta_lr (pinned in tests/test_topology.py).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm import dense_bytes, make_reducer_for
from repro.configs.base import MAvgConfig
from repro.topology.base import (
    Topology,
    block_momentum_update,
    effective_momentum,
    fused_momentum_broadcast_update,
    is_packed_plane,
    learner_dtype,
)
from repro.topology.elastic import (
    membership_at,
    membership_schedule,
    tree_where_mask,
)
from repro.topology.gossip import compress_stack
from repro.utils import tree_cast, tree_norm, tree_sub, tree_zeros_like


class Hierarchical(Topology):
    name = "hierarchical"

    def __init__(self, cfg: MAvgConfig, reducer=None):
        t = cfg.topology
        assert cfg.num_learners % t.groups == 0, (cfg.num_learners, t.groups)
        self.cfg = cfg
        self.G = t.groups
        self.S = cfg.num_learners // t.groups
        self.H = t.outer_every
        self.mu_in = effective_momentum(cfg)
        self.mu_out = t.outer_momentum
        self.group_k = t.group_k
        self.elastic = t.elastic
        # per-learner base local-step counts: group g runs K_g of the K
        # scanned steps (heterogeneous K — groups behind slow inter-node
        # edges can afford more local steps than intra-node ones)
        self._base_steps = (
            np.repeat(np.asarray(t.group_k, np.int32), self.S)
            if t.group_k is not None
            else np.full((cfg.num_learners,), cfg.k_steps, np.int32)
        )
        from repro.robust import make_robust

        self.robust = make_robust(cfg)
        # both levels get the robust estimator: the inner trim applies at
        # group width S, the outer at G (trim_for clamps per width —
        # defense in depth over already-robust group means)
        agg = (
            self.robust.aggregate
            if self.robust is not None and self.robust.aggregates else None
        )
        self.inner_reducer = (
            reducer if reducer is not None
            else make_reducer_for(t.inner_comm or cfg.comm, cfg.meta_dtype,
                                  aggregate=agg)
        )
        self.outer_reducer = make_reducer_for(
            t.outer_comm or cfg.comm, cfg.meta_dtype, aggregate=agg
        )

    # ------------------------------------------------------------------
    def init_buffers(self, gp, cfg: MAvgConfig):
        G = self.G
        gparams = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape)
            .astype(jnp.dtype(cfg.meta_dtype)), gp
        )
        inner_res = self.inner_reducer.init_residual(gp, self.S)
        if inner_res is not None:  # stack the per-group EF residuals
            inner_res = jax.tree.map(
                lambda x: jnp.zeros((G,) + x.shape, x.dtype), inner_res
            )
        topo = {
            "group_params": gparams,
            "group_momentum": tree_zeros_like(gparams),
            "inner_residual": inner_res,
            "outer_residual": self.outer_reducer.init_residual(gp, G),
        }
        if self.elastic is not None:
            topo["membership"] = jnp.asarray(
                membership_schedule(cfg.num_learners, self.elastic, groups=G)
            )
        return None, topo

    # ------------------------------------------------------------------
    def local_steps(self, topo, step):
        if self.group_k is None and self.elastic is None:
            return None
        base = jnp.asarray(self._base_steps)
        if self.elastic is None:
            return base
        m = membership_at(topo["membership"], step)
        return base * m.astype(jnp.int32)

    # ------------------------------------------------------------------
    def mix(self, learners, gp, v, comm_residual, topo, *, step):
        cfg = self.cfg
        G, S = self.G, self.S
        ldt = learner_dtype(learners)
        gparams = topo["group_params"]
        gmom = topo["group_momentum"]

        rmetrics = {}
        if self.robust is not None:
            # score + clip each learner's displacement from its own
            # group's params before the inner reducers run — the inner
            # wire (and EF residual) only ever sees clipped payloads
            anchor = jax.tree.map(lambda g: jnp.repeat(g, S, axis=0), gparams)
            learners, topo, rmetrics = self.robust.clip_anchored(
                learners, anchor, topo
            )

        # ---- inner level: per-group average + block momentum (every K) --
        grouped = jax.tree.map(
            lambda x: x.reshape((G, S) + x.shape[1:]), learners
        )

        if self.elastic is None:
            def inner(lrn_g, gp_g, res_g):
                avg, res, m = self.inner_reducer.reduce(
                    lrn_g, gp_g, res_g, step=step
                )
                # bytes are python floats (static); lift so vmap broadcasts
                return avg, res, {k: jnp.asarray(mv, jnp.float32)
                                  for k, mv in m.items()}

            avg_g, inner_res, im = jax.vmap(inner)(
                grouped, gparams, topo["inner_residual"]
            )
            intra_bytes = jnp.sum(im["comm_bytes"])
            intra_dense = jnp.sum(im["comm_bytes_dense"])
        else:
            # membership-masked inner average: present learners only.
            # Absent learners ran 0 local steps, so their displacement is
            # exactly 0 and ships nothing; the group mean renormalizes
            # over the present count, and absent EF residuals are frozen
            # (an absent learner can't flush its pending error either).
            from repro.comm import DenseReducer

            mask = membership_at(topo["membership"], step).reshape(G, S)
            delta = jax.tree.map(
                lambda w, g: (w.astype(jnp.float32)
                              - g.astype(jnp.float32)[:, None]),
                grouped, gparams,
            )

            def masked_mean(tree, m_g, n_present):
                return jax.tree.map(
                    lambda x: jnp.sum(
                        x.astype(jnp.float32)
                        * m_g.reshape((S,) + (1,) * (x.ndim - 1)), axis=0
                    ) / jnp.maximum(n_present, 1.0),
                    tree,
                )

            def inner_masked(lrn_g, delta_g, gp_g, res_g, m_g):
                n_present = jnp.sum(m_g)
                if isinstance(self.inner_reducer, DenseReducer):
                    # mirror DenseReducer.reduce's mean-of-weights algebra
                    # (not gp + mean(delta)) so the all-present mask is
                    # bit-for-bit the static path
                    avg = masked_mean(lrn_g, m_g, n_present)
                    return avg, res_g, jnp.float32(dense_bytes(lrn_g)), n_present
                c, res, wire = compress_stack(
                    self.inner_reducer, delta_g, res_g, step=step,
                    learners=lrn_g,
                )
                avg = jax.tree.map(
                    lambda g, a: g.astype(jnp.float32) + a,
                    gp_g, masked_mean(c, m_g, n_present),
                )
                return avg, res, jnp.float32(wire), n_present

            avg_g, inner_res, wire_g, present_g = jax.vmap(inner_masked)(
                grouped, delta, gparams, topo["inner_residual"], mask
            )
            if inner_res is not None:
                inner_res = jax.vmap(tree_where_mask)(
                    mask, inner_res, topo["inner_residual"]
                )
            # wire scales with who actually showed up this step
            intra_bytes = jnp.sum(wire_g * present_g) / S
            intra_dense = (dense_bytes(learners) / G) * jnp.sum(present_g) / S

        avg_g = tree_cast(avg_g, cfg.meta_dtype)
        inner_disp = tree_norm(tree_sub(avg_g, gparams))
        gparams_upd, gmom_upd = block_momentum_update(
            gparams, gmom, avg_g, mu=self.mu_in, eta=cfg.meta_lr,
            nesterov=cfg.nesterov, use_pallas=cfg.use_pallas,
        )
        if self.elastic is not None:
            # a group with zero present members takes no inner update
            gmask = (present_g > 0).astype(jnp.float32)
            gparams = tree_where_mask(gmask, gparams_upd, gparams)
            gmom = tree_where_mask(gmask, gmom_upd, gmom)
        else:
            gparams, gmom = gparams_upd, gmom_upd

        # ---- outer level: cross-group average + block momentum (every H) —
        # under lax.cond so the quantize/top-k/momentum work runs only on
        # the 1-in-H steps where it fires, not computed-and-discarded
        do_outer = ((step + 1) % self.H) == 0
        gparams_inner = gparams

        def _outer_fire(_):
            A, ores, om = self.outer_reducer.reduce(
                gparams_inner, gp, topo["outer_residual"], step=step
            )
            A = tree_cast(A, cfg.meta_dtype)
            if is_packed_plane(gp):
                # packed meta plane: the outer momentum update emits the
                # (G, rows, 128) group-reset broadcast in the same pass
                # (the groups are the outer level's "learners"; the group
                # plane stays in the meta dtype)
                gp_out, v_out, gpar = fused_momentum_broadcast_update(
                    gp, v, A, mu=self.mu_out, eta=1.0, num_learners=G,
                    ldtype=jnp.dtype(cfg.meta_dtype), nesterov=False,
                    use_pallas=cfg.use_pallas,
                )
            else:
                gp_out, v_out = block_momentum_update(
                    gp, v, A, mu=self.mu_out, eta=1.0, nesterov=False,
                    use_pallas=cfg.use_pallas,
                )
                gpar = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (G,) + x.shape),
                    gp_out,
                )
            # bytes are static python floats inside the trace; lift them so
            # both branches return the same pytree. The dense yardstick is
            # gated on do_outer exactly like the wire bytes: on hold steps
            # the dense scheme wouldn't cross the inter-node links either,
            # so charging it every step inflated compression ratios.
            return (gp_out, v_out, gpar, ores,
                    jnp.float32(om["comm_bytes"]),
                    jnp.float32(dense_bytes(gparams_inner)))

        def _outer_hold(_):
            return (gp, v, gparams_inner, topo["outer_residual"],
                    jnp.float32(0), jnp.float32(0))

        gp_new, v_new, gparams, outer_res_new, outer_bytes, outer_dense = (
            lax.cond(do_outer, _outer_fire, _outer_hold, None)
        )

        # ---- reset learners to their group's params ---------------------
        learners = jax.tree.map(
            lambda g: jnp.broadcast_to(
                g[:, None], (G, S) + g.shape[1:]
            ).reshape((G * S,) + g.shape[1:]).astype(ldt),
            gparams,
        )

        membership = topo.get("membership")
        # the clip ring (advanced by clip_anchored above) must survive the
        # rebuild or the jit carry structure breaks
        carried = {
            k: topo[k] for k in ("robust_ring", "robust_count") if k in topo
        }
        topo = {
            "group_params": gparams,
            "group_momentum": gmom,
            "inner_residual": inner_res,
            "outer_residual": outer_res_new,
            **carried,
        }
        if membership is not None:
            topo["membership"] = membership  # the schedule rides unchanged
        total_bytes = intra_bytes + outer_bytes
        total_dense = intra_dense + outer_dense
        metrics = {
            "v_norm": tree_norm(v_new),
            "group_v_norm": tree_norm(gmom),
            "displacement_norm": inner_disp,
            # cross-group consensus: how far the per-group meta params
            # have drifted from their mean between outer averages — the
            # signal a per-group K_g autotuner reads (telemetry, §11)
            "consensus_dist": tree_norm(
                jax.tree.map(
                    lambda g: g - jnp.mean(g, axis=0, keepdims=True), gparams
                )
            ),
            "outer_fired": do_outer.astype(jnp.float32),
            # per-edge-class modeled wire traffic (intra every step,
            # inter only when the outer level fires)
            "comm_bytes_intra": intra_bytes,
            "comm_bytes_inter": outer_bytes,
            "comm_bytes": total_bytes,
            "comm_bytes_dense": total_dense,
            # effective per-step compression ratio over both edge classes
            "comm_compression": jnp.where(
                total_bytes > 0, total_dense / jnp.maximum(total_bytes, 1.0),
                jnp.float32(1.0),
            ),
        }
        metrics.update(rmetrics)
        if self.elastic is not None:
            metrics["present_count"] = jnp.sum(present_g)
        return gp_new, v_new, learners, comm_residual, topo, metrics
