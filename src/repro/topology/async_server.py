"""The async bounded-staleness meta server (DESIGN.md §12).

Every other topology in this package barriers all live learners every K
local steps. This one retires the barrier: each learner pushes its packed
displacement plane when *it* finishes a K-step block and pulls the
current w~ without waiting for anyone. True asynchrony is unexpressible
under SPMD — every program step is collective — so, exactly like elastic
membership (§8) and the retired downpour queue (§4), *when* each learner
reaches its K becomes a deterministic, checkpointable schedule:

  * ``AsyncConfig.step_time[j]`` is learner j's simulated wall-clock cost
    of one K-step block, in meta ticks. One meta tick = one dispatch of
    the jitted step = the fastest learner's block time.
  * A per-learner logical clock rides in ``MetaState.topo["clock"]``;
    learner j fires (pushes + pulls) on the ticks where its clock fills,
    and runs its K local steps only on those ticks (the same trailing-
    step masking the elastic schedules use — the SPMD program never
    changes shape). Clocks start at ``-(j mod step_time[j])`` so pushes
    de-phase instead of coinciding; a learner leaving its start lag
    pulls the current center at block start (it has computed nothing
    yet), so the first block obeys the same staleness bound as every
    later one.
  * Staleness tau_j = center updates between learner j's last pull and
    this push — tracked exactly with an update counter
    (``topo["updates"]``) and per-learner pull stamps
    (``topo["pull_update"]``). The step-time profile bounds it by
    construction: tau_j <= step_time[j] - 1 <= AsyncConfig.staleness
    (validated at config time).
  * Applied displacements are weighted by the staleness decay
    ``decay**tau`` (default: the block momentum mu — the mu^tau rule the
    momentum/staleness analyses of Yu et al. revolve around), under one
    of two update rules: ``mavg`` (staleness-decayed block momentum on
    the mean of the ready displacements) or ``elastic`` (Zhang's EASGD
    elastic force toward the *current* center; firing learners relax
    instead of hard-resetting).

The legacy ``eamsgd`` and ``downpour`` algorithms are aliases onto this
server (``resolve_async_config``): eamsgd is the elastic update with a
uniform profile, downpour is the mavg update with decay 1.0 and a
uniform ``staleness+1``-tick profile whose de-phased clocks reproduce
the old warmup (no center motion for the first tau ticks) and per-push
staleness tau. core/meta.py keeps no per-algorithm branches.

A uniform all-ones profile with the mavg update is the synchronous
degenerate case: every learner fires every tick with staleness 0, and
``mix`` delegates to ``FlatAllReduce`` — bitwise-identical, pinned in
tests/test_async.py. Elastic membership composes: an absent learner
cannot fire, so its clock keeps filling and it pushes at its next
present tick — drop vs. lag is one axis (an absent learner is just one
with unbounded step time).

All server state is packed: clocks/stamps are (L,) int32 and the anchor
(pending-displacement base) plane is an (L, rows, 128) stacked buffer,
so the zero-copy donation path applies unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import AsyncConfig, MAvgConfig
from repro.comm.reducer import dense_bytes
from repro.topology.base import (
    FlatAllReduce,
    Topology,
    effective_momentum,
    learner_dtype,
)
from repro.topology.elastic import (
    membership_at,
    membership_schedule,
    tree_where_mask,
)
from repro.utils import tree_broadcast_learners, tree_cast, tree_norm


def resolve_async_config(cfg: MAvgConfig) -> AsyncConfig:
    """The AsyncConfig an MAvgConfig means, including the legacy aliases.

    eamsgd  -> elastic update, uniform profile, tau=0 (synchronous EASGD)
    downpour-> mavg update, decay 1.0 (the legacy queue applied stale
               displacements at full weight), uniform staleness+1-tick
               profile: de-phased clocks give every push staleness
               ~min(L-1, tau) and reproduce the legacy warmup (the
               center holds for the first tau ticks)
    """
    explicit = cfg.topology.server
    if cfg.algorithm == "eamsgd":
        base = explicit if explicit is not None else AsyncConfig()
        return dataclasses.replace(
            base, update="elastic",
            elastic_alpha=(base.elastic_alpha if base.elastic_alpha
                           is not None else cfg.elastic_alpha),
        )
    if cfg.algorithm == "downpour":
        if explicit is not None:
            return explicit
        return AsyncConfig(
            staleness=cfg.staleness,
            step_time=(cfg.staleness + 1,) * cfg.num_learners,
            update="mavg", decay=1.0,
        )
    return explicit if explicit is not None else AsyncConfig()


def step_time_profile(L: int, acfg: AsyncConfig) -> np.ndarray:
    """(L,) int32 ticks-per-K-block profile, deterministic in the config.

    An explicit ``step_time`` wins; otherwise ``skew`` spreads {1..skew}
    evenly over the learners and a seeded permutation assigns slots (so
    which learner is the straggler is seed-, not index-, determined).
    """
    if acfg.step_time:
        assert len(acfg.step_time) == L, (acfg.step_time, L)
        return np.asarray(acfg.step_time, np.int32)
    if acfg.skew <= 1:
        return np.ones((L,), np.int32)
    prof = np.rint(np.linspace(1.0, float(acfg.skew), L)).astype(np.int32)
    rng = np.random.RandomState(acfg.seed)
    return prof[rng.permutation(L)]


class AsyncServer(Topology):
    """Push-when-ready / pull-without-waiting with bounded staleness."""

    name = "async"

    def __init__(self, cfg: MAvgConfig, reducer=None):
        from repro.comm import make_reducer
        from repro.robust import make_robust

        self.cfg = cfg
        self.acfg = resolve_async_config(cfg)
        self.mu = effective_momentum(cfg)
        self.decay = self.acfg.decay if self.acfg.decay is not None else self.mu
        self.alpha = (self.acfg.elastic_alpha
                      if self.acfg.elastic_alpha is not None
                      else cfg.elastic_alpha)
        # async robust semantics: the clip + anomaly scores bound each
        # learner's anchor displacement every tick; the trimmed/median
        # estimator applies only in the synchronous degenerate case (the
        # FlatAllReduce delegate below), where an L-way mean exists
        self.robust = make_robust(cfg)
        agg = (
            self.robust.aggregate
            if self.robust is not None and self.robust.aggregates else None
        )
        self.reducer = (make_reducer(cfg, aggregate=agg)
                        if reducer is None else reducer)
        self.profile = step_time_profile(cfg.num_learners, self.acfg)
        # de-phased start clocks: learner j first fires at tick
        # profile[j]-1 + (j mod profile[j]) — no center motion before the
        # slowest warmup a synchronous run would also pay, pushes spread
        # over the window after it
        self.start_clock = -(np.arange(cfg.num_learners) % self.profile)
        self.start_clock = self.start_clock.astype(np.int32)
        elastic = cfg.topology.elastic
        self.membership = (
            membership_schedule(cfg.num_learners, elastic)
            if elastic is not None else None
        )
        # the synchronous degenerate case: everyone fires every tick with
        # staleness 0 — delegate the arithmetic to FlatAllReduce so tau=0
        # is bitwise-identical to the flat topology (tests/test_async.py)
        self.degenerate = (
            self.acfg.update == "mavg"
            and bool((self.profile == 1).all())
            and self.membership is None
        )
        self._flat = FlatAllReduce(cfg, self.reducer)
        # host-side fire simulation cache for work_completed()
        self._sim_clock = self.start_clock.copy()
        self._sim_t = 0
        self._sim_cum: list[int] = []

    # -- buffers -----------------------------------------------------------

    def init_buffers(self, gp, cfg: MAvgConfig):
        L = cfg.num_learners
        topo = {
            "clock": jnp.asarray(self.start_clock),
            "pull_update": jnp.zeros((L,), jnp.int32),
            "updates": jnp.zeros((), jnp.int32),
            # the center copy each learner last pulled (meta dtype): the
            # base its pending displacement is measured against
            "anchor": tree_broadcast_learners(gp, L),
        }
        if self.membership is not None:
            topo["membership"] = jnp.asarray(self.membership)
        return self.reducer.init_residual(gp, L), topo

    # -- clock hooks -------------------------------------------------------

    def fire_mask(self, topo, step):
        """(L,) bool: which learners complete a K-step block this tick."""
        m = jnp.asarray(self.profile)
        fire = (topo["clock"] + 1) >= m
        if "membership" in topo:
            fire = fire & (membership_at(topo["membership"], step) > 0)
        return fire

    def local_steps(self, topo, step):
        if self.degenerate:
            return None
        k = jnp.int32(self.cfg.k_steps)
        return jnp.where(self.fire_mask(topo, step), k, 0)

    def work_completed(self, step) -> int:
        """Cumulative K-step blocks completed through meta step ``step``
        (host-side replay of the deterministic clock recurrence)."""
        n = int(step) + 1
        while self._sim_t < n:
            fire = (self._sim_clock + 1) >= self.profile
            if self.membership is not None:
                t = self._sim_t % self.membership.shape[0]
                fire = fire & (self.membership[t] > 0)
            prev = self._sim_cum[-1] if self._sim_cum else 0
            self._sim_cum.append(prev + int(fire.sum()))
            self._sim_clock = np.where(fire, 0, self._sim_clock + 1)
            self._sim_t += 1
        return self._sim_cum[n - 1] if n >= 1 else 0

    # -- the meta phase ----------------------------------------------------

    def mix(self, learners, gp, v, comm_residual, topo, *, step):
        cfg = self.cfg
        L = cfg.num_learners
        if self.degenerate:
            # the async topo dict rides through the flat delegate so its
            # robust clip ring (when on) advances and survives the rebuild
            gp2, v2, learners2, comm_residual, topo2, metrics = self._flat.mix(
                learners, gp, v, comm_residual, topo, step=step
            )
            u = topo["updates"] + 1
            topo = dict(
                topo2,
                clock=jnp.zeros((L,), jnp.int32),
                pull_update=jnp.zeros((L,), jnp.int32) + u,
                updates=u,
                anchor=tree_broadcast_learners(gp2, L),
            )
            metrics.update({
                "stale_norm": metrics["displacement_norm"],
                "staleness_mean": jnp.float32(0.0),
                "staleness_max": jnp.float32(0.0),
                "staleness_p99": jnp.float32(0.0),
                "fired_count": jnp.float32(L),
            })
            return gp2, v2, learners2, comm_residual, topo, metrics

        fire = self.fire_mask(topo, step)
        ff = fire.astype(jnp.float32)
        n_fired = ff.sum()
        anyf = n_fired > 0
        gate = anyf.astype(jnp.float32)
        u0 = topo["updates"]
        tau = jnp.maximum(u0 - topo["pull_update"], 0).astype(jnp.float32)
        wgt = ff * jnp.power(jnp.float32(self.decay), tau)
        expand = lambda a, x: a.reshape((L,) + (1,) * (x.ndim - 1))
        ldt = learner_dtype(learners)

        # pre-update consensus: how far the stack has drifted from the
        # center (same telemetry role as FlatAllReduce's, but measured
        # against w~ — there is no common average to measure against)
        consensus = tree_norm(jax.tree.map(
            lambda w, g: w.astype(jnp.float32) - g.astype(jnp.float32)[None],
            learners, gp,
        ))

        rmetrics = {}
        if self.acfg.update == "mavg":
            # staleness-decayed block momentum on the mean of the ready
            # displacements (each measured against the center its learner
            # pulled): v <- mu v + eta * mean_ready(decay^tau (w_j - a_j))
            delta = jax.tree.map(
                lambda w, a: w.astype(jnp.float32) - a.astype(jnp.float32),
                learners, topo["anchor"],
            )
            if self.robust is not None:
                # clip/score each learner's anchor displacement before the
                # staleness weighting (non-fired learners carry weight 0,
                # but their in-progress displacement still feeds the
                # scores and the trailing-median ring)
                delta, topo, rmetrics = self.robust.clip_stack(delta, topo)
            d = jax.tree.map(lambda di: di * expand(wgt, di), delta)
            applied = jax.tree.map(
                lambda di: di.sum(0) / jnp.maximum(n_fired, 1.0), d
            )
            v_new = jax.tree.map(
                lambda vi, di: self.mu * vi + cfg.meta_lr * di, v, applied
            )
            if cfg.nesterov:
                upd = jax.tree.map(
                    lambda vi, di: self.mu * vi + cfg.meta_lr * di,
                    v_new, applied,
                )
            else:
                upd = v_new
        else:
            # EASGD elastic force toward the CURRENT center, staleness-
            # decayed: v <- mu v + alpha * sum_ready(decay^tau (w_j - w~))
            force = jax.tree.map(
                lambda w, g: (w.astype(jnp.float32)
                              - g.astype(jnp.float32)[None]),
                learners, gp,
            )
            if self.robust is not None:
                force, topo, rmetrics = self.robust.clip_stack(force, topo)
            force = jax.tree.map(lambda fi: fi * expand(wgt, fi), force)
            applied = jax.tree.map(lambda fi: fi.sum(0), force)
            v_new = jax.tree.map(
                lambda vi, si: self.mu * vi + self.alpha * si, v, applied
            )
            upd = v_new

        # push-when-ready: the center only moves on ticks with pushes
        v = jax.tree.map(lambda nv, ov: jnp.where(anyf, nv, ov), v_new, v)
        gp_new = jax.tree.map(lambda g, ui: g + gate * ui, gp, upd)

        # pull-without-waiting: firing learners take the fresh center
        # (mavg: hard reset; elastic: relax toward it), re-anchor, and
        # restamp their pull; everyone else keeps computing. A learner
        # whose clock just crossed 0 is leaving its de-phased start lag —
        # it has run zero local steps, so it pulls the current center at
        # block start (hard, both update rules), keeping the first
        # block's staleness under the same step_time[j]-1 bound.
        clock_new = jnp.where(fire, 0, topo["clock"] + 1)
        refresh = (clock_new == 0) & ~fire
        if "membership" in topo:
            # an absent learner is frozen outright — it pulls nothing
            # (drop is unbounded lag; the tau bound applies to present
            # learners' step-time profile only)
            refresh = refresh & (membership_at(topo["membership"], step) > 0)
        rf = refresh.astype(jnp.float32)
        gp_b = tree_broadcast_learners(tree_cast(gp_new, ldt), L)
        if self.acfg.update == "mavg":
            pulled = gp_b
        else:
            pulled = jax.tree.map(
                lambda w, c: w - self.alpha * (w - c), learners, gp_b
            )
        learners = tree_where_mask(ff, pulled, learners)
        learners = tree_where_mask(rf, gp_b, learners)
        anchor = tree_where_mask(
            ff + rf, tree_broadcast_learners(gp_new, L), topo["anchor"]
        )
        u_new = u0 + anyf.astype(jnp.int32)
        topo = dict(
            topo,
            clock=clock_new,
            pull_update=jnp.where(fire | refresh, u_new,
                                  topo["pull_update"]),
            updates=u_new,
            anchor=anchor,
        )

        # wire model: only the ready learners ship their (dense)
        # displacement plane this tick — pushes no longer coincide
        per_learner = dense_bytes(learners) / L
        cb = per_learner * n_fired
        tau_fired = tau * ff
        metrics = {
            "v_norm": tree_norm(v),
            "displacement_norm": tree_norm(applied),
            "stale_norm": tree_norm(applied),
            "consensus_dist": consensus,
            "staleness_mean": tau_fired.sum() / jnp.maximum(n_fired, 1.0),
            "staleness_max": jnp.max(tau_fired),
            "staleness_p99": jnp.where(
                anyf,
                jnp.nanpercentile(jnp.where(fire, tau, jnp.nan), 99.0),
                0.0,
            ),
            "fired_count": n_fired,
            "comm_bytes": cb,
            "comm_bytes_dense": cb,
            "comm_compression": jnp.float32(1.0),
        }
        metrics.update(rmetrics)
        return gp_new, v, learners, comm_residual, topo, metrics
