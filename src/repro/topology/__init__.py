# Meta-level mixing topologies: who averages with whom, how often
# (DESIGN.md §7). The factory is keyed on MAvgConfig.topology and composes
# with repro.comm — each edge class carries its own Reducer.
from repro.topology.async_server import (
    AsyncServer,
    resolve_async_config,
    step_time_profile,
)
from repro.topology.base import (
    FlatAllReduce,
    Topology,
    block_momentum_update,
    effective_momentum,
)
from repro.topology.elastic import (
    mask_mixing_matrix,
    membership_at,
    membership_schedule,
    present_edge_count,
)
from repro.topology.gossip import (
    Gossip,
    avg_graph_degree,
    compress_stack,
    graph_degree,
    mixing_matrix,
    mixing_matrix_stack,
    mixing_period,
)
from repro.topology.hierarchical import Hierarchical


def make_topology(cfg, reducer=None) -> Topology:
    """Build the topology described by ``cfg.topology`` (an MAvgConfig).

    ``reducer`` overrides the primary reducer (flat: the all-reduce;
    hierarchical: intra-group; gossip: neighbor exchange) — the same
    injection point meta_step/make_meta_step always exposed.
    """
    kind = cfg.topology.kind
    # the legacy downpour/eamsgd algorithms are aliases onto the async
    # bounded-staleness server (resolve_async_config) — core/meta.py has
    # no per-algorithm meta-update branches
    if kind == "async" or cfg.algorithm in ("eamsgd", "downpour"):
        return AsyncServer(cfg, reducer)
    if kind == "flat":
        return FlatAllReduce(cfg, reducer)
    if kind == "hierarchical":
        return Hierarchical(cfg, reducer)
    if kind == "gossip":
        return Gossip(cfg, reducer)
    raise ValueError(f"unknown topology {kind!r}")


__all__ = [
    "AsyncServer",
    "FlatAllReduce",
    "Gossip",
    "Hierarchical",
    "Topology",
    "avg_graph_degree",
    "block_momentum_update",
    "compress_stack",
    "effective_momentum",
    "graph_degree",
    "make_topology",
    "mask_mixing_matrix",
    "membership_at",
    "membership_schedule",
    "mixing_matrix",
    "mixing_matrix_stack",
    "mixing_period",
    "present_edge_count",
    "resolve_async_config",
    "step_time_profile",
]
