# Meta-level mixing topologies: who averages with whom, how often
# (DESIGN.md §7). The factory is keyed on MAvgConfig.topology and composes
# with repro.comm — each edge class carries its own Reducer.
from repro.topology.base import (
    FlatAllReduce,
    Topology,
    block_momentum_update,
    effective_momentum,
)
from repro.topology.gossip import (
    Gossip,
    compress_stack,
    graph_degree,
    mixing_matrix,
)
from repro.topology.hierarchical import Hierarchical


def make_topology(cfg, reducer=None) -> Topology:
    """Build the topology described by ``cfg.topology`` (an MAvgConfig).

    ``reducer`` overrides the primary reducer (flat: the all-reduce;
    hierarchical: intra-group; gossip: neighbor exchange) — the same
    injection point meta_step/make_meta_step always exposed.
    """
    kind = cfg.topology.kind
    if kind == "flat":
        return FlatAllReduce(cfg, reducer)
    if kind == "hierarchical":
        return Hierarchical(cfg, reducer)
    if kind == "gossip":
        return Gossip(cfg, reducer)
    raise ValueError(f"unknown topology {kind!r}")


__all__ = [
    "FlatAllReduce",
    "Gossip",
    "Hierarchical",
    "Topology",
    "block_momentum_update",
    "compress_stack",
    "effective_momentum",
    "graph_degree",
    "make_topology",
    "mixing_matrix",
]
