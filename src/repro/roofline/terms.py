"""Three-term roofline model for TPU v5e (target hardware).

    compute    = HLO_FLOPs        / (chips x peak_FLOP/s)
    memory     = HLO_bytes        / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program
totals, per the SPMD single-program view); collective bytes come from the
HLO parser in hlo.py. MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) is
the useful-work yardstick: HLO/MODEL ratio exposes remat recompute and
redundancy.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

from repro.configs.base import InputShape, ModelConfig

# TPU v5e per-chip constants (from the spec)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_LINK_BW = 50e9  # B/s per link


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPs

    def to_dict(self):
        return asdict(self)


def model_flops(cfg: ModelConfig, shape: InputShape, k_steps: int = 1) -> float:
    """6 N D per processed token (training) or 2 N D (inference forward)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * k_steps
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def compute_terms(*, arch: str, shape: InputShape, mesh_name: str, chips: int,
                  hlo_flops: float, hlo_bytes: float, collective_bytes: float,
                  cfg: ModelConfig, k_steps: int = 1,
                  per_device: bool = True) -> RooflineTerms:
    """per_device=True: the HLO numbers come from the SPMD-partitioned
    module, i.e. they are already per-chip (this is what
    ``compiled.as_text()`` exposes). The spec formula X/(chips*rate) with
    whole-program X is identical to X_per_device/rate."""
    mf = model_flops(cfg, shape, k_steps)
    div = 1 if per_device else chips
    compute_s = hlo_flops / (div * PEAK_FLOPS_BF16)
    memory_s = hlo_bytes / (div * HBM_BW)
    collective_s = collective_bytes / (div * ICI_LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf_dev = mf / chips if per_device else mf
    return RooflineTerms(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        model_flops=mf,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        useful_ratio=mf_dev / hlo_flops if hlo_flops else 0.0,
    )
