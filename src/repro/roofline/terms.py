"""Three-term roofline model for TPU v5e (target hardware).

    compute    = HLO_FLOPs        / (chips x peak_FLOP/s)
    memory     = HLO_bytes        / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program
totals, per the SPMD single-program view); collective bytes come from the
HLO parser in hlo.py. MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) is
the useful-work yardstick: HLO/MODEL ratio exposes remat recompute and
redundancy.

Meta-communication adds a fourth, *modeled* term: ``wire_bytes`` is the
payload of the per-meta-step displacement all-reduce under the configured
``repro.comm`` scheme (meta_wire_bytes), and ``wire_s`` its link time —
so the roofline table shows the compression win next to the HLO-measured
collective term.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Optional

from repro.configs.base import CommConfig, InputShape, ModelConfig

# TPU v5e per-chip constants (from the spec)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_LINK_BW = 50e9  # B/s per link (fast intra-node edge class)
DCN_LINK_BW = 25e9  # B/s per host (slow inter-node edge class, ~200 Gb/s)


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPs
    # modeled meta-communication (repro.comm); 0 / "dense" when not computed
    wire_bytes: float = 0.0
    wire_s: float = 0.0
    comm_scheme: str = "dense"
    # per-edge-class split (repro.topology): intra-node (ICI) vs
    # inter-node (DCN) payload per meta step, amortized over outer_every
    wire_intra_bytes: float = 0.0
    wire_inter_bytes: float = 0.0
    topology: str = "flat"

    def to_dict(self):
        return asdict(self)


def participant_wire_bytes(n_params: int, comm: Optional[CommConfig], *,
                           learner_bytes: int = 4) -> float:
    """Payload ONE participant ships under ``comm`` (per meta round).

    Analytic model matching repro.comm's per-step accounting (the
    bytes-per-value/scale/index constants are imported from there so the
    two can't drift); scales are one f32 per chunk_rows x 128 values.
    """
    from repro.comm.quant import SCALE_BYTES, VALUE_BYTES
    from repro.comm.topk import INDEX_BYTES

    if comm is None or comm.scheme == "dense":
        return float(n_params * learner_bytes)
    n_chunks = max(1.0, n_params / (comm.chunk_rows * 128))
    if comm.scheme in VALUE_BYTES:
        per = n_params * VALUE_BYTES[comm.scheme] + n_chunks * SCALE_BYTES
    elif comm.scheme == "topk":
        per = comm.k_frac * n_params * (learner_bytes + INDEX_BYTES)
    elif comm.scheme == "int8_topk":
        per = (comm.k_frac * n_params * (VALUE_BYTES["int8"] + INDEX_BYTES)
               + n_chunks * SCALE_BYTES)
    else:
        raise ValueError(f"unknown comm scheme {comm.scheme!r}")
    return float(per)


def meta_wire_bytes(n_params: int, comm: Optional[CommConfig], *,
                    num_learners: int, learner_bytes: int = 4) -> tuple[float, float]:
    """(dense_bytes, wire_bytes) of one *flat* meta averaging round:
    every learner ships its (possibly compressed) displacement."""
    dense = float(num_learners * n_params * learner_bytes)
    wire = num_learners * participant_wire_bytes(
        n_params, comm, learner_bytes=learner_bytes
    )
    return dense, wire


def elastic_presence(topology, num_learners: int) -> tuple[float, float]:
    """(learner_frac, edge_frac) expected under the membership schedule.

    ``learner_frac`` is the mean fraction of learners present per meta
    step; ``edge_frac`` the mean fraction of *graph edges* with both
    endpoints present — for gossip the two differ (an edge dies when
    either endpoint is absent), and for time-varying graphs the live-edge
    count is averaged over the combined schedule x graph period. Both are
    1.0 when elasticity is off.
    """
    import math

    if topology is None or getattr(topology, "elastic", None) is None:
        return 1.0, 1.0
    from repro.topology import membership_schedule, mixing_matrix_stack

    import numpy as np

    groups = topology.groups if topology.kind == "hierarchical" else 1
    sched = membership_schedule(num_learners, topology.elastic, groups=groups)
    learner_frac = float(sched.mean())
    if topology.kind != "gossip":
        return learner_frac, learner_frac
    stack = mixing_matrix_stack(topology.graph, num_learners)
    T_g, T_s = stack.shape[0], sched.shape[0]
    eye = np.eye(num_learners, dtype=bool)
    tot = live = 0.0
    for t in range(math.lcm(T_g, T_s)):
        adj = (stack[t % T_g] > 0) & ~eye
        m = sched[t % T_s]
        tot += adj.sum()
        live += (adj & (m[:, None] > 0) & (m[None, :] > 0)).sum()
    return learner_frac, float(live / max(tot, 1.0))


def topology_wire_bytes(n_params: int, comm: Optional[CommConfig],
                        topology, *, num_learners: int,
                        learner_bytes: int = 4) -> dict:
    """Per-edge-class wire model of one meta iteration (amortized).

    Returns {"intra_bytes", "inter_bytes", "total_bytes"} plus the
    degree-over-time inputs ("avg_degree", "learner_presence",
    "edge_presence") — bytes crossing the fast intra-node links vs the
    slow inter-node links per meta step, under the given
    ``TopologyConfig`` (None -> flat):

    flat          every learner's displacement feeds a global all-reduce —
                  all of it is modeled as inter-node (the paper's worst
                  case, what K amortizes)
    hierarchical  L intra-group payloads (inner_comm) every step, scaled
                  by the membership presence fraction under elasticity; G
                  cross-group payloads (outer_comm) every outer_every
                  steps, amortized
    gossip        every learner ships to each of its live graph edges
                  every step — inter-node, no amortization; the degree is
                  averaged over the graph period (one-peer exponential)
                  and edges die when either endpoint is absent
    async         push-when-ready: learner j ships its (dense) plane once
                  per step_time[j]-tick block, so the per-tick inter
                  payload is sum_j per / m_j — the staleness profile
                  amortizes the wire exactly the way it skews the clocks
    """
    L = num_learners
    per = lambda c: participant_wire_bytes(n_params, c,
                                           learner_bytes=learner_bytes)
    avg_deg = 0.0
    learner_frac, edge_frac = elastic_presence(topology, L)
    if topology is None or topology.kind == "flat":
        inter = L * per(comm)
        intra = 0.0
    elif topology.kind == "hierarchical":
        intra = L * per(topology.inner_comm or comm) * learner_frac
        inter = (topology.groups * per(topology.outer_comm or comm)
                 / topology.outer_every)
    elif topology.kind == "gossip":
        from repro.topology import avg_graph_degree

        avg_deg = avg_graph_degree(topology.graph, L)
        intra = 0.0
        inter = L * avg_deg * per(topology.inner_comm or comm) * edge_frac
    elif topology.kind == "async":
        from repro.configs.base import AsyncConfig
        from repro.topology import step_time_profile

        acfg = topology.server if topology.server is not None else AsyncConfig()
        prof = step_time_profile(L, acfg)
        pushes_per_tick = float((1.0 / prof).sum())
        intra = 0.0
        # the async server ships dense displacement planes (enforced at
        # config time), one per firing learner per tick
        inter = per(comm) * pushes_per_tick * learner_frac
    else:
        raise ValueError(f"unknown topology {topology.kind!r}")
    return {"intra_bytes": float(intra), "inter_bytes": float(inter),
            "total_bytes": float(intra + inter),
            "avg_degree": float(avg_deg),
            "learner_presence": learner_frac, "edge_presence": edge_frac}


def model_flops(cfg: ModelConfig, shape: InputShape, k_steps: int = 1) -> float:
    """6 N D per processed token (training) or 2 N D (inference forward)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * k_steps
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def compute_terms(*, arch: str, shape: InputShape, mesh_name: str, chips: int,
                  hlo_flops: float, hlo_bytes: float, collective_bytes: float,
                  cfg: ModelConfig, k_steps: int = 1,
                  per_device: bool = True, comm: Optional[CommConfig] = None,
                  num_learners: int = 1, topology=None) -> RooflineTerms:
    """per_device=True: the HLO numbers come from the SPMD-partitioned
    module, i.e. they are already per-chip (this is what
    ``compiled.as_text()`` exposes). The spec formula X/(chips*rate) with
    whole-program X is identical to X_per_device/rate."""
    mf = model_flops(cfg, shape, k_steps)
    div = 1 if per_device else chips
    compute_s = hlo_flops / (div * PEAK_FLOPS_BF16)
    memory_s = hlo_bytes / (div * HBM_BW)
    collective_s = collective_bytes / (div * ICI_LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf_dev = mf / chips if per_device else mf
    wire_bytes = wire_s = intra_b = inter_b = 0.0
    if comm is not None or topology is not None:
        edge = topology_wire_bytes(
            cfg.param_count(), comm, topology, num_learners=num_learners
        )
        intra_b, inter_b = edge["intra_bytes"], edge["inter_bytes"]
        wire_bytes = edge["total_bytes"]
        # each edge class rides its own fabric
        wire_s = (intra_b / (chips * ICI_LINK_BW)
                  + inter_b / (chips * DCN_LINK_BW))
    return RooflineTerms(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        model_flops=mf,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        useful_ratio=mf_dev / hlo_flops if hlo_flops else 0.0,
        wire_bytes=wire_bytes,
        wire_s=wire_s,
        comm_scheme=comm.scheme if comm is not None else "dense",
        wire_intra_bytes=intra_b,
        wire_inter_bytes=inter_b,
        topology=topology.kind if topology is not None else "flat",
    )
