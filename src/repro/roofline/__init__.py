from repro.roofline.hlo import collective_bytes, split_computations
from repro.roofline.terms import (
    DCN_LINK_BW,
    HBM_BW,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
    RooflineTerms,
    compute_terms,
    elastic_presence,
    meta_wire_bytes,
    model_flops,
    participant_wire_bytes,
    topology_wire_bytes,
)
