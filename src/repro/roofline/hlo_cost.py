"""Trip-count-aware FLOP / HBM-byte accounting from optimized HLO.

``compiled.cost_analysis()`` (CPU backend) counts a while-loop body once,
which under-counts a 126-layer lax.scan by 126x. We walk the compiled,
SPMD-partitioned HLO module ourselves (so all numbers are PER DEVICE) and
weight by loop trip counts recovered from loop conditions:

* FLOPs: every ``dot`` contributes 2 * prod(result dims) * prod(lhs
  contracting dims); ``convolution`` contributes 2 * prod(result) *
  prod(kernel non-output dims). Dots inside fusion bodies count too.
* HBM bytes: sum of (result + operand) bytes of top-level instructions in
  non-fusion computations — the "perfect fusion" HBM-traffic model; fused
  internals never touch HBM. parameter/constant/gte/tuple/bitcast lines
  are skipped. Operand shapes come from a per-computation symbol table
  (params + instruction results).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.roofline.hlo import DTYPE_BYTES, _ARRAY_RE, _CONST_RE

_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-\.]*)\(")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_ATTR_COMP_RE = re.compile(r"(?:to_apply|calls|body|condition|branch_computations)=")

SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _arrays(text: str):
    out = []
    for dtype, dims in _ARRAY_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d]
        out.append((dtype, shape))
    return out


def _type_bytes(text: str) -> int:
    total = 0
    for dtype, shape in _arrays(text):
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: list
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symtab: dict = field(default_factory=dict)  # name -> result type str


def parse_module(hlo: str):
    """Returns (dict name -> Computation, entry name)."""
    comps: dict[str, Computation] = {}
    current = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        is_header = (
            not raw.startswith(" ") and line.endswith("{") and "->" in line
        )
        if is_header:
            head = line[5:].strip() if line.startswith("ENTRY") else line
            name = head.split("(")[0].strip().lstrip("%").strip()
            current = Computation(name)
            comps[name] = current
            if line.startswith("ENTRY"):
                entry = name
            # parameters: "(p0: f32[2,3], p1: (f32[2], s32[]))"
            if "(" in head:
                params_str = head[head.index("(") + 1 : head.rindex("->")]
                for m in re.finditer(r"([\w.\-]+)\s*:\s*", params_str):
                    pname = m.group(1)
                    rest = params_str[m.end() :]
                    nxt = re.search(r"[\w.\-]+\s*:", rest)
                    tstr = rest[: nxt.start()] if nxt else rest
                    current.symtab[pname] = tstr
            continue
        if line == "}" or current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        opcode = om.group(1)
        result_type = rhs[: om.start()]
        after = rhs[om.end() :]
        # operand region: up to the matching close paren (assume flat)
        close = after.find(")")
        operand_str = after[:close] if close >= 0 else after
        attrs = after[close + 1 :] if close >= 0 else ""
        operands = _OPERAND_RE.findall(operand_str)
        instr = Instr(name, opcode, result_type, operands, attrs, line)
        current.instrs.append(instr)
        current.symtab[name] = result_type
    return comps, entry


def _trip_count_of(comps, cond_name: str) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ins in comp.instrs:
        for c in _CONST_RE.findall(ins.line):
            best = max(best, int(c))
    return best


def _attr_comp(ins: Instr, key: str):
    m = re.search(key + r"=%?([\w.\-]+)", ins.line)
    return m.group(1) if m else None


def _dot_flops(ins: Instr, symtab) -> float:
    res = _arrays(ins.result_type)
    if not res:
        return 0.0
    n = 1
    for d in res[0][1]:
        n *= d
    lhs_t = symtab.get(ins.operands[0], "") if ins.operands else ""
    lhs = _arrays(lhs_t)
    if not lhs:
        return 0.0
    m = _LHS_CONTRACT_RE.search(ins.line)
    contract = [int(d) for d in m.group(1).split(",") if d] if m else []
    k = 1
    for d in contract:
        if d < len(lhs[0][1]):
            k *= lhs[0][1][d]
    return 2.0 * n * k


def _conv_flops(ins: Instr, symtab) -> float:
    res = _arrays(ins.result_type)
    if not res or len(ins.operands) < 2:
        return 0.0
    n = 1
    for d in res[0][1]:
        n *= d
    ker = _arrays(symtab.get(ins.operands[1], ""))
    if not ker:
        return 0.0
    k = 1
    for d in ker[0][1][:-1]:
        k *= d
    return 2.0 * n * k


def _instr_bytes(ins: Instr, symtab, comps=None) -> float:
    """HBM traffic of one instruction.

    Slicing ops only touch the sliced region, NOT the whole operand —
    charging a scan's dynamic-update-slice the full stacked output buffer
    every iteration would overcount a 4096-step scan by orders of
    magnitude (caught against the xLSTM scan; EXPERIMENTS.md §Perf).
    XLA wraps the dus in a kLoop fusion whose *result type* is the full
    aliased buffer, so fusions are resolved through their root.
    """
    res = _type_bytes(ins.result_type)
    if ins.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * res  # read slice region + write result
    if ins.opcode == "dynamic-update-slice":
        upd = symtab.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
        return 2.0 * _type_bytes(upd)
    if ins.opcode == "scatter":
        upd = symtab.get(ins.operands[-1], "") if ins.operands else ""
        return 3.0 * _type_bytes(upd)
    if ins.opcode == "fusion" and comps is not None:
        return _fusion_bytes(ins, symtab, comps)
    b = res
    for op in ins.operands:
        b += _type_bytes(symtab.get(op, ""))
    return b


def _fusion_bytes(ins: Instr, symtab, comps) -> float:
    """Fusion traffic: operands (excluding in-place-aliased full buffers)
    + outputs, where a dynamic-update-slice root writes only its update
    region."""
    tgt = _attr_comp(ins, "calls")
    comp = comps.get(tgt) if tgt else None

    def out_bytes_of(name, fcomp):
        node = next((i for i in fcomp.instrs if i.name == name), None)
        if node is None:
            return _type_bytes(fcomp.symtab.get(name, ""))
        if node.opcode == "dynamic-update-slice" and len(node.operands) > 1:
            return 2.0 * _type_bytes(fcomp.symtab.get(node.operands[1], ""))
        return _type_bytes(node.result_type)

    if comp is not None and comp.instrs:
        root = comp.instrs[-1]
        if root.opcode == "tuple":
            out = sum(out_bytes_of(op, comp) for op in root.operands)
        else:
            out = out_bytes_of(root.name, comp)
        aliased = root.opcode == "dynamic-update-slice" or (
            root.opcode == "tuple"
            and any(
                (n := next((i for i in comp.instrs if i.name == op), None))
                and n.opcode == "dynamic-update-slice"
                for op in root.operands
            )
        )
    else:
        out = _type_bytes(ins.result_type)
        aliased = "dynamic-update-slice" in ins.name
        if aliased:
            out = 0.0  # cannot resolve update size; be conservative
    inp = 0.0
    for op in ins.operands:
        t = symtab.get(op, "")
        # in-place dus fusions alias the big output buffer as an operand;
        # it is not read in full
        if aliased and t and t.strip() == ins.result_type.strip():
            continue
        inp += _type_bytes(t)
    return inp + out


@dataclass
class HloCost:
    flops: float
    bytes: float
    by_comp_flops: dict


def hlo_cost(hlo: str) -> HloCost:
    comps, entry = parse_module(hlo)
    if entry is None:
        entry = next(iter(comps)) if comps else None
    if entry is None:
        return HloCost(0.0, 0.0, {})

    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                tgt = _attr_comp(ins, "calls")
                if tgt:
                    fusion_bodies.add(tgt)

    flops_cache: dict[str, float] = {}

    def comp_flops(cname: str, seen=()) -> float:
        """Total FLOPs of one call of computation cname (nested weighted)."""
        if cname in flops_cache:
            return flops_cache[cname]
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return 0.0
        f = 0.0
        for ins in comp.instrs:
            if ins.opcode == "dot":
                f += _dot_flops(ins, comp.symtab)
            elif ins.opcode == "convolution":
                f += _conv_flops(ins, comp.symtab)
            elif ins.opcode == "while":
                body = _attr_comp(ins, "body")
                cond = _attr_comp(ins, "condition")
                trips = _trip_count_of(comps, cond) if cond else 1
                f += trips * comp_flops(body, seen + (cname,))
            elif ins.opcode in ("fusion", "call", "conditional", "custom-call"):
                for key in ("calls", "to_apply"):
                    tgt = _attr_comp(ins, key)
                    if tgt:
                        f += comp_flops(tgt, seen + (cname,))
                        break
        flops_cache[cname] = f
        return f

    total_bytes = [0.0]
    by_comp: dict[str, float] = {}

    def walk_bytes(cname: str, mult: float, seen=()):
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = _attr_comp(ins, "body")
                cond = _attr_comp(ins, "condition")
                trips = _trip_count_of(comps, cond) if cond else 1
                if body:
                    walk_bytes(body, mult * trips, seen + (cname,))
                continue
            if ins.opcode == "call":
                tgt = _attr_comp(ins, "to_apply")
                if tgt and tgt not in fusion_bodies:
                    walk_bytes(tgt, mult, seen + (cname,))
                    continue
            if ins.opcode in SKIP_BYTES:
                continue
            total_bytes[0] += mult * _instr_bytes(ins, comp.symtab, comps)

    def walk_flops(cname: str, mult: float, seen=()):
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = _attr_comp(ins, "body")
                cond = _attr_comp(ins, "condition")
                trips = _trip_count_of(comps, cond) if cond else 1
                if body:
                    walk_flops(body, mult * trips, seen + (cname,))
                continue
            f = 0.0
            if ins.opcode == "dot":
                f = _dot_flops(ins, comp.symtab)
            elif ins.opcode == "convolution":
                f = _conv_flops(ins, comp.symtab)
            elif ins.opcode in ("fusion", "call", "conditional"):
                for key in ("calls", "to_apply"):
                    tgt = _attr_comp(ins, key)
                    if tgt:
                        f = comp_flops(tgt, seen + (cname,))
                        break
            if f:
                by_comp[cname] = by_comp.get(cname, 0.0) + mult * f

    walk_flops(entry, 1.0)
    walk_bytes(entry, 1.0)
    total_flops = sum(by_comp.values())
    return HloCost(total_flops, total_bytes[0], by_comp)


# ---------------------------------------------------------------------------
# dry-run cost of a jitted function (AOT: no device allocation)
# ---------------------------------------------------------------------------


@dataclass
class JitCost:
    """Compiled-program cost of one jitted function.

    hbm_bytes        trip-count-weighted HBM traffic of the optimized HLO
                     (the hlo_cost model above)
    flops            same walk, dot/conv FLOPs
    arg_bytes        total input buffer bytes
    out_bytes        total output buffer bytes
    alias_bytes      bytes of inputs aliased onto outputs (buffer
                     donation — jax.jit(donate_argnums=...)); these
                     buffers are counted once, not twice
    temp_bytes       compiler temp allocation
    peak_state_bytes arg + out + temp - alias: the peak live footprint of
                     the program's own buffers, the number donation
                     halves for a state -> state step (DESIGN.md §10)
    """

    hbm_bytes: float
    flops: float
    arg_bytes: int
    out_bytes: int
    alias_bytes: int
    temp_bytes: int

    @property
    def peak_state_bytes(self) -> int:
        return (self.arg_bytes + self.out_bytes + self.temp_bytes
                - self.alias_bytes)


def jit_cost(fn, *abstract_args, **jit_kwargs) -> JitCost:
    """Lower + compile ``fn`` on abstract ShapeDtypeStruct args and read
    the costs off the compiled artifact — nothing is allocated or run, so
    this works at full 405B scale on the CPU container (the dry-run
    move). ``jit_kwargs`` pass to jax.jit; ``donate_argnums`` is how the
    donated-vs-functional peak-memory comparison is produced."""
    import jax

    compiled = jax.jit(fn, **jit_kwargs).lower(*abstract_args).compile()
    cost = hlo_cost(compiled.as_text())
    mem = compiled.memory_analysis()
    return JitCost(
        hbm_bytes=cost.bytes,
        flops=cost.flops,
        arg_bytes=int(mem.argument_size_in_bytes),
        out_bytes=int(mem.output_size_in_bytes),
        alias_bytes=int(mem.alias_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
    )
