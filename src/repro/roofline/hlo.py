"""Collective-byte accounting from optimized HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not
collective traffic, so we parse ``compiled.as_text()``: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction contributes its result bytes, and instructions living inside
``while`` bodies (scan over layers, scan over K local steps) are
multiplied by the loop trip count. Trip counts are recovered from the
loop-condition computation (the comparison constant) — the standard
lax.scan lowering — with a fallback of 1.
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)"
)
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO result type (handles tuples)."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def split_computations(hlo: str):
    """Returns (computation name -> instruction lines, entry name)."""
    comps: dict[str, list[str]] = {}
    current = None
    entry = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        is_header = (
            not line.startswith(" ")
            and stripped.endswith("{")
            and "->" in stripped
        )
        if is_header:
            head = stripped
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.split("(")[0].strip().lstrip("%").strip()
            current = name
            comps.setdefault(current, [])
            if stripped.startswith("ENTRY"):
                entry = name
            continue
        if stripped == "}":
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    """Largest integer constant in the loop condition (scan bound)."""
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def collective_bytes(hlo: str) -> dict:
    """Returns {'total': bytes, 'by_type': {...}, 'by_site': [...]}.

    Bytes are the *result* sizes of collective ops, trip-count weighted.
    """
    comps, entry = split_computations(hlo)
    if entry is None:  # single-computation module
        entry = next(iter(comps)) if comps else None
    if entry is None:
        return {"total": 0, "by_type": {}, "sites": []}

    by_type: dict[str, int] = defaultdict(int)
    sites = []

    def walk(comp: str, multiplier: int, seen: tuple):
        if comp not in comps or comp in seen:
            return
        for line in comps[comp]:
            # collectives: "%name = TYPE op-name(...)"
            for cname in COLLECTIVES:
                token = f" {cname}("
                alt = f" {cname}-start("
                if token in line or alt in line:
                    lhs = line.split("=", 1)
                    type_str = lhs[1] if len(lhs) > 1 else line
                    type_str = type_str.split(cname)[0]
                    b = _shape_bytes(type_str) * multiplier
                    by_type[cname] += b
                    sites.append({"op": cname, "bytes": b, "comp": comp})
                    break
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                walk(body, multiplier * trips, seen + (comp,))
                continue
            m = _CALL_RE.search(line)
            if m and not line.lstrip().startswith("ROOT fusion"):
                walk(m.group(1), multiplier, seen + (comp,))

    walk(entry, 1, ())
    return {"total": sum(by_type.values()), "by_type": dict(by_type), "sites": sites}
