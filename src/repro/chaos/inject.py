"""Fault injectors: one per layer, each a no-op that is *bitwise*
identical to today when its schedule is quiet (pinned in
tests/test_chaos.py).

  wrap_batch_fn      data layer — poisons the target learner's float
                     batch leaves with NaN/Inf, host-side, before the
                     jitted step ever sees them.
  PayloadCorruptor   comm layer — in-jit corruption of the post-local-
                     phase learner planes (the displacement payload the
                     reducer is about to ship): whole-plane scale and a
                     single real bit-flip via bitcast XOR. Quiet steps
                     select the untouched input through ``jnp.where`` on
                     an all-false mask, so the installed-but-idle
                     corruptor is value-identical to no corruptor.
  apply_chaos        topology layer — config transform: crash windows
                     become rows of an *explicit* elastic membership
                     schedule (masked through the stochastic-complement
                     rewiring like any other absence, DESIGN.md §8), and
                     straggle spikes land on the async server's step-time
                     profile (with the staleness bound raised to keep the
                     config valid).

Checkpoint faults don't live here: ``FaultSchedule.save_fault`` feeds
``checkpoint.save_state(fault=...)`` directly (the Trainer threads it).
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

import jax
import jax.numpy as jnp

from repro.chaos.config import ChaosConfig
from repro.chaos.schedule import FaultSchedule
from repro.configs.base import AsyncConfig, ElasticConfig, MAvgConfig


def wrap_batch_fn(batch_fn, schedule: FaultSchedule):
    """``batch_fn`` with the schedule's NaN/Inf batch faults applied to
    the target learner's float leaves (leading axis L). Int-token LM
    batches carry no float leaves and pass through untouched — NaN data
    is a float-pipeline fault (document on the CLI). Returns ``batch_fn``
    itself when the schedule has no batch faults."""
    if not schedule.any_batch_faults:
        return batch_fn

    def wrapped(rng, step):
        b = batch_fn(rng, step)
        nan, inf = schedule.batch_fault_at(int(step))
        if not (nan.any() or inf.any()):
            return b

        def poison(x):
            x = np.asarray(x)
            if not np.issubdtype(x.dtype, np.floating):
                return x
            x = np.array(x)
            x[nan.astype(bool)] = np.nan
            x[inf.astype(bool)] = np.inf
            return x

        return jax.tree.map(poison, b)

    return wrapped


def _broadcast(m, x):
    return m.reshape((m.shape[0],) + (1,) * (x.ndim - 1))


def _flip_one_element(x, xorm, pos):
    """XOR the schedule's bit into ONE seeded element per learner of the
    (L, ...) float plane ``x`` (a real bit-level flip through
    ``lax.bitcast_convert_type``). ``xorm`` rows of 0 leave every word
    untouched (x ^ 0 == x). bf16 planes flip ``bit - 16`` (the bf16 word
    is the top half of the f32 word); f32-bits below 16 then flip
    nothing."""
    if x.dtype == jnp.float32:
        itype, mask = jnp.int32, xorm
    elif x.dtype == jnp.bfloat16:
        itype = jnp.int16
        mask = jax.lax.shift_right_logical(
            xorm, jnp.int32(16)
        ).astype(jnp.int16)
    else:
        return x
    L = x.shape[0]
    flat = x.reshape(L, -1)
    n = flat.shape[1]
    idx = pos % n
    onehot = jnp.arange(n)[None, :] == idx[:, None]
    words = jax.lax.bitcast_convert_type(flat, itype)
    words = words ^ jnp.where(onehot, mask[:, None],
                              jnp.zeros((), itype))
    return jax.lax.bitcast_convert_type(words, x.dtype).reshape(x.shape)


class PayloadCorruptor:
    """In-jit payload corruption, gated on the compiled schedule arrays
    (jit constants — the step stays a pure function of (state, batches)).

    ``__call__(learners, step)`` scales every float leaf of the dirty
    learners and bit-flips one seeded element of the first float leaf
    (under packing that leaf IS the whole-model plane). Clean learners
    and quiet steps take the untouched input through ``where`` on an
    all-false mask — bitwise identity, not just numerical closeness.
    """

    def __init__(self, schedule: FaultSchedule):
        T, L = schedule.cfg.horizon, schedule.num_learners

        def pad(a, fill, dt):
            return jnp.asarray(
                np.concatenate([a, np.full((1, L), fill, a.dtype)], 0)
            ).astype(dt)

        # trailing all-clear row: steps beyond the horizon index it
        self._scale = pad(schedule.scale, 1.0, jnp.float32)
        self._xor = pad(schedule.xor, 0, jnp.int32)
        self._pos = pad(schedule.pos, 0, jnp.int32)
        self._T = T
        self.active = schedule.any_payload_faults

    def __call__(self, learners, step):
        idx = jnp.minimum(step, self._T)
        scale = jnp.take(self._scale, idx, axis=0)  # (L,)
        xorm = jnp.take(self._xor, idx, axis=0)
        pos = jnp.take(self._pos, idx, axis=0)
        dirty = (scale != 1.0) | (xorm != 0)

        leaves, treedef = jax.tree_util.tree_flatten(learners)
        out, flipped = [], False
        for x in leaves:
            if not jnp.issubdtype(x.dtype, jnp.floating):
                out.append(x)
                continue
            cor = (
                x.astype(jnp.float32) * _broadcast(scale, x)
            ).astype(x.dtype)
            if not flipped:
                cor = _flip_one_element(cor, xorm, pos)
                flipped = True
            out.append(jnp.where(_broadcast(dirty, x), cor, x))
        return jax.tree_util.tree_unflatten(treedef, out)


def _crash_membership(schedule: FaultSchedule, topo_cfg) -> np.ndarray:
    """(horizon, L) membership rows: the configured elastic schedule (if
    any) ANDed with the crash windows."""
    crash = schedule.crash_schedule()
    T, L = crash.shape
    if topo_cfg.elastic is not None:
        from repro.topology.elastic import membership_schedule

        groups = topo_cfg.groups if topo_cfg.kind == "hierarchical" else 1
        base = membership_schedule(L, topo_cfg.elastic, groups=groups)
        P = base.shape[0]
        rows = np.stack([base[s % P] for s in range(T)]) * crash
    else:
        rows = crash
    if (rows.sum(axis=1) < 1.0).any():
        bad = int(np.argmin(rows.sum(axis=1)))
        raise ValueError(
            f"chaos crash schedule leaves NO learner present at step "
            f"{bad} (crash windows composed with the elastic schedule) — "
            f"shrink the crash duration or the elastic drop_frac"
        )
    return rows


def apply_chaos(mcfg: MAvgConfig, chaos: ChaosConfig, *,
                salt: int = 0) -> MAvgConfig:
    """The config-level injections: crash faults -> an explicit elastic
    membership schedule, straggle faults -> the async step-time profile.
    With neither fault kind present the config is returned UNCHANGED
    (identical object — the off==bitwise pin needs no trust in config
    plumbing)."""
    # STRUCTURE is decided at salt 0, CONTENT at the caller's salt: a
    # retry that drops a transient crash must still carry the membership
    # schedule (now all-present rows) — the checkpointed topo buffers and
    # the supervisor's quarantine lever both need the structure to
    # persist across attempts, only the injected absences go away.
    schedule0 = FaultSchedule(chaos, mcfg.num_learners, salt=0)
    schedule = (
        schedule0 if salt == 0
        else FaultSchedule(chaos, mcfg.num_learners, salt=salt)
    )
    t = mcfg.topology
    if not (schedule0.any_crash_faults or schedule0.straggle_extra.any()):
        return mcfg
    if schedule0.any_crash_faults:
        if t.kind == "flat":
            raise ValueError(
                "chaos crash faults map onto the elastic membership mask, "
                "which the flat topology has no mixing rows for — use "
                "hierarchical / gossip / async (TopologyConfig.kind)"
            )
        rows = _crash_membership(schedule, t)
        elastic = t.elastic if t.elastic is not None else ElasticConfig(
            drop_frac=0.0
        )
        elastic = replace(
            elastic, period=rows.shape[0],
            schedule=tuple(tuple(float(v) for v in r) for r in rows),
        )
        t = replace(t, elastic=elastic)
    if schedule0.straggle_extra.any():
        if t.kind != "async":
            raise ValueError(
                "chaos straggle faults perturb the async server's "
                "step-time profile — use TopologyConfig(kind='async')"
            )
        from repro.topology.async_server import step_time_profile

        server = t.server if t.server is not None else AsyncConfig()
        prof = schedule.straggled_profile(
            step_time_profile(mcfg.num_learners, server)
        )
        server = replace(
            server, step_time=prof,
            staleness=max(server.staleness, max(prof) - 1),
        )
        t = replace(t, server=server)
    return replace(mcfg, topology=t)
