"""``FaultSchedule``: the compiled, replayable form of a ``ChaosConfig``.

The same move the elastic membership schedule and the async server's
step-time profiles made (DESIGN.md §8/§12): real faults race wall clocks,
but under SPMD the *schedule of faults* is a deterministic function of
the config, compiled here into per-step numpy mask arrays indexed by the
absolute meta step. Because ``MetaState.step`` is checkpointed, a resumed
or rolled-back run replays the exact same faults — which is what makes
supervised recovery testable at all.

Retry semantics ride on ``salt`` (the supervisor's attempt counter):
non-sticky faults exist only at salt 0 — a rollback replays them *clean*
(transient faults don't recur on retry) — while sticky faults survive
every salt (a genuinely broken component), driving the
``recovery_exhausted`` path.

Steps at or beyond the horizon are fault-free by construction: every
in-jit lookup array carries a trailing all-clear row and clamps its
index, every host-side lookup bounds-checks.
"""
from __future__ import annotations

import numpy as np

from repro.chaos.config import ChaosConfig, FaultSpec


class FaultSchedule:
    """Per-kind mask arrays over ``(horizon, num_learners)``.

    nan / inf        (T, L) f32 0/1 — poison the learner's batch floats
    scale            (T, L) f32 — payload multiplier (1.0 = clean)
    xor              (T, L) int32 — payload bit-flip word (0 = clean)
    pos              (T, L) int32 — seeded raw index of the flipped
                     element (the corruptor mods it by the plane size)
    crash            (T, L) f32 0/1 — 0 while the learner is crashed
    straggle_extra   (L,) int — extra step-time ticks per learner
    save faults      {step: "torn" | "corrupt"}
    """

    def __init__(self, cfg: ChaosConfig, num_learners: int, *,
                 salt: int = 0):
        self.cfg = cfg
        self.num_learners = int(num_learners)
        self.salt = int(salt)
        T, L = cfg.horizon, self.num_learners
        self.nan = np.zeros((T, L), np.float32)
        self.inf = np.zeros((T, L), np.float32)
        self.scale = np.ones((T, L), np.float32)
        self.xor = np.zeros((T, L), np.int32)
        self.pos = np.zeros((T, L), np.int32)
        self.crash = np.ones((T, L), np.float32)
        self.straggle_extra = np.zeros((L,), np.int64)
        self.save_faults: dict[int, str] = {}
        for f in cfg.faults:
            if not (f.sticky or salt == 0):
                continue  # transient fault: the retry replays clean
            self._compile(f)

    # ------------------------------------------------------------------
    def _learner(self, f: FaultSpec) -> int:
        if f.learner >= 0:
            assert f.learner < self.num_learners, (f, self.num_learners)
            return f.learner
        # seeded draw, deterministic per (config seed, fault step/kind)
        rng = np.random.RandomState(
            (self.cfg.seed * 1000003 + f.step * 101
             + hash(f.kind) % 9973) % (2**31)
        )
        return int(rng.randint(0, self.num_learners))

    def _compile(self, f: FaultSpec) -> None:
        steps = range(f.step, f.step + f.duration)
        if f.kind in ("torn_save", "corrupt_save"):
            tag = "torn" if f.kind == "torn_save" else "corrupt"
            for s in steps:
                self.save_faults[s] = tag
            return
        j = self._learner(f)
        if f.kind == "nan_batch":
            self.nan[f.step: f.step + f.duration, j] = 1.0
        elif f.kind == "inf_batch":
            self.inf[f.step: f.step + f.duration, j] = 1.0
        elif f.kind in ("payload_scale", "finite_scale"):
            # finite_scale rides the same compiled array: the finiteness
            # guarantee lives in FaultSpec validation (bounded finite
            # magnitude), not in a separate injection path
            self.scale[f.step: f.step + f.duration, j] = f.magnitude
        elif f.kind in ("payload_bitflip", "finite_bitflip"):
            word = np.int32(np.uint32(1 << f.bit).view(np.int32))
            self.xor[f.step: f.step + f.duration, j] = word
            rng = np.random.RandomState(
                (self.cfg.seed * 7919 + f.step * 31 + j) % (2**31)
            )
            self.pos[f.step: f.step + f.duration, j] = rng.randint(
                0, 2**31 - 1
            )
        elif f.kind == "crash":
            self.crash[f.step: f.step + f.duration, j] = 0.0
        elif f.kind == "straggle":
            self.straggle_extra[j] += int(f.magnitude)

    # ------------------------------------------------------------------
    # host-side lookups (batch poisoning, save faults, attribution)
    # ------------------------------------------------------------------

    def batch_fault_at(self, step: int):
        """(nan_mask, inf_mask): (L,) f32 0/1 host arrays for ``step``
        (all-clear beyond the horizon)."""
        if 0 <= step < self.cfg.horizon:
            return self.nan[step], self.inf[step]
        z = np.zeros((self.num_learners,), np.float32)
        return z, z

    def save_fault(self, step: int):
        """``"torn"`` / ``"corrupt"`` / None for the save at ``step`` —
        threaded into ``checkpoint.save_state(fault=...)``."""
        return self.save_faults.get(int(step))

    def suspect(self, step: int):
        """The learner most recently targeted by a data/payload fault at
        or before ``step`` (None if none) — the attribution oracle the
        supervisor's quarantine policy consumes in tests/benches. Real
        deployments would attribute from telemetry (per-learner loss
        spread, comm CRC failures); under injected chaos the schedule
        itself is ground truth."""
        hi = min(int(step), self.cfg.horizon - 1)
        for s in range(hi, -1, -1):
            for mask in (self.nan[s], self.inf[s]):
                if mask.any():
                    return int(np.argmax(mask))
            if (self.scale[s] != 1.0).any():
                return int(np.argmax(self.scale[s] != 1.0))
            if (self.xor[s] != 0).any():
                return int(np.argmax(self.xor[s] != 0))
        return None

    # ------------------------------------------------------------------
    # compiled views for the other layers
    # ------------------------------------------------------------------

    @property
    def any_batch_faults(self) -> bool:
        return bool(self.nan.any() or self.inf.any())

    @property
    def any_payload_faults(self) -> bool:
        return bool((self.scale != 1.0).any() or (self.xor != 0).any())

    @property
    def any_crash_faults(self) -> bool:
        return bool((self.crash == 0.0).any())

    def crash_schedule(self) -> np.ndarray:
        """(T, L) 0/1 membership rows encoding the crash windows — ANDed
        into the elastic membership schedule by ``inject.apply_chaos``."""
        return self.crash.copy()

    def straggled_profile(self, profile) -> tuple:
        """The async step-time profile with straggle spikes added."""
        prof = np.asarray(profile, np.int64)
        assert prof.shape == (self.num_learners,), (
            prof.shape, self.num_learners
        )
        return tuple(int(t) for t in prof + self.straggle_extra)
