"""repro.chaos — deterministic fault injection (DESIGN.md §13).

A seeded, checkpointable ``FaultSchedule`` (compiled from a frozen
``ChaosConfig``) rides alongside the run like the elastic membership
schedule, composing injectors at every layer: NaN/Inf batches (data),
bit-flip / scale payload corruption (comm), learner crash windows mapped
onto the elastic membership mask (topology), straggler spikes on the
async step-time profiles, and torn / corrupt checkpoint writes. Every
injector off ⇒ bitwise-identical to today (pinned in tests/test_chaos.py).

Recovery lives in ``core/supervisor.py``; the verified checkpoint chain
in ``checkpoint/npz.py``.
"""
from repro.chaos.config import (
    FAULT_KINDS,
    STANDARD_KINDS,
    ChaosConfig,
    FaultSpec,
    standard_chaos,
)
from repro.chaos.inject import (
    PayloadCorruptor,
    apply_chaos,
    wrap_batch_fn,
)
from repro.chaos.schedule import FaultSchedule

__all__ = [
    "FAULT_KINDS",
    "STANDARD_KINDS",
    "ChaosConfig",
    "FaultSchedule",
    "FaultSpec",
    "PayloadCorruptor",
    "apply_chaos",
    "standard_chaos",
    "wrap_batch_fn",
]
