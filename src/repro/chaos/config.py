"""Fault-injection configuration (``repro.chaos``, DESIGN.md §13).

A chaos run is fully described by a frozen, hashable ``ChaosConfig``: a
seed, a horizon, and a tuple of ``FaultSpec``s. Everything downstream —
the compiled mask arrays of ``FaultSchedule``, the batch poisoner and
payload corruptor of ``inject.py``, the crash membership schedule — is a
pure function of this config (plus the supervisor's retry ``salt``), so
a chaos run is deterministic, replayable from any checkpoint (faults are
absolute-step indexed, like the elastic membership schedule), and
config-validated up front rather than failing mid-run.

Fault kinds, by the layer they perturb:

  nan_batch / inf_batch   data (data/synthetic.py): the target learner's
                          float batch leaves for the step are poisoned
                          host-side. Int-token LM batches have no float
                          leaves and are unaffected — NaN data is a
                          float-pipeline fault.
  payload_bitflip         comm (repro.comm): one seeded element of the
                          target learner's post-local-phase plane gets
                          one bit XOR-flipped (in-jit, real bitcast).
  payload_scale           comm: the target learner's whole plane is
                          scaled by ``magnitude`` (a mis-scaled wire
                          payload — huge but finite).
  crash                   topology (repro.topology): the learner is
                          removed from the elastic membership mask for
                          ``duration`` steps (mapped through the
                          stochastic-complement rewiring, §8).
  straggle                async server (§12): the learner's step-time
                          profile entry gains ``magnitude`` extra ticks
                          (the staleness bound is raised to stay valid).
  torn_save / corrupt_save  checkpoint (checkpoint/npz.py): the save at
                          ``step`` is torn (truncated, no sidecar) or
                          bit-flipped post-write.

``sticky``: a non-sticky fault is *transient* — it fires only on the
first attempt (supervisor retry ``salt`` 0); after a rollback the replay
is clean (a re-read batch, a re-sent payload). A sticky fault re-fires on
every retry — the hardware is actually broken — which is how
``recovery_exhausted`` is exercised.
"""
from __future__ import annotations

from dataclasses import dataclass, field

FAULT_KINDS = (
    "nan_batch",
    "inf_batch",
    "payload_bitflip",
    "payload_scale",
    "crash",
    "straggle",
    "torn_save",
    "corrupt_save",
    "finite_scale",
    "finite_bitflip",
)

# kinds that target a specific learner (the rest target the run)
LEARNER_KINDS = (
    "nan_batch", "inf_batch", "payload_bitflip", "payload_scale",
    "crash", "straggle", "finite_scale", "finite_bitflip",
)

# the largest |magnitude| a finite_scale fault may carry: scaled f32
# payloads of magnitude up to ~2^87 stay strictly below the f32 max
# (2^40 * 2^87 < 2^128), so the corrupted plane is finite BY CONSTRUCTION
FINITE_SCALE_MAX = 2.0 ** 40


@dataclass(frozen=True)
class FaultSpec:
    """One injected fault.

    kind       one of ``FAULT_KINDS``
    step       absolute meta step the fault fires at
    learner    target learner index (learner-targeted kinds; -1 draws one
               deterministically from ``ChaosConfig.seed`` and ``step``)
    duration   steps the fault persists (nan/inf bursts, crash windows)
    magnitude  payload_scale multiplier / straggle extra ticks
    bit        payload_bitflip: which bit of the f32 word to flip
               (bf16 planes flip ``bit - 16``; bits below 16 are then
               clamped to the sign of the mantissa head)
    sticky     re-fires on supervisor retries (see module docstring)
    """

    kind: str
    step: int
    learner: int = -1
    duration: int = 1
    magnitude: float = 8.0
    bit: int = 30
    sticky: bool = False

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, (
            f"unknown fault kind {self.kind!r}; choose from {FAULT_KINDS}"
        )
        assert self.step >= 0, self.step
        assert self.duration >= 1, self.duration
        assert 0 <= self.bit <= 31, self.bit
        if self.kind in ("torn_save", "corrupt_save"):
            assert self.learner == -1, (
                f"{self.kind} targets the run's save path, not a learner"
            )
        if self.kind == "finite_scale":
            # the finiteness guarantee is by construction, not hope: the
            # multiplier itself must be finite and bounded away from the
            # f32 overflow region (see FINITE_SCALE_MAX)
            import math

            assert math.isfinite(self.magnitude), self.magnitude
            assert 0 < abs(self.magnitude) <= FINITE_SCALE_MAX, (
                f"finite_scale magnitude {self.magnitude} outside "
                f"(0, {FINITE_SCALE_MAX}]"
            )
        if self.kind == "finite_bitflip":
            # mask the exponent-top bit: flipping bit 30 (f32) / 14 (bf16)
            # of a normal value lands in the inf/NaN exponent range, which
            # is exactly what the finite guard WOULD catch. Bits <= 29
            # produce huge-but-finite corruption the guard cannot see.
            object.__setattr__(self, "bit", min(self.bit, 29))


@dataclass(frozen=True)
class ChaosConfig:
    """The whole fault schedule: seed + horizon + fault tuple (frozen,
    hashable — rides in TrainConfig like every other config).

    horizon    schedule length T in meta steps; every fault must fire and
               expire within it (faults are compiled to (T, L) masks).
               Also the period of the crash membership schedule, so keep
               ``horizon >= meta_steps`` when crashes are injected — the
               schedule then never wraps and quarantine windows map 1:1
               onto absolute steps.
    """

    seed: int = 0
    horizon: int = 64
    faults: tuple = field(default_factory=tuple)

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))
        assert self.horizon >= 1, self.horizon
        for f in self.faults:
            assert isinstance(f, FaultSpec), f
            assert f.step + f.duration <= self.horizon, (
                f"fault {f.kind!r} at step {f.step} (duration "
                f"{f.duration}) exceeds the chaos horizon {self.horizon}"
            )

    @property
    def has_crash(self) -> bool:
        return any(f.kind == "crash" for f in self.faults)

    @property
    def has_straggle(self) -> bool:
        return any(f.kind == "straggle" for f in self.faults)


STANDARD_KINDS = ("crash", "nan", "payload", "straggle", "torn_save")


def standard_chaos(num_learners: int, meta_steps: int, *, seed: int = 0,
                   kinds=STANDARD_KINDS) -> ChaosConfig:
    """The bench's standard fault schedule (crash + NaN burst + payload
    corruption + torn save — ISSUE 9's acceptance scenario), sized to the
    run: faults land in the first half so the supervised run has room to
    recover, the horizon covers the whole run so the crash schedule never
    wraps. ``kinds`` selects a subset (CLI ``--chaos-faults``)."""
    assert num_learners >= 2, num_learners
    assert meta_steps >= 8, (
        f"the standard chaos schedule needs >= 8 meta steps to place its "
        f"faults, got {meta_steps}"
    )
    q = max(meta_steps // 8, 1)
    faults = []
    if "crash" in kinds:
        faults.append(FaultSpec("crash", step=q, learner=1,
                                duration=min(2 * q, meta_steps - q)))
    if "nan" in kinds:
        faults.append(FaultSpec("nan_batch", step=2 * q, learner=0))
    if "payload" in kinds:
        faults.append(FaultSpec("payload_scale", step=3 * q,
                                learner=num_learners - 1, magnitude=64.0))
        faults.append(FaultSpec("payload_bitflip", step=4 * q,
                                learner=num_learners - 1))
    if "straggle" in kinds:
        faults.append(FaultSpec("straggle", step=0, learner=1,
                                magnitude=1.0, duration=1))
    if "torn_save" in kinds:
        faults.append(FaultSpec("torn_save", step=5 * q))
    return ChaosConfig(seed=seed, horizon=max(meta_steps, 8),
                       faults=tuple(faults))
