import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run CLI.

Lowers + compiles every (architecture x input-shape) pair against the
single-pod (16x16 = 256 chips) and multi-pod (2x16x16 = 512 chips)
production meshes, printing memory_analysis() / cost_analysis() and
writing per-combination JSON (roofline terms included) to
benchmarks/results/dryrun/.

The two lines above run before ANY other import — jax locks the device
count on first initialisation. Smoke tests / benches never import this
module, so they see 1 CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --skip-existing
"""

import argparse
import json
import sys
import traceback


def main() -> int:
    from repro.configs.base import ARCH_IDS, INPUT_SHAPES
    from repro.launch import dryrun_lib as D

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None, help="arch ids (default: all)")
    ap.add_argument("--shape", nargs="*", default=None, help="input shapes (default: all)")
    ap.add_argument("--mesh", nargs="*", default=["single", "multi"],
                    choices=["single", "multi"])
    ap.add_argument("--hierarchical", action="store_true",
                    help="pod-level learners + FSDP inside pods (multi-pod only)")
    ap.add_argument("--algorithm", default="mavg")
    ap.add_argument("--tp-mode", default="megatron",
                    choices=["megatron", "fsdp", "dp"])
    ap.add_argument("--compute-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--variant", default="",
                    help="label suffix for perf-iteration results")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "everything"])
    ap.add_argument("--mlstm-chunk", type=int, default=0,
                    help="chunkwise-parallel mLSTM chunk length (0=recurrent)")
    ap.add_argument("--k", type=int, default=2,
                    help="K local steps per meta-step in the lowered program")
    ap.add_argument("--expert-axis", default="",
                    help="pin MoE dispatch/combine to this mesh axis")
    ap.add_argument("--expert-shard-map", action="store_true",
                    help="manual shard_map expert parallelism (serve only)")
    ap.add_argument("--no-serve-fsdp", action="store_true",
                    help="replicate serve weights over data (perf probe)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    if args.remat != "full":
        from repro.models import transformer

        transformer.set_remat_policy(args.remat)
    if args.mlstm_chunk:
        from repro.models import xlstm

        xlstm.set_mlstm_chunk(args.mlstm_chunk)
    if args.expert_axis:
        from repro.models import moe

        moe.set_expert_axis(args.expert_axis)
    if args.no_serve_fsdp:
        from repro.launch import specs

        specs.SERVE_FSDP_ENABLED = False

    archs = args.arch or ARCH_IDS
    shapes = args.shape or list(INPUT_SHAPES)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in args.mesh:
                if args.hierarchical and mesh != "multi":
                    continue
                mode = "hier" if args.hierarchical else "faithful"
                if args.variant:
                    mode = f"{mode}+{args.variant}"
                path = D.result_path(arch, shape, mesh, mode, args.algorithm)
                if args.skip_existing and os.path.exists(path):
                    print(f"SKIP (exists) {arch} {shape} {mesh} {mode}")
                    continue
                print(f"=== {arch} x {shape} x {mesh} ({mode}) ===", flush=True)
                try:
                    res = D.run_one(
                        arch, shape, mesh, hierarchical=args.hierarchical,
                        algorithm=args.algorithm, save_hlo=args.save_hlo,
                        tp_mode=args.tp_mode,
                        compute_dtype=args.compute_dtype,
                        variant=args.variant,
                        k_steps=args.k,
                        expert_shard_map=args.expert_shard_map,
                    )
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mesh, str(e)))
                    continue
                if res.get("skipped"):
                    print(f"  SKIPPED: {res['reason']}")
                else:
                    print(f"  memory_analysis: {json.dumps(res['memory'])}")
                    cost = res.get("cost", {})
                    print(
                        f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
                        f"bytes={cost.get('bytes accessed', 0):.3e}"
                    )
                    print(f"  collectives: {json.dumps(res['collectives']['by_type'])}")
                    r = res["roofline"]
                    print(
                        f"  roofline: compute={r['compute_s']:.4g}s "
                        f"memory={r['memory_s']:.4g}s "
                        f"collective={r['collective_s']:.4g}s "
                        f"-> {r['bottleneck']}-bound "
                        f"(useful_ratio={r['useful_ratio']:.3f})"
                    )
                    print(f"  lower={res['lower_s']}s compile={res['compile_s']}s")
                D.save_result(res, args.algorithm)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nAll requested dry-run combinations lowered + compiled.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
