"""Production meshes.

Target hardware: TPU v5e pods — 256 chips per pod (16x16 ICI torus),
2 pods over DCI for the multi-pod configuration.

* single-pod: (16, 16) over ('data', 'model') — 256 chips.
  M-AVG learners live on the 'data' axis (P = 16 learners, each a 16-way
  tensor-parallel group).
* multi-pod: (2, 16, 16) over ('pod', 'data', 'model') — 512 chips.
  Faithful mode: P = 32 learners over ('pod','data'). Hierarchical mode
  (beyond paper, DESIGN.md section 5): P = 2 learners — one per pod — each
  copy FSDP-sharded over 'data' x 'model'; the only inter-pod traffic is
  the meta-level average every K steps, amortising the slow DCI link
  exactly the way the paper amortises its Infiniband allreduce.

This module defines FUNCTIONS only — importing it never touches jax
device state, so tests see one CPU device while dryrun.py (which sets
XLA_FLAGS before any jax import) sees 512 host devices.
"""
from __future__ import annotations

import jax

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def learner_axes(mesh, *, hierarchical: bool = False):
    """Mesh axes the learner (paper's P) dimension is sharded over."""
    if "pod" in mesh.shape:
        return ("pod",) if hierarchical else ("pod", "data")
    return ("data",)


def num_learners(mesh, *, hierarchical: bool = False) -> int:
    out = 1
    for a in learner_axes(mesh, hierarchical=hierarchical):
        out *= mesh.shape[a]
    return out


def fsdp_axes(mesh, *, hierarchical: bool = False):
    """Axes used to shard each learner's copy beyond tensor parallelism."""
    if hierarchical and "pod" in mesh.shape:
        return "data"
    return None


def make_debug_mesh(data: int = 2, model: int = 2):
    """Tiny mesh for CPU integration tests (requires >=4 host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
