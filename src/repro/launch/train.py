"""Training launcher: end-to-end M-AVG training of an assigned architecture
(reduced or full config) on whatever devices are available.

On CPU this trains the reduced config with a small learner count (the
end-to-end example driver); on a real TPU pod, pass --full and the
production mesh from mesh.py is used with the learner axis sharded over
'data' (the jitted program is identical — that is what the dry-run
proves).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --algorithm mavg --learners 4 --k 4 --steps 50
"""
from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ALGORITHMS,
    ASYNC_UPDATES,
    COMM_SCHEMES,
    GOSSIP_GRAPHS,
    OBS_SINKS,
    TOPOLOGIES,
    AsyncConfig,
    CommConfig,
    ROBUST_ESTIMATORS,
    ElasticConfig,
    MAvgConfig,
    ObsConfig,
    RobustConfig,
    TopologyConfig,
    TrainConfig,
    get_config,
)
from repro.core.trainer import Trainer
from repro.data import lm_batch_fn, lm_eval_set
from repro.models import api as model_api
from repro.optim import warmup_cosine
from repro.pack import unpack_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    # choices derive from the configs/base.py constants so new algorithms /
    # schemes / topologies show up here without hand-maintained duplication
    ap.add_argument("--algorithm", default="mavg", choices=ALGORITHMS)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--momentum", type=float, default=0.7)
    ap.add_argument("--full", action="store_true",
                    help="full-scale config (TPU pod required)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--comm", default="dense", choices=COMM_SCHEMES,
                    help="meta-communication compression scheme (repro.comm)")
    ap.add_argument("--comm-k-frac", type=float, default=0.1,
                    help="kept fraction for the top-k comm schemes")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the comm error-feedback residual")
    ap.add_argument("--topology", default="flat", choices=TOPOLOGIES,
                    help="meta-level mixing topology (repro.topology)")
    ap.add_argument("--groups", type=int, default=1,
                    help="hierarchical: number of learner groups G")
    ap.add_argument("--outer-every", type=int, default=1,
                    help="hierarchical: cross-group average every H meta steps")
    ap.add_argument("--outer-momentum", type=float, default=0.0,
                    help="hierarchical: block momentum of the outer level")
    ap.add_argument("--gossip-graph", default="ring", choices=GOSSIP_GRAPHS,
                    help="gossip: mixing graph")
    ap.add_argument("--outer-comm", default=None, choices=COMM_SCHEMES,
                    help="cross-group comm scheme (default: same as --comm)")
    ap.add_argument("--group-k", default=None,
                    help="hierarchical: comma-separated per-group local-step "
                         "counts K_g (each <= --k), e.g. --group-k 2,4")
    ap.add_argument("--async-staleness", type=int, default=0,
                    help="async: staleness bound tau (center updates a "
                         "pulled copy may lag behind)")
    ap.add_argument("--async-profile", default=None,
                    help="async: comma-separated per-learner step-time "
                         "profile in meta ticks, e.g. --async-profile "
                         "1,1,2,4 (overrides --async-skew)")
    ap.add_argument("--async-skew", type=int, default=1,
                    help="async: slowest/fastest step-time ratio of the "
                         "seed-generated profile (1 = uniform)")
    ap.add_argument("--async-update", default="mavg", choices=ASYNC_UPDATES,
                    help="async: staleness-decayed update rule")
    ap.add_argument("--async-decay", type=float, default=None,
                    help="async: staleness decay base (default: the block "
                         "momentum, the mu^tau rule)")
    ap.add_argument("--async-seed", type=int, default=0,
                    help="async: seed assigning profile slots to learners")
    ap.add_argument("--elastic-period", type=int, default=0,
                    help="elastic membership schedule length in meta steps "
                         "(0 = everyone always present)")
    ap.add_argument("--elastic-drop", type=float, default=0.25,
                    help="fraction of learners absent per scheduled step")
    ap.add_argument("--elastic-seed", type=int, default=0,
                    help="seed of the deterministic membership schedule")
    ap.add_argument("--obs-sink", default="none", choices=OBS_SINKS,
                    help="structured run log sink (repro.obs): per-step "
                         "telemetry records under a run manifest")
    ap.add_argument("--run-dir", default=None,
                    help="run-log / trace directory (required for the "
                         "jsonl and csv sinks)")
    ap.add_argument("--trace", action="store_true",
                    help="phase span timers + Chrome-trace export to "
                         "<run-dir>/trace.json")
    ap.add_argument("--profiler", action="store_true",
                    help="capture a jax.profiler device trace into "
                         "<run-dir>/jax_trace")
    ap.add_argument("--obs-cost", action="store_true",
                    help="record the compiled meta step's measured HBM / "
                         "peak-state numbers in the run manifest")
    ap.add_argument("--obs-health", action="store_true",
                    help="run-health watchdogs over the flushed metric "
                         "windows (obs.health): structured alerts in the "
                         "run log, fatal rules halt with a resumable "
                         "checkpoint")
    ap.add_argument("--obs-no-halt", action="store_true",
                    help="demote fatal health rules to warn: record "
                         "alerts, never stop the run")
    ap.add_argument("--obs-attribution", action="store_true",
                    help="measured-vs-modeled phase attribution rows "
                         "(obs.profile) recorded once before step 0")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest VERIFIED checkpoint from "
                         "--checkpoint-dir (torn/corrupt snapshots are "
                         "skipped via the CRC sidecar chain; falls back "
                         "to the newest unverified one) and append to "
                         "the run log")
    ap.add_argument("--checkpoint-every", type=int, default=10,
                    help="checkpoint cadence in meta steps (with "
                         "--checkpoint-dir)")
    ap.add_argument("--checkpoint-keep", type=int, default=0,
                    help="retain only the last N verified snapshots "
                         "(0 = keep everything)")
    ap.add_argument("--chaos", action="store_true",
                    help="deterministic fault injection (repro.chaos): "
                         "run under the standard fault schedule sized to "
                         "--steps/--learners. NOTE: int-token LM batches "
                         "carry no float leaves, so the nan fault kind "
                         "perturbs nothing here — use crash/payload/"
                         "straggle/torn_save")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed of the standard chaos schedule")
    ap.add_argument("--chaos-faults", default=None,
                    help="comma subset of the standard fault kinds "
                         "(crash,nan,payload,straggle,torn_save); "
                         "default all")
    ap.add_argument("--robust", default=None, choices=ROBUST_ESTIMATORS,
                    help="robust meta aggregation (repro.robust): replace "
                         "the learner-stack mean with a coordinate-wise "
                         "trimmed mean or median ('mean' keeps the plain "
                         "mean but still enables clip/score below)")
    ap.add_argument("--robust-trim", type=int, default=1,
                    help="learners trimmed from EACH end per coordinate "
                         "(trimmed estimator)")
    ap.add_argument("--robust-clip", type=float, default=0.0,
                    help="per-learner displacement norm clip at this "
                         "multiple of the trailing-median budget "
                         "(0 = no clipping)")
    ap.add_argument("--robust-clip-window", type=int, default=8,
                    help="trailing-median ring length (meta steps) the "
                         "clip budget is computed over")
    ap.add_argument("--robust-no-score", action="store_true",
                    help="disable per-learner anomaly scoring (on by "
                         "default when --robust is set)")
    ap.add_argument("--robust-quarantine-after", type=int, default=0,
                    help="inline quarantine: mask a learner out of "
                         "membership after this many consecutive "
                         "anomalous flush windows (0 = never; needs a "
                         "membership-capable topology)")
    ap.add_argument("--finite-guard", action="store_true",
                    help="in-step NaN/Inf barrier: poisoned learner "
                         "planes are reset to the broadcast global "
                         "params before the mix (MAvgConfig.finite_guard)")
    ap.add_argument("--supervise", action="store_true",
                    help="wrap the run in core.supervisor.Supervisor: "
                         "on a health halt / checkpoint-verify failure, "
                         "roll back to the last verified snapshot and "
                         "retry with recovery policies (requires "
                         "--checkpoint-dir)")
    ap.add_argument("--supervise-retries", type=int, default=3,
                    help="supervisor retry budget before "
                         "RecoveryExhausted")
    ap.add_argument("--supervise-quarantine", type=int, default=0,
                    help="probation window (meta steps) a suspect "
                         "learner is quarantined from membership after "
                         "rollback (0 = never)")
    ap.add_argument("--supervise-readmit", type=int, default=1,
                    help="quarantine hysteresis: clean probation windows "
                         "a quarantined learner must sit out before "
                         "readmission (total mask = window * this)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if cfg.input_mode != "tokens":
        raise SystemExit(
            f"{args.arch} uses stub-frontend inputs; use examples/ for it"
        )

    outer_comm = (
        CommConfig(scheme=args.outer_comm, k_frac=args.comm_k_frac,
                   error_feedback=not args.no_error_feedback)
        if args.outer_comm else None
    )
    group_k = (
        tuple(int(k) for k in args.group_k.split(","))
        if args.group_k else None
    )
    elastic = (
        ElasticConfig(period=args.elastic_period, drop_frac=args.elastic_drop,
                      seed=args.elastic_seed)
        if args.elastic_period > 0 else None
    )
    server = (
        AsyncConfig(
            staleness=args.async_staleness,
            step_time=(tuple(int(t) for t in args.async_profile.split(","))
                       if args.async_profile else ()),
            skew=args.async_skew, seed=args.async_seed,
            update=args.async_update, decay=args.async_decay,
        )
        if args.topology == "async" else None
    )
    chaos_cfg = None
    if args.chaos:
        from repro.chaos import STANDARD_KINDS, standard_chaos

        kinds = (
            tuple(k.strip() for k in args.chaos_faults.split(","))
            if args.chaos_faults else STANDARD_KINDS
        )
        unknown = set(kinds) - set(STANDARD_KINDS)
        if unknown:
            raise SystemExit(
                f"--chaos-faults: unknown kinds {sorted(unknown)}; choose "
                f"from {STANDARD_KINDS}"
            )
        chaos_cfg = standard_chaos(
            args.learners, args.steps, seed=args.chaos_seed, kinds=kinds
        )
    if args.supervise and not args.checkpoint_dir:
        raise SystemExit("--supervise needs --checkpoint-dir (the "
                         "verified rollback chain lives there)")

    robust = (
        RobustConfig(
            estimator=args.robust, trim=args.robust_trim,
            clip_mult=args.robust_clip,
            clip_window=args.robust_clip_window,
            score=not args.robust_no_score,
            quarantine_after=args.robust_quarantine_after,
        )
        if args.robust is not None else None
    )

    def make_mcfg(momentum_scale: float = 1.0) -> MAvgConfig:
        return MAvgConfig(
            algorithm=args.algorithm, num_learners=args.learners,
            k_steps=args.k, learner_lr=args.lr,
            momentum=args.momentum * momentum_scale,
            finite_guard=args.finite_guard,
            robust=robust,
            comm=CommConfig(scheme=args.comm, k_frac=args.comm_k_frac,
                            error_feedback=not args.no_error_feedback),
            topology=TopologyConfig(
                kind=args.topology, groups=args.groups,
                outer_every=args.outer_every,
                outer_momentum=args.outer_momentum,
                graph=args.gossip_graph, outer_comm=outer_comm,
                group_k=group_k, elastic=elastic, server=server,
            ),
        )

    def loss_fn(params, batch):
        return model_api.loss_fn(params, cfg, batch)

    def make_trainer(plan) -> Trainer:
        tcfg = TrainConfig(
            model=cfg, mavg=make_mcfg(plan.momentum_scale),
            batch_per_learner=args.batch, seq_len=args.seq,
            meta_steps=args.steps, checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=(
                args.checkpoint_every if args.checkpoint_dir else 0
            ),
            checkpoint_keep=args.checkpoint_keep,
            chaos=chaos_cfg, data_salt=plan.data_salt,
            obs=ObsConfig(sink=args.obs_sink, run_dir=args.run_dir,
                          trace=args.trace, profiler=args.profiler,
                          cost_analysis=args.obs_cost,
                          health=args.obs_health,
                          health_halt=not args.obs_no_halt,
                          attribution=args.obs_attribution),
        )
        return Trainer(
            tcfg,
            loss_fn,
            init_params_fn=lambda rng: model_api.init_params(rng, cfg),
            batch_fn=lm_batch_fn(cfg, args.learners, args.k, args.batch,
                                 args.seq),
            lr_schedule=warmup_cosine(args.lr * plan.lr_scale, 5,
                                      args.steps),
        )

    if args.supervise:
        from repro.core.supervisor import (
            RecoveryPolicy,
            Supervisor,
        )

        sup = Supervisor(
            make_trainer,
            target_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            policy=RecoveryPolicy(
                max_retries=args.supervise_retries,
                quarantine_steps=args.supervise_quarantine,
                readmit_clean_windows=args.supervise_readmit,
            ),
        )
        trainer, history = sup.run()
    else:
        from repro.core.supervisor import RecoveryPlan

        trainer = make_trainer(RecoveryPlan())
        if args.resume:
            from repro.checkpoint import (
                latest_checkpoint,
                latest_verified_checkpoint,
            )

            ckpt = (
                latest_verified_checkpoint(args.checkpoint_dir or "")
                or latest_checkpoint(args.checkpoint_dir or "")
            )
            if ckpt is None:
                raise SystemExit(
                    "--resume: no checkpoint in --checkpoint-dir"
                )
            trainer.restore(ckpt)
            print(f"resumed from {ckpt}")
        history = trainer.run()

    eval_batch = lm_eval_set(cfg, n=32, seq_len=args.seq)
    loss, _ = jax.jit(loss_fn)(unpack_params(trainer.state), eval_batch)
    print(f"\nfinal train loss {history[-1]['loss']:.4f}  "
          f"eval loss {float(loss):.4f}  "
          f"samples {history[-1]['samples']}")
    trainer.close()


if __name__ == "__main__":
    main()
