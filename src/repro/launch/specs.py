"""Abstract input specs (ShapeDtypeStruct) + sharding assembly for the
dry-run and the real launchers.

input_specs() provides weak-type-correct, shardable stand-ins for every
model input — no device allocation — including the stub modality
frontends (audio frame embeddings, VLM patch embeddings) per the
assignment carve-out.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    InputShape,
    MAvgConfig,
    ModelConfig,
)
from repro.core.meta import MetaState, init_state
from repro.launch import mesh as meshlib
from repro.models import api as model_api
from repro.sharding import add_learner_axis, make_param_specs

DRYRUN_K_STEPS = 2  # local steps per meta-step in the lowered train program
SERVE_FSDP_THRESHOLD = 20e9  # params above this get FSDP-sharded weights


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# abstract params / state
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: model_api.init_params(k, cfg), jax.random.PRNGKey(0)
    )


def abstract_state(cfg: ModelConfig, mcfg: MAvgConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(lambda p: init_state(p, mcfg), params)


# ---------------------------------------------------------------------------
# train inputs: (L, K, B_local, ...) per learner per local step
# ---------------------------------------------------------------------------


def train_input_specs(cfg: ModelConfig, shape: InputShape, num_learners: int,
                      k_steps: int = DRYRUN_K_STEPS) -> dict:
    assert shape.global_batch % num_learners == 0, (
        f"{shape.name}: global_batch {shape.global_batch} not divisible by "
        f"P={num_learners}"
    )
    b_loc = shape.global_batch // num_learners
    lead = (num_learners, k_steps, b_loc)
    out = {}
    for name, (shp, dtype) in model_api.batch_shapes(cfg, 1, shape.seq_len).items():
        out[name] = sds(lead + shp[1:], dtype)
    return out


def train_input_shardings(cfg: ModelConfig, mesh, learner_axes) -> dict:
    def spec(_name, s):
        return NamedSharding(mesh, P(learner_axes, *([None] * (len(s.shape) - 1))))

    shapes = model_api.batch_shapes(cfg, 1, 8)
    return {name: NamedSharding(mesh, P(learner_axes)) for name in shapes}


def _batch_axes(mesh, batch: int):
    """Largest prefix of (pod, data) axes that divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    chosen = []
    size = 1
    for a in axes:
        if batch % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


# ---------------------------------------------------------------------------
# meta-step jit assembly (donation + shardings)
# ---------------------------------------------------------------------------


def meta_step_jit_kwargs(mcfg: MAvgConfig, state_shardings=None,
                         n_extra_args: int = 2,
                         donate_extra: tuple = ()) -> dict:
    """jax.jit kwargs for a ``step(state, batches, ...)`` meta step.

    One assembly point so every launcher agrees on the two coupled
    choices (DESIGN.md §10):

    * ``donate_argnums=(STATE_ARGNUM,)`` under ``mcfg.donate`` — the
      input MetaState's planes are aliased onto the output state's and
      updated in place, halving the meta phase's peak state HBM;
    * the state's in_shardings are the SAME object as its out_shardings.
      XLA only aliases a donated buffer whose input layout matches the
      output it is donated to, so a donated state must enter and leave
      the step under one sharding. (It also keeps the loop-carried
      layout stable across steps, donation or not.)

    ``n_extra_args`` counts the non-state positional args (batches, lr,
    and the telemetry ring under repro.obs) which stay unsharded /
    unconstrained. ``donate_extra`` names additional loop-carried argnums
    to donate regardless of ``mcfg.donate`` — the Trainer's on-device
    MetricsBuffer ring rides here (DESIGN.md §11): the caller never
    re-reads a pre-step ring, so its row write is always safe to do in
    place.
    """
    from repro.core.meta import STATE_ARGNUM

    kwargs = {}
    if state_shardings is not None:
        kwargs["in_shardings"] = (state_shardings,) + (None,) * n_extra_args
        kwargs["out_shardings"] = (state_shardings, None)
    donate = ((STATE_ARGNUM,) if mcfg.donate else ()) + tuple(donate_extra)
    if donate:
        kwargs["donate_argnums"] = donate
    return kwargs


# ---------------------------------------------------------------------------
# state shardings (train)
# ---------------------------------------------------------------------------


def state_shardings(cfg: ModelConfig, mcfg: MAvgConfig, mesh, *,
                    hierarchical: bool = False,
                    tp_mode: str = "megatron") -> MetaState:
    """tp_mode:
    'megatron' — within-learner tensor parallelism over 'model' (heads /
        d_ff sharded; all-reduce of activations per layer).
    'fsdp' — weights fully sharded over 'model' on their largest dim and
        the learner's local batch sharded over 'model' (ZeRO-3 style:
        per-layer weight all-gather instead of activation all-reduce —
        wins when B*S >> d_model, see EXPERIMENTS.md section Perf).
    """
    laxes = meshlib.learner_axes(mesh, hierarchical=hierarchical)
    fsdp = meshlib.fsdp_axes(mesh, hierarchical=hierarchical)
    params = abstract_params(cfg)
    if getattr(mcfg, "packed", False):
        return _packed_state_shardings(cfg, mcfg, mesh, params, laxes, tp_mode)
    if tp_mode == "dp":
        # paper-faithful extreme: one learner per CHIP, weights replicated
        # per learner — the only communication is the meta average (the
        # quantity the paper's K amortises). Only for models that fit one
        # chip (qwen3-1.7b-class).
        laxes = tuple(mesh.axis_names)
        gp_specs = make_param_specs(params, mesh, model_axis=None)
    elif tp_mode == "fsdp":
        gp_specs = make_param_specs(params, mesh, model_axis=None,
                                    fsdp_axis="model")
    else:
        gp_specs = make_param_specs(params, mesh, model_axis="model",
                                    fsdp_axis=fsdp)
    learner_specs = add_learner_axis(gp_specs, laxes if len(laxes) > 1 else laxes[0])
    n = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    from repro.comm import uses_error_feedback

    # EF residual is per-learner f32 with the learners' shapes -> same specs
    comm_sh = n(learner_specs) if uses_error_feedback(mcfg) else None

    # topology buffers (MetaState.topo): mirror the structure init_state
    # allocates. Gossip's params/momentum stacks and the async server's
    # anchor plane are (L, ...) like the learners and shard the same way;
    # everything else (G-leading hierarchical stacks, EF residual stacks,
    # (L,) clocks) stays replicated — small, or the axis rarely matches
    # a mesh axis size.
    from repro.core.meta import init_state as _init_state

    topo_abs = jax.eval_shape(
        lambda p: _init_state(p, mcfg), abstract_params(cfg)
    ).topo
    topo_sh = None
    if topo_abs is not None:
        topo_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), topo_abs
        )
        if mcfg.topology.kind == "gossip":
            topo_sh["params"] = n(learner_specs)
            topo_sh["momentum"] = n(learner_specs)
        if "anchor" in (topo_sh or {}):
            topo_sh["anchor"] = n(learner_specs)

    return MetaState(
        global_params=n(gp_specs),
        momentum=n(gp_specs),
        learners=n(learner_specs),
        local_momentum=None,
        step=NamedSharding(mesh, P()),
        comm_residual=comm_sh,
        topo=topo_sh,
    )


def _packed_state_shardings(cfg: ModelConfig, mcfg: MAvgConfig, mesh, params,
                            laxes, tp_mode: str) -> MetaState:
    """Shardings for the packed flat meta-plane (repro.pack, DESIGN.md §9).

    Every plane is one (rows, 128) buffer (or a (lead, rows, 128) stack),
    so per-leaf tensor-parallel specs don't apply; instead the packed row
    dimension is sharded over 'model' when it divides cleanly (each shard
    keeps the 8-row sublane multiple) — ZeRO-style: the local phase's
    unpack gathers what its matmuls need, the meta phase stays sharded.
    The learner axis of stacked planes shards over the learner mesh axes
    exactly as per-leaf learners did. The returned MetaState carries the
    same static PackSpec as the live state, so jit in_shardings matches
    structurally.
    """
    from repro.pack import make_pack_spec

    spec = make_pack_spec(params, dtype=mcfg.meta_dtype)
    if tp_mode == "dp":
        laxes = tuple(mesh.axis_names)
    lax_spec = laxes if len(laxes) > 1 else laxes[0]
    row_ax = None
    if (tp_mode != "dp" and "model" in mesh.shape
            and spec.rows % (mesh.shape["model"] * 8) == 0):
        row_ax = "model"
    ns = lambda *s: NamedSharding(mesh, P(*s))
    plane = ns(row_ax, None)  # (rows, 128) meta planes
    stacked = ns(lax_spec, row_ax, None)  # (L, rows, 128) learner planes

    from repro.comm import uses_error_feedback

    topo_abs = jax.eval_shape(
        lambda p: init_state(p, mcfg), params
    ).topo
    topo_sh = None
    if topo_abs is not None:
        # hierarchical (G, ...) stacks replicated (G is small and rarely
        # matches a mesh axis); gossip per-learner stacks and the async
        # server's (L, rows, 128) anchor plane shard like the learners
        topo_sh = jax.tree.map(lambda _: ns(), topo_abs)
        if mcfg.topology.kind == "gossip":
            topo_sh["params"] = stacked
            topo_sh["momentum"] = stacked
        if "anchor" in topo_sh:
            topo_sh["anchor"] = stacked

    return MetaState(
        global_params=plane,
        momentum=plane,
        learners=stacked,
        local_momentum=None,
        step=ns(),
        comm_residual=stacked if uses_error_feedback(mcfg) else None,
        topo=topo_sh,
        spec=spec,
    )


# ---------------------------------------------------------------------------
# serve (prefill) inputs
# ---------------------------------------------------------------------------


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    out = {}
    for name, (shp, dtype) in model_api.batch_shapes(
        cfg, shape.global_batch, shape.seq_len
    ).items():
        if name == "labels":
            continue
        out[name] = sds(shp, dtype)
    return out


def prefill_input_shardings(cfg: ModelConfig, mesh, shape: InputShape) -> dict:
    baxes = _batch_axes(mesh, shape.global_batch)
    specs = {}
    for name, (shp, _dt) in model_api.batch_shapes(
        cfg, shape.global_batch, shape.seq_len
    ).items():
        if name == "labels":
            continue
        specs[name] = NamedSharding(mesh, P(baxes, *([None] * (len(shp) - 1))))
    return specs


SERVE_FSDP_ENABLED = True  # flip via launchers for perf comparison


def serve_param_shardings(cfg: ModelConfig, mesh):
    params = abstract_params(cfg)
    fsdp = None
    if SERVE_FSDP_ENABLED and cfg.param_count() > SERVE_FSDP_THRESHOLD:
        fsdp = ("pod", "data") if "pod" in mesh.shape else "data"
    specs = make_param_specs(params, mesh, model_axis="model", fsdp_axis=fsdp)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# decode inputs (one token + cache)
# ---------------------------------------------------------------------------


def decode_input_specs(cfg: ModelConfig, shape: InputShape):
    cache = jax.eval_shape(
        partial(model_api.init_cache, cfg, shape.global_batch, shape.seq_len)
    )
    tokens = sds((shape.global_batch,), jnp.int32)
    return cache, tokens


def cache_shardings(cfg: ModelConfig, mesh, shape: InputShape):
    """Family-specific KV-cache / recurrent-state placement (DESIGN.md §6)."""
    baxes = _batch_axes(mesh, shape.global_batch)
    msize = mesh.shape["model"]

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        S = shape.seq_len
        seq_ax = "model" if S % msize == 0 else None
        return {
            "k": ns(None, baxes, seq_ax, None, None),
            "v": ns(None, baxes, seq_ax, None, None),
            "pos": ns(),
        }
    if cfg.family == "hybrid":
        W = min(cfg.sliding_window or shape.seq_len, shape.seq_len)
        win_ax = "model" if W % msize == 0 else None
        d_in_ok = (cfg.ssm_expand * cfg.d_model) % msize == 0
        din_ax = "model" if d_in_ok else None
        return {
            "k": ns(None, baxes, win_ax, None, None),
            "v": ns(None, baxes, win_ax, None, None),
            "k_meta": ns(None, baxes, None, None, None),
            "v_meta": ns(None, baxes, None, None, None),
            "conv": ns(None, baxes, None, din_ax),
            "ssm": ns(None, baxes, din_ax, None),
            "pos": ns(),
        }
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        hd_m = d_in // cfg.num_heads  # mLSTM head dim
        hd_s = cfg.d_model // cfg.num_heads  # sLSTM head dim
        m_ax = "model" if hd_m % msize == 0 else None
        s_ax = "model" if hd_s % msize == 0 else None
        return {
            "m": (
                ns(None, None, baxes, None, None, m_ax),  # C (G,M,B,nh,hd,hd)
                ns(None, None, baxes, None, m_ax),  # n (G,M,B,nh,hd)
                ns(None, None, baxes, None),  # m (G,M,B,nh)
                ns(None, None, baxes, None, din_ax := (
                    "model" if d_in % msize == 0 else None
                )),  # conv buffer (G,M,B,k-1,d_in)
            ),
            "s": (
                ns(None, baxes, None, s_ax),  # c (G,B,nh,hd)
                ns(None, baxes, None, s_ax),
                ns(None, baxes, None, s_ax),
                ns(None, baxes, None, s_ax),
            ),
            "pos": ns(),
        }
    raise ValueError(cfg.family)


def decode_token_sharding(mesh, shape: InputShape):
    baxes = _batch_axes(mesh, shape.global_batch)
    return NamedSharding(mesh, P(baxes))
