"""Dry-run machinery: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, extract memory / cost / collective
statistics for the roofline analysis.

This module does NOT touch XLA_FLAGS — the CLI entry point
(repro/launch/dryrun.py) sets the 512-device host platform before any jax
import, per the spec. Import this library under whatever mesh you have.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    MAvgConfig,
    ModelConfig,
    get_config,
)
from repro.core.meta import make_meta_step
from repro.launch import mesh as meshlib
from repro.launch import specs as S
from repro.models import api as model_api
from repro.roofline import collective_bytes, compute_terms
from repro.roofline.hlo_cost import hlo_cost

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun")


# ---------------------------------------------------------------------------
# applicability (DESIGN.md section 7)
# ---------------------------------------------------------------------------


def applicability(cfg: ModelConfig, shape: InputShape):
    """Returns (runs: bool, reason: str, serve_cfg: ModelConfig)."""
    if shape.is_decode and cfg.is_encoder_only:
        return False, "encoder-only architecture has no autoregressive decode", cfg
    if shape.name == "long_500k":
        if cfg.subquadratic:
            return True, "", cfg
        if cfg.name == "qwen3-1.7b":
            # demonstration sliding-window serve variant (DESIGN.md section 7)
            return True, "sliding-window-8192 serve variant", replace(
                cfg, sliding_window=8192
            )
        return False, "full O(S^2) attention at 524k context; no sub-quadratic variant defined by the model card", cfg
    return True, "", cfg


# ---------------------------------------------------------------------------
# step builders — return (jitted_fn, abstract_args tuple)
# ---------------------------------------------------------------------------


def build_train(cfg: ModelConfig, mesh, shape: InputShape, *,
                hierarchical: bool = False, algorithm: str = "mavg",
                k_steps: int = S.DRYRUN_K_STEPS, tp_mode: str = "megatron",
                compute_dtype: str = "float32"):
    L = (mesh.size if tp_mode == "dp"
         else meshlib.num_learners(mesh, hierarchical=hierarchical))
    mcfg = MAvgConfig(
        algorithm=algorithm, num_learners=L, k_steps=k_steps,
        learner_lr=0.01, momentum=0.7, compute_dtype=compute_dtype,
    )

    def loss_fn(params, batch):
        return model_api.loss_fn(params, cfg, batch)

    step_fn = make_meta_step(loss_fn, mcfg)

    def train_step(state, batches):
        return step_fn(state, batches)

    state_sds = S.abstract_state(cfg, mcfg)
    batch_sds = S.train_input_specs(cfg, shape, L, k_steps)
    state_sh = S.state_shardings(cfg, mcfg, mesh, hierarchical=hierarchical,
                                 tp_mode=tp_mode)
    laxes = (tuple(mesh.axis_names) if tp_mode == "dp"
             else meshlib.learner_axes(mesh, hierarchical=hierarchical))
    lax_spec = laxes if len(laxes) > 1 else laxes[0]
    b_loc = shape.global_batch // L
    if tp_mode == "fsdp" and b_loc % mesh.shape["model"] == 0:
        # fsdp mode: local batch data-parallel over the model axis
        batch_spec = P(lax_spec, None, "model")
    else:
        batch_spec = P(lax_spec)
    batch_sh = {name: NamedSharding(mesh, batch_spec) for name in batch_sds}
    # donation + the shared state in/out sharding come from one assembly
    # point (S.meta_step_jit_kwargs): under mcfg.donate the lowered train
    # program aliases the input state planes onto the output state —
    # the dry-run HLO's peak meta-state memory is 1x the live state, not 2x
    kwargs = S.meta_step_jit_kwargs(mcfg, state_sh, n_extra_args=1)
    kwargs["in_shardings"] = (state_sh, batch_sh)
    fn = jax.jit(train_step, **kwargs)
    return fn, (state_sds, batch_sds), mcfg


def build_prefill(cfg: ModelConfig, mesh, shape: InputShape):
    def prefill(params, batch):
        logits, _ = model_api.forward(params, cfg, batch)
        return logits

    params_sds = S.abstract_params(cfg)
    batch_sds = S.prefill_input_specs(cfg, shape)
    params_sh = S.serve_param_shardings(cfg, mesh)
    batch_sh = S.prefill_input_shardings(cfg, mesh, shape)
    fn = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
    return fn, (params_sds, batch_sds)


def build_decode(cfg: ModelConfig, mesh, shape: InputShape):
    def serve_step(params, cache, tokens):
        return model_api.decode_step(params, cfg, cache, tokens)

    params_sds = S.abstract_params(cfg)
    cache_sds, tokens_sds = S.decode_input_specs(cfg, shape)
    params_sh = S.serve_param_shardings(cfg, mesh)
    cache_sh = S.cache_shardings(cfg, mesh, shape)
    tok_sh = S.decode_token_sharding(mesh, shape)
    fn = jax.jit(serve_step, in_shardings=(params_sh, cache_sh, tok_sh))
    return fn, (params_sds, cache_sds, tokens_sds)


# ---------------------------------------------------------------------------
# single-combination dry run
# ---------------------------------------------------------------------------


def _analyses(compiled):
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {k: float(v) for k, v in dict(ca).items()
                       if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        out["cost"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            out["memory"] = {}
        else:
            out["memory"] = {
                attr: float(getattr(ma, attr))
                for attr in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(ma, attr)
            }
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    return out


def _sharded_arg_bytes(sds_tree, sh_tree, mesh) -> float:
    """Analytic per-device bytes of the arguments under their shardings."""
    total = 0.0
    sds_leaves = jax.tree.leaves(sds_tree)
    sh_leaves = jax.tree.leaves(
        sh_tree, is_leaf=lambda x: isinstance(x, NamedSharding)
    )
    for sds, sh in zip(sds_leaves, sh_leaves):
        n_shards = 1
        if isinstance(sh, NamedSharding):
            for axis in sh.spec:
                if axis is None:
                    continue
                for a in (axis if isinstance(axis, tuple) else (axis,)):
                    n_shards *= mesh.shape[a]
        total += sds.size * jnp.dtype(sds.dtype).itemsize / n_shards
    return total


def run_one(arch: str, shape_name: str, mesh_name: str, *,
            hierarchical: bool = False, algorithm: str = "mavg",
            save_hlo: bool = False, tp_mode: str = "megatron",
            compute_dtype: str = "float32", variant: str = "",
            k_steps: int = S.DRYRUN_K_STEPS,
            expert_shard_map: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    runs, reason, cfg_eff = applicability(cfg, shape)
    mode = "hier" if hierarchical else "faithful"
    if variant:
        mode = f"{mode}+{variant}"
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": mode, "algorithm": algorithm, "tp_mode": tp_mode,
        "compute_dtype": compute_dtype,
        "skipped": not runs, "reason": reason,
    }
    if not runs:
        return result

    mesh = meshlib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    t0 = time.time()
    if expert_shard_map and shape.kind != "train":
        # manual all-to-all-style expert parallelism (serving only —
        # shard_map does not compose with the learner vmap)
        from repro.models import moe

        moe.set_expert_axis("model", mesh)
    with mesh:
        if shape.kind == "train":
            fn, args, mcfg = build_train(
                cfg_eff, mesh, shape, hierarchical=hierarchical,
                algorithm=algorithm, tp_mode=tp_mode,
                compute_dtype=compute_dtype, k_steps=k_steps,
            )
            k_steps = mcfg.k_steps
        elif shape.kind == "prefill":
            fn, args = build_prefill(cfg_eff, mesh, shape)
            mcfg, k_steps = None, 1
        else:
            fn, args = build_decode(cfg_eff, mesh, shape)
            mcfg, k_steps = None, 1
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    if expert_shard_map:
        from repro.models import moe

        moe.set_expert_axis(None, None)

    result.update(_analyses(compiled))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    result["collectives"] = {"total": coll["total"], "by_type": coll["by_type"]}
    result["n_collective_sites"] = len(coll["sites"])
    result["lower_s"] = round(t_lower, 2)
    result["compile_s"] = round(t_compile, 2)
    result["hlo_lines"] = hlo.count("\n")

    # trip-count-aware FLOP/byte totals from the HLO itself
    # (cost_analysis counts while bodies once — see hlo_cost.py)
    cost = hlo_cost(hlo)
    result["hlo_cost"] = {"flops": cost.flops, "bytes": cost.bytes}
    hlo_flops = cost.flops or result["cost"].get("flops", 0.0)
    hlo_bytes = cost.bytes or result["cost"].get("bytes accessed", 0.0)
    terms = compute_terms(
        arch=arch, shape=shape, mesh_name=mesh_name, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=float(coll["total"]), cfg=cfg_eff, k_steps=k_steps,
        comm=mcfg.comm if mcfg is not None else None,
        num_learners=mcfg.num_learners if mcfg is not None else 1,
    )
    result["roofline"] = terms.to_dict()
    result["param_count"] = cfg_eff.param_count()
    result["active_param_count"] = cfg_eff.active_param_count()
    if save_hlo:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        hpath = os.path.join(
            RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}__{mode}.hlo.txt"
        )
        with open(hpath, "w") as f:
            f.write(hlo)
        result["hlo_path"] = hpath
    return result


def result_path(arch, shape_name, mesh_name, mode="faithful", algorithm="mavg"):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "" if algorithm == "mavg" else f"__{algorithm}"
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}__{mode}{suffix}.json"
    )


def save_result(res: dict, algorithm="mavg"):
    path = result_path(res["arch"], res["shape"], res["mesh"], res["mode"],
                       res.get("algorithm", algorithm))
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return path
