"""Serving launcher: batched autoregressive decoding with the global
weights w~ (serving never touches the M-AVG learner state).

CPU: serves the reduced config with a small batch — the end-to-end check
that prefill -> decode loop -> detokenised stream works. TPU: the same
program under the production mesh with serve_param_shardings.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import api as model_api


def generate(params, cfg, prompt_tokens, max_new: int, cache_len: int,
             temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature batched generation. prompt: (B, S0) int32."""
    B, S0 = prompt_tokens.shape
    decode = jax.jit(
        lambda p, c, t: model_api.decode_step(p, cfg, c, t)
    )
    prefill = jax.jit(
        lambda p, b: model_api.prefill(p, cfg, b, cache_len)
    )
    logits, cache = prefill(params, {"tokens": prompt_tokens})

    out = []
    rng = jax.random.PRNGKey(seed)
    tok = None
    for i in range(max_new):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        out.append(tok)
        logits, cache = decode(params, cache, tok)
    return jnp.stack(out, axis=1)  # (B, max_new)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    params = model_api.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32,
    )
    cache_len = args.prompt_len + args.tokens + 8
    t0 = time.time()
    out = generate(params, cfg, prompt, args.tokens, cache_len,
                   temperature=args.temperature)
    dt = time.time() - t0
    print(f"arch={args.arch} batch={args.batch} generated {args.tokens} "
          f"tokens/seq in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print("sample token ids:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
