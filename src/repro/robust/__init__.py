# Byzantine-tolerant meta aggregation (DESIGN.md §14): robust estimators
# over the learner stack, per-learner norm clipping to a trailing-median
# displacement budget, and Krum-style anomaly scores streamed through
# repro.obs. MAvgConfig.robust=None leaves every code path untouched.
from repro.robust.aggregator import (
    ROBUST_METRIC_PREFIX,
    RobustAggregator,
    anomaly_scores,
    make_robust,
    robust_ring_buffers,
)

__all__ = [
    "ROBUST_METRIC_PREFIX",
    "RobustAggregator",
    "anomaly_scores",
    "make_robust",
    "robust_ring_buffers",
]
