"""Robust aggregation over the learner stack (DESIGN.md §14).

The paper's meta update trusts the plain mean over learner displacements;
one learner shipping finite-but-corrupt payloads (a mis-scaled plane, a
bit-flip that misses NaN/Inf) silently poisons the global momentum for
every learner — the in-step finite guard (§13) cannot see it, and the
supervisor's only remedy is detect -> halt -> rollback, which discards
healthy work. This module bounds each learner's influence on the
consensus instead:

* **Trimmed mean / median** (``aggregate``): coordinate-wise order
  statistics over the L axis replace the learner-stack mean inside the
  mean-based reducers (kernels/robust_reduce.py on the packed plane;
  the jnp oracle per leaf elsewhere). ``trim=0`` is bitwise the plain
  mean.
* **Norm clipping** (``guard``): each learner's displacement is scaled
  down to at most ``clip_mult x`` the median of a trailing ring of
  per-step median displacement norms — a budget that tracks the run's
  own scale, so a learner whose payload suddenly blows up is bounded
  without tuning an absolute threshold. Clipped-away mass is REJECTED:
  the clip happens before the wire compressor, so it never enters the
  error-feedback residual and is never replayed into later rounds.
* **Anomaly scores** (``anomaly_scores``): Krum-style nearest-neighbor
  distance sums computed from the per-learner Gram matrix of the
  displacement stack — one (L, L) matmul over the packed plane, no
  pairwise plane materialization. Scores stream through repro.obs each
  mix (schema v4 ``robust`` records) and feed the Trainer's inline
  quarantine, so a persistently-anomalous learner is removed from
  membership without a HealthHalt round-trip.

The trailing-median ring rides in ``MetaState.topo`` (keys
``robust_ring``/``robust_count``) only when clipping is on — the
checkpoint layout changes only when the feature does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MAvgConfig, RobustConfig
from repro.kernels import ops as kops

# every robust metric key the topologies emit starts with this; the
# Trainer repackages them out of the step records into ``robust`` records
ROBUST_METRIC_PREFIX = "robust_"

_EPS = 1e-12


def robust_ring_buffers(rcfg: RobustConfig) -> dict:
    """The trailing-median clip state merged into ``MetaState.topo`` by
    ``core.meta.init_state`` when clipping is on: a (clip_window,) ring of
    per-step median displacement norms plus the write cursor. No clipping
    fires until the ring has filled once (the warmup)."""
    return {
        "robust_ring": jnp.zeros((rcfg.clip_window,), jnp.float32),
        "robust_count": jnp.zeros((), jnp.int32),
    }


def anomaly_scores(delta, *, neighbors: int = 0):
    """Krum-style anomaly scores of an (L, ...) displacement stack.

    Builds the (L, L) Gram matrix G from per-learner flattened chunks
    (``||d_j - d_k||^2 = G_jj + G_kk - 2 G_jk`` — one matmul, no pairwise
    plane), then scores each learner by the sum of its ``neighbors``
    smallest non-self distances (0 = auto: L - 2). Large score = far from
    every cluster of peers = anomalous.
    """
    flats = [
        x.astype(jnp.float32).reshape(x.shape[0], -1)
        for x in jax.tree.leaves(delta)
    ]
    L = flats[0].shape[0]
    G = sum(f @ f.T for f in flats)  # (L, L)
    sq = jnp.diagonal(G)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * G, 0.0)
    d2 = d2 + jnp.where(jnp.eye(L, dtype=bool), jnp.inf, 0.0)
    k = neighbors if neighbors > 0 else max(L - 2, 1)
    k = min(k, L - 1)
    return jnp.sum(jnp.sort(d2, axis=1)[:, :k], axis=1)


class RobustAggregator:
    """The per-topology robust hooks, built once by ``make_robust``."""

    def __init__(self, rcfg: RobustConfig, *, num_learners: int,
                 use_pallas: bool = False):
        self.cfg = rcfg
        self.num_learners = num_learners
        self.use_pallas = use_pallas

    # -- trimmed mean / median -----------------------------------------
    @property
    def aggregates(self) -> bool:
        """Does the estimator replace the learner-stack mean (i.e. should
        mean-based reducers get the ``aggregate`` hook)?"""
        return self.cfg.estimator != "mean"

    def trim_for(self, L: int) -> int:
        if self.cfg.estimator == "median":
            return kops.median_trim(L)
        if self.cfg.estimator == "trimmed":
            # an aggregation narrower than the config's width (e.g. the
            # hierarchical outer level over G groups) clamps to a valid
            # trim rather than failing — the groups' means are already
            # robust, the outer trim is defense in depth
            return min(self.cfg.trim, (L - 1) // 2)
        return 0

    def aggregate(self, stacked):
        """Robust aggregate of a stacked (L, ...) pytree — the drop-in
        replacement for ``tree_mean_axis0`` / per-leaf ``jnp.mean(axis=0)``
        inside the reducers. f32 output, like the means it replaces."""
        L = jax.tree.leaves(stacked)[0].shape[0]
        return kops.robust_reduce_tree(
            stacked, trim=self.trim_for(L), use_pallas=self.use_pallas
        )

    # -- norm clip + anomaly scores ------------------------------------
    @property
    def has_clip(self) -> bool:
        return self.cfg.clip_mult > 0.0

    def guard(self, delta, topo):
        """Score + clip the (L, ...) displacement stack ``delta``.

        Returns ``(scale, topo', metrics)`` where ``scale`` is the (L,)
        f32 per-learner clip factor (1.0 = untouched; the caller applies
        it with a ``where(scale < 1, ...)`` select so unclipped learners
        stay bit-identical), ``topo'`` carries the advanced trailing-
        median ring when clipping is on, and ``metrics`` holds the
        ``robust_*`` scalars the Trainer repackages into ``robust``
        records.
        """
        leaves = jax.tree.leaves(delta)
        L = leaves[0].shape[0]
        metrics = {}
        sqsum = sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)),
                    axis=tuple(range(1, x.ndim)))
            for x in leaves
        )
        norms = jnp.sqrt(sqsum)  # (L,) per-learner displacement norms
        scale = jnp.ones((L,), jnp.float32)
        if self.has_clip:
            ring = topo["robust_ring"]
            count = topo["robust_count"]
            W = self.cfg.clip_window
            full = count >= W
            budget = self.cfg.clip_mult * jnp.median(ring)
            raw = jnp.minimum(1.0, budget / jnp.maximum(norms, _EPS))
            scale = jnp.where(full, raw, 1.0)
            ring = ring.at[count % W].set(jnp.median(norms))
            topo = {**topo, "robust_ring": ring, "robust_count": count + 1}
            metrics["robust_clipped_learners"] = jnp.sum(
                (scale < 1.0).astype(jnp.float32)
            )
            metrics["robust_clip_budget"] = jnp.where(full, budget, 0.0)
        if self.cfg.score:
            scores = anomaly_scores(
                delta, neighbors=self.cfg.score_neighbors
            )
            metrics["robust_anomaly_score"] = jnp.max(scores)
            for j in range(L):
                metrics[f"robust_score_{j}"] = scores[j]
        metrics["robust_trim_fraction"] = jnp.float32(
            2.0 * self.trim_for(self.num_learners) / self.num_learners
        )
        return scale, topo, metrics

    def clip_anchored(self, learners, anchor, topo):
        """Guard applied at the learner-weight level against an already
        (L, ...)-shaped anchor stack (flat: broadcast w~; hierarchical:
        each learner's group params): learners whose displacement from
        their anchor exceeds the budget are pulled back to
        ``anchor + scale * delta`` BEFORE the reducer runs, so the wire
        compressor — and therefore the error-feedback residual — only
        ever sees the clipped displacement (rejection, not deferral).
        Unclipped learners pass through bit-identical.

        Returns (learners', topo', metrics).
        """
        delta = jax.tree.map(
            lambda w, a: w.astype(jnp.float32) - a.astype(jnp.float32),
            learners, anchor,
        )
        scale, topo, metrics = self.guard(delta, topo)
        if self.has_clip:
            def clip_leaf(w, a, d):
                s = scale.reshape((w.shape[0],) + (1,) * (w.ndim - 1))
                clipped = (a.astype(jnp.float32) + d * s).astype(w.dtype)
                return jnp.where(s < 1.0, clipped, w)

            learners = jax.tree.map(clip_leaf, learners, anchor, delta)
        return learners, topo, metrics

    def clip_learners(self, learners, gp, topo):
        """``clip_anchored`` against the shared meta params w~ (the flat
        topology's anchor). Returns (learners', topo', metrics)."""
        anchor = jax.tree.map(
            lambda w, g: jnp.broadcast_to(
                g[None], (w.shape[0],) + g.shape
            ).astype(g.dtype),
            learners, gp,
        )
        return self.clip_anchored(learners, anchor, topo)

    def clip_stack(self, delta, topo):
        """The gossip/async guard applied directly on an already-formed
        (L, ...) displacement stack (gossip's ``w - x``, the async
        server's anchor displacements): scales over-budget rows down,
        leaves the rest bit-identical. Returns (delta', topo', metrics)."""
        scale, topo, metrics = self.guard(delta, topo)
        if self.has_clip:
            def clip_leaf(d):
                s = scale.reshape((d.shape[0],) + (1,) * (d.ndim - 1))
                return jnp.where(s < 1.0, d.astype(jnp.float32) * s,
                                 d.astype(jnp.float32))

            delta = jax.tree.map(clip_leaf, delta)
        return delta, topo, metrics


def make_robust(cfg: MAvgConfig):
    """RobustAggregator for ``cfg.robust``, or None when the subsystem is
    off — the None keeps every existing code path object-identical."""
    if cfg.robust is None:
        return None
    return RobustAggregator(
        cfg.robust, num_learners=cfg.num_learners, use_pallas=cfg.use_pallas
    )
