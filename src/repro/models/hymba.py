"""Hymba (arXiv:2411.13676): every layer runs attention heads and mamba
(selective-SSM) heads *in parallel* on the same input; the two branch
outputs are normalised, combined with learned per-branch scalars, and
projected. 128 learnable meta tokens are prepended and remain globally
attendable under sliding-window attention (they are the "global path";
the reference model additionally keeps 3 full-attention layers, which we
fold into the meta-token mechanism — noted in DESIGN.md).

Training uses an associative scan for the SSM (O(S log S) depth) and
sliding-window attention; decode carries O(1) SSM state + a rolling
window KV cache + static meta-token KV, so the long_500k shape is served
with a constant-size working set.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import _dense_init
from repro.models.xlstm import _causal_conv

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _dt_rank(cfg: ModelConfig) -> int:
    return max(16, cfg.d_model // 16)


def _init_block(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_state
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 10)
    p = {
        "norm": L.init_rmsnorm(d),
        "attn": L.init_attention(ks[0], cfg),
        # mamba branch
        "w_xz": _dense_init(ks[1], (d, 2, d_in), d),
        "conv": _dense_init(ks[2], (cfg.ssm_conv, d_in), cfg.ssm_conv),
        "w_bc": _dense_init(ks[3], (d_in, 2 * N), d_in),
        "w_dt_down": _dense_init(ks[4], (d_in, r), d_in),
        "w_dt_up": _dense_init(ks[5], (r, d_in), r),
        "b_dt": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01))),  # softplus^-1
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, N))
        ),
        "D": jnp.ones((d_in,), jnp.float32),
        "w_ssm_out": _dense_init(ks[6], (d_in, d), d_in),
        # branch fusion
        "attn_out_norm": L.init_rmsnorm(d),
        "ssm_out_norm": L.init_rmsnorm(d),
        "beta_attn": jnp.ones((), jnp.float32),
        "beta_ssm": jnp.ones((), jnp.float32),
        # ffn
        "mlp_norm": L.init_rmsnorm(d),
        "mlp": L.init_mlp(ks[7], d, cfg.d_ff),
    }
    return p


def init(rng, cfg: ModelConfig) -> dict:
    k_e, k_b = jax.random.split(rng)
    ks = jax.random.split(k_b, cfg.num_layers)
    return {
        "embed": L.init_embed(k_e, cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "blocks": jax.vmap(partial(_init_block, cfg=cfg))(ks),
    }


# ---------------------------------------------------------------------------
# mamba branch
# ---------------------------------------------------------------------------


def _ssm_inputs(bp, cfg: ModelConfig, xn):
    """Shared preprocessing for seq scan and single-step decode.

    xn: (B, S, d) -> u (conv'd, gated input), z gate, dt, B_t, C_t.
    """
    dt_ = xn.dtype
    xz = jnp.einsum("bsd,dtf->bstf", xn, bp["w_xz"].astype(dt_))
    x_in, z = xz[..., 0, :], xz[..., 1, :]
    return x_in, z


def _ssm_params(bp, u):
    """u: (B, S, d_in) post-conv. Returns dt, Bt, Ct (f32)."""
    N = bp["A_log"].shape[1]
    bc = jnp.einsum("bsf,fn->bsn", u, bp["w_bc"].astype(u.dtype)).astype(jnp.float32)
    Bt, Ct = bc[..., :N], bc[..., N:]
    dt = jnp.einsum(
        "bsf,fr,rg->bsg", u, bp["w_dt_down"].astype(u.dtype),
        bp["w_dt_up"].astype(u.dtype),
    ).astype(jnp.float32)
    dt = jax.nn.softplus(dt + bp["b_dt"])
    return dt, Bt, Ct


def mamba_seq(bp, cfg: ModelConfig, xn, conv_state=None, ssm_state=None):
    """xn: (B, S, d). Returns (y (B, S, d), (conv_state, ssm_state))."""
    B, S, d = xn.shape
    x_in, z = _ssm_inputs(bp, cfg, xn)
    if conv_state is not None:  # decode-style continuation
        x_cat = jnp.concatenate([conv_state.astype(x_in.dtype), x_in], axis=1)
        u = jax.nn.silu(_causal_conv(x_cat, bp["conv"]))[:, conv_state.shape[1] :]
    else:
        u = jax.nn.silu(_causal_conv(x_in, bp["conv"]))
    dt, Bt, Ct = _ssm_params(bp, u)
    A = -jnp.exp(bp["A_log"])  # (d_in, N)
    u32 = u.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)  # (B, S, d_in, N)
    dBu = dt[..., None] * Bt[:, :, None, :] * u32[..., None]

    if ssm_state is None:
        ssm_state = jnp.zeros((B, u.shape[-1], A.shape[1]), jnp.float32)
    # fold the initial state into the first step
    dBu = dBu.at[:, 0].add(dA[:, 0] * ssm_state)

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a2 * a1, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (dA, dBu), axis=1)  # (B,S,d_in,N)
    y = jnp.einsum("bsfn,bsn->bsf", h, Ct) + bp["D"] * u32
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xn.dtype)
    out = jnp.einsum("bsf,fd->bsd", y, bp["w_ssm_out"].astype(xn.dtype))
    kc = cfg.ssm_conv - 1
    if S >= kc:
        new_conv_state = x_in[:, -kc:]
    else:
        new_conv_state = jnp.pad(x_in, ((0, 0), (kc - S, 0), (0, 0)))
    return out, (new_conv_state, h[:, -1])


def mamba_step(bp, cfg: ModelConfig, xn, conv_state, ssm_state):
    """One-token decode. xn: (B, 1, d); conv_state (B, k-1, d_in)."""
    x_in, z = _ssm_inputs(bp, cfg, xn)  # (B,1,d_in)
    x_cat = jnp.concatenate([conv_state.astype(x_in.dtype), x_in], axis=1)
    u = jax.nn.silu(
        jnp.einsum("bkf,kf->bf", x_cat, bp["conv"].astype(x_in.dtype))
    )[:, None]
    dt, Bt, Ct = _ssm_params(bp, u)
    A = -jnp.exp(bp["A_log"])
    u32 = u.astype(jnp.float32)
    dA = jnp.exp(dt[:, 0, :, None] * A)  # (B, d_in, N)
    dBu = dt[:, 0, :, None] * Bt[:, 0, None, :] * u32[:, 0, :, None]
    h = dA * ssm_state + dBu
    y = jnp.einsum("bfn,bn->bf", h, Ct[:, 0]) + bp["D"] * u32[:, 0]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(xn.dtype)
    out = jnp.einsum("bf,fd->bd", y, bp["w_ssm_out"].astype(xn.dtype))[:, None]
    new_conv = jnp.concatenate([conv_state[:, 1:], x_in.astype(conv_state.dtype)], axis=1)
    return out, (new_conv, h)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def _block_fwd(x, bp, cfg: ModelConfig, positions):
    xn = L.rmsnorm(x, bp["norm"], cfg.norm_eps)
    # attention branch (sliding window + globally-visible meta prefix)
    q, k, v = L._qkv(xn, bp["attn"], cfg, positions)
    S = x.shape[1]
    attn_fn = L.chunked_attention if S > L.ATTN_CHUNK_THRESHOLD else L.full_attention
    a = attn_fn(
        q, k, v, causal=True, sliding_window=cfg.sliding_window,
        prefix_global=cfg.meta_tokens,
    )
    a = jnp.einsum("bshk,hkd->bsd", a, bp["attn"]["wo"].astype(x.dtype))
    # mamba branch
    s, _ = mamba_seq(bp, cfg, xn)
    fused = (
        bp["beta_attn"] * L.rmsnorm(a, bp["attn_out_norm"], cfg.norm_eps)
        + bp["beta_ssm"] * L.rmsnorm(s, bp["ssm_out_norm"], cfg.norm_eps)
    ) * 0.5
    h = x + fused.astype(x.dtype)
    y = L.swiglu(L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps), bp["mlp"])
    return h + y


def forward(params, cfg: ModelConfig, batch, *, use_pallas: bool = False):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    dt = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], cfg, tokens)
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["embed"]["meta"].astype(dt), (B, cfg.meta_tokens, cfg.d_model)
        )
        x = jnp.concatenate([meta, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def step(x, bp):
        return _block_fwd(x, bp, cfg, positions), None

    x, _ = lax.scan(step, x, params["blocks"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)
    return logits, {"aux_loss": jnp.float32(0.0)}


def loss_fn(params, cfg: ModelConfig, batch, *, use_pallas: bool = False):
    logits, _ = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.meta_tokens:
        pad = jnp.full((labels.shape[0], cfg.meta_tokens), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = L.cross_entropy(logits[:, :-1], labels[:, 1:])
    return ce, {"ce": ce, "aux_loss": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, batch, cache_len: int, *,
            use_pallas: bool = False):
    """Prompt pass building window KV + meta-token KV + SSM states."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    dt = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], cfg, tokens)
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["embed"]["meta"].astype(dt), (B, cfg.meta_tokens, cfg.d_model)
        )
        x = jnp.concatenate([meta, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    W = min(cfg.sliding_window or cache_len, max(cache_len, 1))

    def place(kv):  # last W positions, left-padded
        if S >= W:
            return kv[:, S - W:]
        return jnp.pad(kv, ((0, 0), (W - S, 0), (0, 0), (0, 0)))

    def step(x, bp):
        xn = L.rmsnorm(x, bp["norm"], cfg.norm_eps)
        q, k, v = L._qkv(xn, bp["attn"], cfg, positions)
        attn_fn = (
            L.chunked_attention if S > L.ATTN_CHUNK_THRESHOLD else L.full_attention
        )
        a = attn_fn(q, k, v, causal=True, sliding_window=cfg.sliding_window,
                    prefix_global=cfg.meta_tokens)
        a = jnp.einsum("bshk,hkd->bsd", a, bp["attn"]["wo"].astype(x.dtype))
        s_out, (conv_s, ssm_s) = mamba_seq(bp, cfg, xn)
        fused = (
            bp["beta_attn"] * L.rmsnorm(a, bp["attn_out_norm"], cfg.norm_eps)
            + bp["beta_ssm"] * L.rmsnorm(s_out, bp["ssm_out_norm"], cfg.norm_eps)
        ) * 0.5
        h = x + fused.astype(x.dtype)
        y = L.swiglu(L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps), bp["mlp"])
        caps = (
            place(k).astype(dt), place(v).astype(dt),
            k[:, : cfg.meta_tokens].astype(dt), v[:, : cfg.meta_tokens].astype(dt),
            conv_s.astype(dt), ssm_s,
        )
        return h + y, caps

    x, (k_w, v_w, k_m, v_m, conv_all, ssm_all) = lax.scan(
        step, x, params["blocks"]
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)[:, -1]
    cache = {
        "k": k_w, "v": v_w, "k_meta": k_m, "v_meta": v_m,
        "conv": conv_all, "ssm": ssm_all,
        "pos": jnp.asarray(S, jnp.int32),
    }
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """Rolling-window KV + meta-token KV + O(1) mamba state per layer.

    Total size is O(window + meta), NOT O(seq_len): this is what makes
    long_500k feasible for the hybrid family.
    """
    dt = jnp.dtype(dtype or cfg.dtype)
    Lyr = cfg.num_layers
    W = min(cfg.sliding_window or seq_len, seq_len)
    d_in = cfg.ssm_expand * cfg.d_model
    kv = (Lyr, batch, W, cfg.num_kv_heads, cfg.head_dim)
    meta_kv = (Lyr, batch, cfg.meta_tokens, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv, dt),
        "v": jnp.zeros(kv, dt),
        "k_meta": jnp.zeros(meta_kv, dt),
        "v_meta": jnp.zeros(meta_kv, dt),
        "conv": jnp.zeros((Lyr, batch, cfg.ssm_conv - 1, d_in), dt),
        "ssm": jnp.zeros((Lyr, batch, d_in, cfg.ssm_state), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, *, use_pallas: bool = False):
    """tokens: (B,). Window cache is shifted left one slot per step."""
    import math as _math

    pos = cache["pos"]  # absolute position of the new token
    B = tokens.shape[0]
    dt = jnp.dtype(cfg.dtype)
    W = cache["k"].shape[2]
    x = L.embed_tokens(params["embed"], cfg, tokens[:, None])

    def step(x, inp):
        bp, kc, vc, km, vm, conv_s, ssm_s = inp
        xn = L.rmsnorm(x, bp["norm"], cfg.norm_eps)
        q, k_new, v_new = L._qkv(xn, bp["attn"], cfg, pos[None])
        kc = jnp.concatenate([kc[:, 1:], k_new.astype(kc.dtype)], axis=1)
        vc = jnp.concatenate([vc[:, 1:], v_new.astype(vc.dtype)], axis=1)
        # window positions: pos-W+1 .. pos ; meta tokens at 0..m-1
        kk = jnp.concatenate([km, kc], axis=1).astype(q.dtype)
        vv = jnp.concatenate([vm, vc], axis=1).astype(q.dtype)
        n_rep = cfg.num_heads // cfg.num_kv_heads
        kk, vv = L._expand_kv(kk, n_rep), L._expand_kv(vv, n_rep)
        s = jnp.einsum("bqhk,bshk->bhqs", q, kk).astype(jnp.float32)
        s = s / _math.sqrt(cfg.head_dim)
        win_pos = pos - W + 1 + jnp.arange(W)
        valid = jnp.concatenate(
            [jnp.ones((cfg.meta_tokens,), bool), win_pos >= cfg.meta_tokens], 0
        )
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        prob = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        a = jnp.einsum("bhqs,bshk->bqhk", prob, vv)
        a = jnp.einsum("bshk,hkd->bsd", a, bp["attn"]["wo"].astype(x.dtype))
        m_out, (conv_s, ssm_s) = mamba_step(bp, cfg, xn, conv_s, ssm_s)
        fused = (
            bp["beta_attn"] * L.rmsnorm(a, bp["attn_out_norm"], cfg.norm_eps)
            + bp["beta_ssm"] * L.rmsnorm(m_out, bp["ssm_out_norm"], cfg.norm_eps)
        ) * 0.5
        h = x + fused.astype(x.dtype)
        y = L.swiglu(L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps), bp["mlp"])
        return h + y, (kc, vc, conv_s, ssm_s)

    x, (k_all, v_all, conv_all, ssm_all) = lax.scan(
        step,
        x,
        (
            params["blocks"],
            cache["k"],
            cache["v"],
            cache["k_meta"],
            cache["v_meta"],
            cache["conv"],
            cache["ssm"],
        ),
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)[:, 0]
    new_cache = dict(
        cache, k=k_all, v=v_all, conv=conv_all, ssm=ssm_all, pos=pos + 1
    )
    return logits, new_cache
