"""Mixture-of-Experts layer: fine-grained routed experts + shared experts.

Covers DeepSeekMoE (2 shared + 64 routed, top-6) and Kimi-K2
(1 shared + 384 routed, top-8).

Dispatch uses the capacity-bounded gather/scatter pattern: tokens are
assigned positions inside their expert's capacity buffer with a cumsum
over the routing one-hot; the expert dimension is sharded over the
``model`` mesh axis (expert parallelism), so the gather/scatter lowers to
the all-to-all-style collectives a real MoE deployment performs, while the
expert matmuls stay local.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, init_mlp, swiglu


# Expert-parallel sharding annotations. None = pure data flow (CPU tests);
# the launchers set this to 'model' so the dispatch gather / combine
# scatter keep the expert dim pinned to the tensor-parallel mesh axis.
EXPERT_AXIS = None

# Manual expert parallelism via shard_map (serving paths only — shard_map
# does not compose with the learner vmap in this JAX version, measured in
# EXPERIMENTS.md §Perf C). Each shard computes ONLY its local experts from
# the replicated token block and contributes a partial (T, d) psum:
# communication = one psum per layer, no replicate-reshard fallbacks.
SHARD_MAP_MESH = None  # set by launchers to the active Mesh


def _shard_map(fn, *, mesh, in_specs, out_specs, axis_names):
    """Version-compatible shard_map: ``jax.shard_map`` (new API, takes
    ``axis_names``) when present, else ``jax.experimental.shard_map`` where
    the equivalent is the complement ``auto`` axis set."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names)
    from jax.experimental.shard_map import shard_map as _sm

    # Older JAX has no axis_names= (and its partial-auto mode trips XLA's
    # "PartitionId not supported for SPMD partitioning"); run fully manual —
    # axes absent from the specs are replicated, which matches these specs.
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def set_expert_axis(axis, mesh=None):
    global EXPERT_AXIS, SHARD_MAP_MESH
    EXPERT_AXIS = axis
    SHARD_MAP_MESH = mesh


def _constrain_experts(x, spec=None):
    """Pin the expert dim to the tensor-parallel mesh axis.

    The capacity gather's output sharding is ambiguous to GSPMD (indices
    sharded on E, source replicated); left alone it replicates x_e and
    then ALL-GATHERS the expert weights per layer (~34 GB/layer for
    kimi-k2 — measured, EXPERIMENTS.md §Perf). No-op unless a launcher
    called set_expert_axis.
    """
    if EXPERT_AXIS is None:
        return x
    if spec is None:
        spec = (EXPERT_AXIS,) + (None,) * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def init_moe(key, cfg: ModelConfig) -> dict:
    d, E, de = cfg.d_model, cfg.num_experts, cfg.d_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": _dense_init(ks[0], (d, E), d),
        "w_in": _dense_init(ks[1], (E, d, 2, de), d),  # [gate, up] per expert
        "w_out": _dense_init(ks[2], (E, de, d), de),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[3], d, cfg.num_shared_experts * de)
    return p


def _capacity(num_tokens: int, cfg: ModelConfig, dropless: bool = False) -> int:
    """Per-expert capacity. ``dropless=True`` (serving paths) sizes the
    buffer for the worst case so no token is ever dropped: batched
    prefill logits then match token-by-token decode exactly
    (tests/test_decode_consistency.py), which capacity dropping breaks (a
    drop depends on the *other* tokens in the batch). top_k indices are
    distinct per token, so one expert receives at most ``num_tokens``
    slots — that bound, not num_tokens * k, keeps the dispatch buffer
    E x T instead of E x T*k (ragged dropless dispatch to shrink this
    further is a ROADMAP open item)."""
    if dropless:
        c = num_tokens
    else:
        c = int(num_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor of 8


def _route(xt, p, cfg: ModelConfig, dropless: bool = False):
    """Router + capacity assignment (shared by both execution paths).

    Returns (gates (T,k), slot_expert (T*k,), pos_clamped, keep, aux).
    """
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    dt = xt.dtype
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce) * cfg.moe_aux_coef

    # dispatch: slot s = (t, j) -> (expert, position-in-capacity)
    C = _capacity(T, cfg, dropless)
    slot_expert = idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(slot_expert, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # (T*k,)
    keep = pos_in_e < C
    pos_clamped = jnp.minimum(pos_in_e, C - 1)
    return gates, slot_expert, pos_clamped, keep, aux, C


def moe_layer(x, p, cfg: ModelConfig, dropless: bool = False):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, d)
    dt = x.dtype

    gates, slot_expert, pos_clamped, keep, aux, C = _route(xt, p, cfg, dropless)

    if SHARD_MAP_MESH is not None and EXPERT_AXIS is not None:
        out = _experts_shard_map(
            xt, p, cfg, gates, slot_expert, pos_clamped, keep, C
        )
        if cfg.num_shared_experts:
            out = out + swiglu(xt, p["shared"])
        return out.reshape(B, S, d), aux

    token_of_slot = jnp.repeat(jnp.arange(T), k)
    # scatter token ids into the (E, C) dispatch table; sentinel T = empty.
    # Dropped (over-capacity) slots scatter out of range (mode='drop') —
    # they must NOT write, or they'd overwrite the slot that exactly
    # fills the capacity (duplicate-index scatter order is unspecified).
    flat = jnp.where(keep, slot_expert * C + pos_clamped, E * C)
    dispatch = (
        jnp.full((E * C,), T, jnp.int32)
        .at[flat].set(token_of_slot, mode="drop")
        .reshape(E, C)
    )

    # gate weights laid out like the dispatch table (E, C)
    gate_tab = jnp.zeros((E * C,), jnp.float32).at[flat].set(
        gates.reshape(-1), mode="drop"
    ).reshape(E, C)

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
    x_e = xt_pad[dispatch]  # (E, C, d) — expert-parallel gather
    x_e = _constrain_experts(x_e)

    h = jnp.einsum("ecd,edtf->ectf", x_e, p["w_in"].astype(dt))
    h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt))  # (E, C, d)
    y_e = _constrain_experts(y_e)

    # ---- combine: scatter-add on the expert shards ----
    # Each expert shard accumulates its C tokens into a partial (T, d)
    # buffer; under GSPMD (E sharded over 'model') this lowers to one
    # all-reduce of (T, d) instead of a replicated (T*k, d) gather +
    # segment-sum (perf iteration for kimi-k2, EXPERIMENTS.md §Perf).
    y_w = y_e * gate_tab[..., None].astype(dt)  # (E, C, d)
    out = jnp.zeros((T + 1, d), dt).at[dispatch.reshape(-1)].add(
        y_w.reshape(E * C, d), mode="drop"
    )[:T]
    # the combined tokens are replicated again (one all-reduce over the
    # expert axis); keep the exchange in the compute dtype
    if EXPERT_AXIS is not None:
        out = jax.lax.with_sharding_constraint(out, P(None, None))

    if cfg.num_shared_experts:
        out = out + swiglu(xt, p["shared"])
    return out.reshape(B, S, d), aux


def _experts_shard_map(xt, p, cfg: ModelConfig, gates, slot_expert,
                       pos_clamped, keep, C):
    """Manual expert parallelism (serving paths).

    Each 'model'-axis shard owns E/n_shards experts; it dispatches the
    replicated token block to its local experts, runs the FFNs locally,
    and contributes a partial (T, d) output — combined with ONE psum.
    Communication per layer = one (T, d) all-reduce, versus the GSPMD
    gather/scatter path's replicate-reshard fallbacks (EXPERIMENTS.md
    §Perf C).
    """
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    dt = xt.dtype
    mesh = SHARD_MAP_MESH
    n_shards = mesh.shape[EXPERT_AXIS]
    E_loc = E // n_shards
    P_ = P

    def local(xt, gates, slot_expert, pos_clamped, keep, w_in, w_out):
        shard = jax.lax.axis_index(EXPERT_AXIS)
        lo = shard * E_loc
        mine = keep & (slot_expert >= lo) & (slot_expert < lo + E_loc)
        flat = jnp.where(
            mine, (slot_expert - lo) * C + pos_clamped, E_loc * C
        )
        token_of_slot = jnp.repeat(jnp.arange(T), k)
        dispatch = (
            jnp.full((E_loc * C,), T, jnp.int32)
            .at[flat].set(token_of_slot, mode="drop")
            .reshape(E_loc, C)
        )
        gate_tab = jnp.zeros((E_loc * C,), jnp.float32).at[flat].set(
            gates.reshape(-1), mode="drop"
        ).reshape(E_loc, C)
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
        x_e = xt_pad[dispatch]  # (E_loc, C, d)
        h = jnp.einsum("ecd,edtf->ectf", x_e, w_in.astype(dt))
        h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
        y_e = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dt))
        y_w = y_e * gate_tab[..., None].astype(dt)
        part = jnp.zeros((T + 1, d), dt).at[dispatch.reshape(-1)].add(
            y_w.reshape(E_loc * C, d), mode="drop"
        )[:T]
        # psum in f32: XLA CPU's AllReducePromotion pass check-fails on
        # bf16 all-reduce (hlo_instruction.cc "Invalid binary opcode copy")
        return jax.lax.psum(part.astype(jnp.float32), EXPERT_AXIS).astype(dt)

    rep = P_()
    fn = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P_(None, None), P_(None, None), rep, rep, rep,
                  P_(EXPERT_AXIS), P_(EXPERT_AXIS)),
        out_specs=P_(None, None),
        axis_names={EXPERT_AXIS},
    )
    return fn(xt, gates, slot_expert, pos_clamped, keep, p["w_in"], p["w_out"])
