"""xLSTM (arXiv:2405.04517): mLSTM (matrix-memory) + sLSTM (scalar-memory)
blocks with exponential gating, alternating in a ``slstm_every`` pattern.

Training uses the recurrent form via ``lax.scan`` over time (O(S) — this is
what makes the long_500k shape runnable for this family); decode carries the
per-layer recurrent state, so serving one token is O(1) in context length.

State pytrees:
  mLSTM: C (B, nh, hd, hd) matrix memory, n (B, nh, hd), m (B, nh)
  sLSTM: c, n, h (B, nh, hd), m (B, nh, hd)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import _dense_init

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_mlstm_block(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = cfg.num_heads
    hd = d_in // nh
    ks = jax.random.split(key, 8)
    return {
        "norm": L.init_rmsnorm(d),
        "w_up": _dense_init(ks[0], (d, 2, d_in), d),  # [x-path, z-gate]
        "conv": _dense_init(ks[1], (cfg.ssm_conv, d_in), cfg.ssm_conv),
        "wq": _dense_init(ks[2], (d_in, nh, hd), d_in),
        "wk": _dense_init(ks[3], (d_in, nh, hd), d_in),
        "wv": _dense_init(ks[4], (d_in, nh, hd), d_in),
        "w_i": _dense_init(ks[5], (d_in, nh), d_in),
        "b_i": jnp.zeros((nh,), jnp.float32),
        "w_f": _dense_init(ks[6], (d_in, nh), d_in),
        "b_f": jnp.ones((nh,), jnp.float32) * 3.0,  # forget-bias init
        "out_norm": L.init_rmsnorm(d_in),
        "w_down": _dense_init(ks[7], (d_in, d), d_in),
    }


def _init_slstm_block(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    ff = -(-int(d * 4 / 3) // 128) * 128  # proj factor 4/3 rounded to 128
    ks = jax.random.split(key, 11)
    p = {"norm": L.init_rmsnorm(d), "out_norm": L.init_rmsnorm(d)}
    for gi, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = _dense_init(ks[gi], (d, nh, hd), d)
        p[f"r_{g}"] = _dense_init(ks[4 + gi], (nh, hd, hd), hd)
        p[f"b_{g}"] = (jnp.ones((nh, hd)) * 3.0 if g == "f" else jnp.zeros((nh, hd)))
    p["w_up"] = _dense_init(ks[8], (d, 2, ff), d)
    p["w_down"] = _dense_init(ks[9], (ff, d), ff)
    return p


def init(rng, cfg: ModelConfig) -> dict:
    assert cfg.slstm_every >= 2 and cfg.num_layers % cfg.slstm_every == 0
    G = cfg.num_layers // cfg.slstm_every  # super-blocks
    M = cfg.slstm_every - 1  # mLSTM blocks per super-block
    k_e, k_m, k_s = jax.random.split(rng, 3)
    km = jax.random.split(k_m, G * M).reshape(G, M, 2)
    params = {
        "embed": L.init_embed(k_e, cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "mlstm": jax.vmap(jax.vmap(partial(_init_mlstm_block, cfg=cfg)))(km),
        "slstm": jax.vmap(partial(_init_slstm_block, cfg=cfg))(
            jax.random.split(k_s, G)
        ),
    }
    return params


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------


def _causal_conv(x, w):
    """x: (B, S, d_in); w: (k, d_in) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    return out


# Chunk length for the chunkwise-parallel mLSTM training form. 0 keeps the
# step-recurrent form. Perf iteration (EXPERIMENTS.md section Perf,
# xlstm-350m): the recurrent form round-trips the (B, nh, hd, hd) matrix
# memory through HBM once per TOKEN; the chunkwise form (equivalent math,
# xLSTM paper appendix) carries state once per CHUNK and turns the
# intra-chunk work into MXU-shaped matmuls.
MLSTM_CHUNK = 0


def set_mlstm_chunk(n: int) -> None:
    global MLSTM_CHUNK
    MLSTM_CHUNK = n


def _mlstm_inputs(bp, cfg: ModelConfig, x, state):
    """Shared projections for both mLSTM integrators."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    nh = cfg.num_heads
    hd = d_in // nh
    dt = x.dtype
    C0, n0, m0, conv_buf = state

    xn = L.rmsnorm(x, bp["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,dtf->bstf", xn, bp["w_up"].astype(dt))
    xu, z = up[..., 0, :], up[..., 1, :]
    # carry the causal-conv receptive field across calls (decode needs the
    # last ssm_conv-1 inputs; zeros at t=0 match the train-time zero pad)
    kc = cfg.ssm_conv - 1
    conv_in = jnp.concatenate([conv_buf.astype(xu.dtype), xu], axis=1)
    xc = jax.nn.silu(_causal_conv(conv_in, bp["conv"]))[:, kc:]
    new_conv_buf = conv_in[:, -kc:].astype(jnp.float32)
    q = jnp.einsum("bsf,fhk->bshk", xc, bp["wq"].astype(dt))
    k = jnp.einsum("bsf,fhk->bshk", xc, bp["wk"].astype(dt)) / jnp.sqrt(
        jnp.float32(hd)
    ).astype(dt)
    v = jnp.einsum("bsf,fhk->bshk", xu, bp["wv"].astype(dt))
    i_pre = (
        jnp.einsum("bsf,fh->bsh", xc, bp["w_i"].astype(dt)).astype(jnp.float32)
        + bp["b_i"]
    )
    f_pre = (
        jnp.einsum("bsf,fh->bsh", xc, bp["w_f"].astype(dt)).astype(jnp.float32)
        + bp["b_f"]
    )
    return q, k, v, i_pre, f_pre, z, new_conv_buf, (C0, n0, m0)


def mlstm_chunked(bp, cfg: ModelConfig, x, state=None, chunk: int = 64):
    """Chunkwise-parallel mLSTM (math identical to the recurrence).

    Within a chunk of length T, with b_t = cumsum(f_pre) and stabiliser
    m_t = max(b_t + m0, max_{s<=t}(b_t - b_s + i_s)):
        h_t = [ sum_{s<=t} e^{b_t-b_s+i_s-m_t} (q_t.k_s) v_s
                + e^{b_t+m0-m_t} C0 q_t ] / max(|q_t . n_t|, 1)
    and the chunk-final (C, n, m) feeds the next chunk — one HBM round
    trip of the matrix memory per chunk instead of per token.
    """
    B, S, d = x.shape
    if state is None:
        state = mlstm_init_state(cfg, B)
    q, k, v, i_pre, f_pre, z, new_conv_buf, (C0, n0, m0) = _mlstm_inputs(
        bp, cfg, x, state
    )
    dt = x.dtype
    nh = cfg.num_heads
    d_in = cfg.ssm_expand * d
    assert S % chunk == 0, (S, chunk)
    NC, T = S // chunk, chunk

    def resh(a):  # (B, S, nh, hd) -> (NC, B, nh, T, hd) f32
        return (
            a.astype(jnp.float32)
            .reshape(B, NC, T, nh, -1)
            .transpose(1, 0, 3, 2, 4)
        )

    qs, ks, vs = resh(q), resh(k), resh(v)
    gates = lambda g: g.reshape(B, NC, T, nh).transpose(1, 0, 3, 2)  # (NC,B,nh,T)
    iis, ffs = gates(i_pre), gates(f_pre)
    tril = jnp.tril(jnp.ones((T, T), bool))

    def one_chunk(carry, inp):
        C, n, m = carry
        qc, kc_, vc, ic, fc = inp  # (B,nh,T,hd) / (B,nh,T)
        b = jnp.cumsum(fc, axis=-1)  # (B,nh,T)
        # running stabiliser: m_t = max(b_t + m0, b_t + cummax(i_s - b_s))
        running = jax.lax.cummax(ic - b, axis=ic.ndim - 1)
        m_t = jnp.maximum(b + m[..., None], b + running)  # (B,nh,T)
        inter = jnp.exp(b + m[..., None] - m_t)  # (B,nh,T)
        # decay matrix D_ts = exp(b_t - b_s + i_s - m_t), s <= t
        logD = b[..., :, None] - b[..., None, :] + ic[..., None, :] \
            - m_t[..., :, None]
        D = jnp.where(tril, jnp.exp(logD), 0.0)  # (B,nh,T,T)
        scores = jnp.einsum("bhtk,bhsk->bhts", qc, kc_) * D
        num = jnp.einsum("bhts,bhsv->bhtv", scores, vc)
        num = num + inter[..., None] * jnp.einsum("bhtk,bhvk->bhtv", qc, C)
        n_t = jnp.einsum("bhts,bhsk->bhtk", D, kc_) + inter[..., None] * n[
            ..., None, :
        ]
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhtk,bhtk->bht", qc, n_t)), 1.0
        )
        h = num / den[..., None]  # (B,nh,T,hd)
        # chunk-final state (t = T-1 weights, same stabiliser convention)
        m_end = m_t[..., -1]
        w_s = jnp.exp(b[..., -1:] - b + ic - m_end[..., None])  # (B,nh,T)
        C_new = jnp.exp(b[..., -1] + m - m_end)[..., None, None] * C \
            + jnp.einsum("bhsv,bhsk->bhvk", vc * w_s[..., None], kc_)
        n_new = jnp.exp(b[..., -1] + m - m_end)[..., None] * n \
            + jnp.einsum("bhs,bhsk->bhk", w_s, kc_)
        return (C_new, n_new, m_end), h

    (C, n, m), hs = lax.scan(one_chunk, (C0, n0, m0), (qs, ks, vs, iis, ffs))
    # hs: (NC, B, nh, T, hd) -> (B, S, d_in)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, d_in).astype(dt)
    h = L.rmsnorm(h, bp["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", h, bp["w_down"].astype(dt))
    return x + out, (C, n, m, new_conv_buf)


def mlstm_seq(bp, cfg: ModelConfig, x, state=None):
    """x: (B, S, d). Returns (out (B, S, d), final state)."""
    B, S, d = x.shape
    if MLSTM_CHUNK and S % MLSTM_CHUNK == 0 and S > 1:
        return mlstm_chunked(bp, cfg, x, state, chunk=MLSTM_CHUNK)
    d_in = cfg.ssm_expand * d
    nh = cfg.num_heads
    hd = d_in // nh
    dt = x.dtype

    if state is None:
        state = mlstm_init_state(cfg, B)
    q, k, v, i_pre, f_pre, z, new_conv_buf, (C0, n0, m0) = _mlstm_inputs(
        bp, cfg, x, state
    )

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp  # (B,nh,hd)...(B,nh)
        m_new = jnp.maximum(ft + m, it)
        i_g = jnp.exp(it - m_new)[..., None]
        f_g = jnp.exp(ft + m - m_new)[..., None]
        kt32, vt32, qt32 = (a.astype(jnp.float32) for a in (kt, vt, qt))
        C = f_g[..., None] * C + i_g[..., None] * (
            vt32[..., :, None] * kt32[..., None, :]
        )
        n = f_g * n + i_g * kt32
        num = jnp.einsum("bhvk,bhk->bhv", C, qt32)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt32))[..., None], 1.0
        )
        h = (num / den).astype(dt)
        return (C, n, m_new), h

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_pre.transpose(1, 0, 2),
        f_pre.transpose(1, 0, 2),
    )
    (C, n, m), hs = lax.scan(step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_in)
    h = L.rmsnorm(h, bp["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", h, bp["w_down"].astype(dt))
    return x + out, (C, n, m, new_conv_buf)


def mlstm_init_state(cfg: ModelConfig, B: int):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.num_heads
    hd = d_in // nh
    return (
        jnp.zeros((B, nh, hd, hd), jnp.float32),
        jnp.zeros((B, nh, hd), jnp.float32),
        jnp.full((B, nh), -1e30, jnp.float32),
        jnp.zeros((B, cfg.ssm_conv - 1, d_in), jnp.float32),  # conv buffer
    )


def slstm_seq(bp, cfg: ModelConfig, x, state=None):
    B, S, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    dt = x.dtype
    xn = L.rmsnorm(x, bp["norm"], cfg.norm_eps)
    pre = {
        g: jnp.einsum("bsd,dhk->bshk", xn, bp[f"w_{g}"].astype(dt)).astype(
            jnp.float32
        )
        + bp[f"b_{g}"]
        for g in ("i", "f", "z", "o")
    }
    if state is None:
        state = slstm_init_state(cfg, B)

    def step(carry, inp):
        c, n, h, m = carry
        ip, fp, zp, op = inp  # (B, nh, hd)
        rec = {
            g: jnp.einsum("bhk,hkj->bhj", h, bp[f"r_{g}"]) for g in ("i", "f", "z", "o")
        }
        ip, fp, zp, op = (
            ip + rec["i"],
            fp + rec["f"],
            zp + rec["z"],
            op + rec["o"],
        )
        m_new = jnp.maximum(fp + m, ip)
        i_g = jnp.exp(ip - m_new)
        f_g = jnp.exp(fp + m - m_new)
        c = f_g * c + i_g * jnp.tanh(zp)
        n = f_g * n + i_g
        h = jax.nn.sigmoid(op) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    xs = tuple(pre[g].transpose(1, 0, 2, 3) for g in ("i", "f", "z", "o"))
    state, hs = lax.scan(step, state, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(dt)
    h = L.rmsnorm(h, bp["out_norm"], cfg.norm_eps)
    x = x + h
    up = jnp.einsum("bsd,dtf->bstf", h, bp["w_up"].astype(dt))
    y = jax.nn.gelu(up[..., 0, :]) * up[..., 1, :]
    return x + jnp.einsum("bsf,fd->bsd", y, bp["w_down"].astype(dt)), state


def slstm_init_state(cfg: ModelConfig, B: int):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((B, nh, hd), jnp.float32)
    return (z, z, z, jnp.full((B, nh, hd), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def _scan_groups(params, cfg: ModelConfig, x, states=None):
    """Scan over super-blocks of (slstm_every-1) mLSTM + 1 sLSTM."""
    B = x.shape[0]
    G = cfg.num_layers // cfg.slstm_every
    M = cfg.slstm_every - 1
    if states is None:
        m_state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (G, M) + a.shape),
            mlstm_init_state(cfg, B),
        )
        s_state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (G,) + a.shape), slstm_init_state(cfg, B)
        )
    else:
        m_state, s_state = states

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def group(x, inp):
        mp, sp, ms, ss = inp

        def mstep(x, minp):
            bp, st = minp
            x, st = mlstm_seq(bp, cfg, x, st)
            return x, st

        x, ms = lax.scan(mstep, x, (mp, ms))
        x, ss = slstm_seq(sp, cfg, x, ss)
        return x, (ms, ss)

    x, (m_state, s_state) = lax.scan(
        group, x, (params["mlstm"], params["slstm"], m_state, s_state)
    )
    return x, (m_state, s_state)


def forward(params, cfg: ModelConfig, batch, *, use_pallas: bool = False):
    x = L.embed_tokens(params["embed"], cfg, batch["tokens"])
    x, _ = _scan_groups(params, cfg, x)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.lm_head(params["embed"], cfg, x), {"aux_loss": jnp.float32(0.0)}


def loss_fn(params, cfg: ModelConfig, batch, *, use_pallas: bool = False):
    logits, _ = forward(params, cfg, batch)
    ce = L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    return ce, {"ce": ce, "aux_loss": jnp.float32(0.0)}


def prefill(params, cfg: ModelConfig, batch, cache_len: int = 0, *,
            use_pallas: bool = False):
    """Process a prompt; the recurrent states ARE the cache (O(1) size)."""
    tokens = batch["tokens"]
    x = L.embed_tokens(params["embed"], cfg, tokens)
    x, (m_state, s_state) = _scan_groups(params, cfg, x)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)[:, -1]
    cache = {"m": m_state, "s": s_state,
             "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """Recurrent state — O(1) in seq_len (the point of the ssm family)."""
    G = cfg.num_layers // cfg.slstm_every
    M = cfg.slstm_every - 1
    m_state = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (G, M) + a.shape),
        mlstm_init_state(cfg, batch),
    )
    s_state = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (G,) + a.shape), slstm_init_state(cfg, batch)
    )
    return {"m": m_state, "s": s_state, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cfg: ModelConfig, cache, tokens, *, use_pallas: bool = False):
    x = L.embed_tokens(params["embed"], cfg, tokens[:, None])  # (B,1,d)
    x, (m_state, s_state) = _scan_groups(
        params, cfg, x, states=(cache["m"], cache["s"])
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)[:, 0]
    return logits, {"m": m_state, "s": s_state, "pos": cache["pos"] + 1}
