"""Decoder/encoder transformer covering the dense, moe, audio and vlm
families (GQA, RoPE, qk-norm, QKV-bias, tied embeddings, MoE layers,
sliding-window attention, stub modality frontends).

Layer stacks are scanned over a leading layer axis. MoE configs with
``first_dense_layers`` keep a separate (small) stack for the leading dense
blocks, matching DeepSeekMoE / Kimi-K2.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_layer

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, moe: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": L.init_rmsnorm(cfg.d_model),
    }
    if moe:
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff)
    return p


def _stack(init_fn, key, n):
    ks = jax.random.split(key, n)
    return jax.vmap(init_fn)(ks)


def init(rng, cfg: ModelConfig) -> dict:
    k_embed, k_dense, k_moe, k_blocks = jax.random.split(rng, 4)
    params = {"embed": L.init_embed(k_embed, cfg), "final_norm": L.init_rmsnorm(cfg.d_model)}
    if cfg.num_experts:
        nd = cfg.first_dense_layers
        if nd:
            params["dense_blocks"] = _stack(
                partial(_init_block, cfg=cfg, moe=False), k_dense, nd
            )
        params["blocks"] = _stack(
            partial(_init_block, cfg=cfg, moe=True), k_moe, cfg.num_layers - nd
        )
    else:
        params["blocks"] = _stack(
            partial(_init_block, cfg=cfg, moe=False), k_blocks, cfg.num_layers
        )
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

# Activation-checkpoint policy for the layer scan. 'nothing_saveable' is the
# memory-min baseline (recompute everything); perf iteration 4 switches to
# 'dots_with_no_batch_dims_saveable' which keeps matmul outputs and avoids
# one full recompute pass (fewer FSDP weight re-gathers, useful_ratio -> 1).
REMAT_POLICY = jax.checkpoint_policies.nothing_saveable


def set_remat_policy(name: str) -> None:
    global REMAT_POLICY
    REMAT_POLICY = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "everything": jax.checkpoint_policies.everything_saveable,
    }[name]


def _block_fwd(x, bp, cfg: ModelConfig, positions, moe: bool, use_pallas: bool,
               dropless: bool = False):
    h = x + L.attention_block(
        L.rmsnorm(x, bp["attn_norm"], cfg.norm_eps), bp["attn"], cfg, positions,
        use_pallas=use_pallas,
    )
    hn = L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps)
    if moe:
        y, aux = moe_layer(hn, bp["moe"], cfg, dropless)
    else:
        y, aux = L.swiglu(hn, bp["mlp"]), jnp.float32(0.0)
    return h + y, aux


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Returns (x (B,S,d), loss_mask (B,S) or None)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.input_mode == "tokens":
        x = L.embed_tokens(params["embed"], cfg, batch["tokens"])
        mask = None
    elif cfg.input_mode == "embeddings":  # audio: precomputed frames (stub)
        x = batch["embeddings"].astype(dt)
        mask = None
    elif cfg.input_mode == "tokens+patches":  # vlm: patch embeds + text
        patches = batch["patches"].astype(dt) + params["embed"]["patch_pos"].astype(dt)
        text = L.embed_tokens(params["embed"], cfg, batch["tokens"])
        x = jnp.concatenate([patches, text], axis=1)
        B, P = patches.shape[:2]
        mask = jnp.concatenate(
            [jnp.zeros((B, P), bool), jnp.ones((B, text.shape[1]), bool)], axis=1
        )
    else:
        raise ValueError(cfg.input_mode)
    if cfg.meta_tokens:
        B = x.shape[0]
        meta = jnp.broadcast_to(
            params["embed"]["meta"].astype(dt), (B, cfg.meta_tokens, cfg.d_model)
        )
        x = jnp.concatenate([meta, x], axis=1)
        if mask is not None:
            mask = jnp.concatenate(
                [jnp.zeros((B, cfg.meta_tokens), bool), mask], axis=1
            )
    return x, mask


def forward(params, cfg: ModelConfig, batch, *, use_pallas: bool = False,
            train: bool = False):
    """-> (logits (B, S_total, V) f32, aux dict).

    ``train=True`` (the loss path) keeps MoE capacity dropping; serving /
    eval callers get the dropless dispatch so batched logits match
    token-by-token decode (see moe._capacity)."""
    x, mask = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux_total = jnp.float32(0.0)

    def scan_blocks(x, stack, moe):
        # remat each block: activation memory for 126-layer x 4k-seq configs
        # would otherwise be stored per scan iteration for the backward pass
        @partial(jax.checkpoint, policy=REMAT_POLICY)
        def step(carry, bp):
            x, aux = carry
            x, a = _block_fwd(x, bp, cfg, positions, moe, use_pallas,
                              dropless=not train)
            return (x, aux + a), None

        (x, aux), _ = lax.scan(step, (x, jnp.float32(0.0)), stack)
        return x, aux

    if "dense_blocks" in params:
        x, a = scan_blocks(x, params["dense_blocks"], moe=False)
        aux_total += a
    x, a = scan_blocks(x, params["blocks"], moe=cfg.num_experts > 0)
    aux_total += a
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)
    return logits, {"aux_loss": aux_total, "prefix_mask": mask}


def loss_fn(params, cfg: ModelConfig, batch, *, use_pallas: bool = False):
    """Causal-LM loss (next-token) or masked-prediction loss (encoder)."""
    logits, aux = forward(params, cfg, batch, use_pallas=use_pallas,
                          train=True)
    labels = batch["labels"]
    if cfg.is_encoder_only:
        # masked prediction at positions given by labels>=0 (hubert-style)
        ce = L.cross_entropy(logits, labels)
    else:
        # align: prefix tokens (patches/meta) carry no labels
        S_total = logits.shape[1]
        S_lab = labels.shape[1]
        pad = S_total - S_lab
        if pad:
            labels = jnp.concatenate(
                [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels], axis=1
            )
        ce = L.cross_entropy(logits[:, :-1], labels[:, 1:])
    total = ce + aux["aux_loss"]
    return total, {"ce": ce, "aux_loss": aux["aux_loss"]}


# ---------------------------------------------------------------------------
# prefill: forward pass that also builds the KV cache
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, batch, cache_len: int, *,
            use_pallas: bool = False):
    """Process a full prompt, returning (last-position logits, cache).

    The cache is laid out exactly as decode_step expects: full-length
    with pos = S for full-attention configs; rolling window-aligned for
    sliding-window configs (latest token in the last slot).
    """
    x, _ = _embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    positions = jnp.arange(S)
    W = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    dt = jnp.dtype(cfg.dtype)

    def place(kv):  # (B, S, nkv, hd) -> cache slab (B, W or cache_len, ...)
        if cfg.sliding_window:
            if S >= W:
                return kv[:, S - W:]
            return jnp.pad(kv, ((0, 0), (W - S, 0), (0, 0), (0, 0)))
        return jnp.pad(kv, ((0, 0), (0, cache_len - S), (0, 0), (0, 0)))

    def make_step(moe):
        def step(x, bp):
            hn = L.rmsnorm(x, bp["attn_norm"], cfg.norm_eps)
            a_out, k, v = L.attention_block_kv(
                hn, bp["attn"], cfg, positions, use_pallas
            )
            h = x + a_out
            hn2 = L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps)
            if moe:
                y, _ = moe_layer(hn2, bp["moe"], cfg, dropless=True)
            else:
                y = L.swiglu(hn2, bp["mlp"])
            return h + y, (place(k).astype(dt), place(v).astype(dt))

        return step

    nd = cfg.first_dense_layers if cfg.num_experts else 0
    ks, vs = [], []
    if nd:
        x, (kd, vd) = lax.scan(make_step(False), x, params["dense_blocks"])
        ks.append(kd)
        vs.append(vd)
    x, (km, vm) = lax.scan(make_step(cfg.num_experts > 0), x, params["blocks"])
    ks.append(km)
    vs.append(vm)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)[:, -1]
    cache = {
        "k": jnp.concatenate(ks, axis=0) if len(ks) > 1 else ks[0],
        "v": jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0],
        "pos": jnp.asarray(S, jnp.int32),
    }
    return logits, cache


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """KV cache. Sliding-window configs keep a rolling window-sized cache
    (O(window), not O(seq)) — this is what makes the sliding-window serve
    variant viable at 524k context."""
    dt = jnp.dtype(dtype or cfg.dtype)
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (cfg.num_layers, batch, S, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, *, use_pallas: bool = False):
    """One decode step. tokens: (B,) int32. Returns (logits (B,V), cache)."""
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], cfg, tokens[:, None])  # (B,1,d)

    nd = cfg.first_dense_layers if cfg.num_experts else 0

    def make_step(moe):
        def step(carry, inp):
            x = carry
            bp, kc, vc = inp
            hn = L.rmsnorm(x, bp["attn_norm"], cfg.norm_eps)
            if cfg.sliding_window and kc.shape[1] <= cfg.sliding_window:
                a_out, kc, vc = _window_attention_decode(
                    hn, bp["attn"], cfg, kc, vc, pos
                )
            else:
                a_out, kc, vc = L.attention_decode(hn, bp["attn"], cfg, kc, vc, pos)
            h = x + a_out
            hn2 = L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps)
            if moe:
                y, _ = moe_layer(hn2, bp["moe"], cfg, dropless=True)
            else:
                y = L.swiglu(hn2, bp["mlp"])
            return h + y, (kc, vc)

        return step

    k_all, v_all = cache["k"], cache["v"]
    new_k, new_v = [], []
    if nd:
        x, (kd, vd) = lax.scan(
            make_step(False), x, (params["dense_blocks"], k_all[:nd], v_all[:nd])
        )
        new_k.append(kd)
        new_v.append(vd)
    x, (km, vm) = lax.scan(
        make_step(cfg.num_experts > 0), x,
        (params["blocks"], k_all[nd:], v_all[nd:]),
    )
    new_k.append(km)
    new_v.append(vm)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x)[:, 0]
    cache = {
        "k": jnp.concatenate(new_k, axis=0) if len(new_k) > 1 else new_k[0],
        "v": jnp.concatenate(new_v, axis=0) if len(new_v) > 1 else new_v[0],
        "pos": pos + 1,
    }
    return logits, cache


def _window_attention_decode(x, p, cfg: ModelConfig, kc, vc, pos):
    """Rolling window-cache decode (shift left, append at the end).

    Keys are roped at their absolute positions when inserted, so the
    rolling buffer needs no re-rotation.
    """
    import math as _math

    q, k_new, v_new = L._qkv(x, p, cfg, pos[None] if pos.ndim == 0 else pos)
    kc = jnp.concatenate([kc[:, 1:], k_new.astype(kc.dtype)], axis=1)
    vc = jnp.concatenate([vc[:, 1:], v_new.astype(vc.dtype)], axis=1)
    W = kc.shape[1]
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kk = L._expand_kv(kc.astype(q.dtype), n_rep)
    vv = L._expand_kv(vc.astype(q.dtype), n_rep)
    s = jnp.einsum("bqhk,bshk->bhqs", q, kk).astype(jnp.float32)
    s = s / _math.sqrt(cfg.head_dim)
    win_pos = pos - W + 1 + jnp.arange(W)
    s = jnp.where((win_pos >= 0)[None, None, None, :], s, -1e30)
    prob = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", prob, vv)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, kc, vc
