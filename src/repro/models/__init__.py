from repro.models.api import (
    batch_shapes,
    decode_step,
    forward,
    get_model,
    init_cache,
    init_params,
    loss_fn,
    make_batch,
    prefill,
)
