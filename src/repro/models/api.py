"""Uniform model API: family registry + batch builders.

Every family module exposes:
  init(rng, cfg) -> params
  forward(params, cfg, batch, *, use_pallas=False) -> (logits, aux)
  loss_fn(params, cfg, batch, *, use_pallas=False) -> (loss, metrics)
  init_cache(cfg, batch, seq_len, dtype=None) -> cache
  decode_step(params, cfg, cache, tokens, *, use_pallas=False)
      -> (logits (B, V), cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import hymba, transformer, xlstm

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "audio": transformer,
    "vlm": transformer,
    "ssm": xlstm,
    "hybrid": hymba,
}


def get_model(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(rng, cfg: ModelConfig):
    return get_model(cfg).init(rng, cfg)


def loss_fn(params, cfg: ModelConfig, batch, *, use_pallas: bool = False):
    return get_model(cfg).loss_fn(params, cfg, batch, use_pallas=use_pallas)


def forward(params, cfg: ModelConfig, batch, *, use_pallas: bool = False):
    return get_model(cfg).forward(params, cfg, batch, use_pallas=use_pallas)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    return get_model(cfg).init_cache(cfg, batch, seq_len, dtype=dtype)


def decode_step(params, cfg: ModelConfig, cache, tokens, *, use_pallas=False):
    return get_model(cfg).decode_step(
        params, cfg, cache, tokens, use_pallas=use_pallas
    )


def prefill(params, cfg: ModelConfig, batch, cache_len: int, *,
            use_pallas=False):
    """Process a prompt batch -> (last-position logits, decode-ready cache)."""
    return get_model(cfg).prefill(
        params, cfg, batch, cache_len, use_pallas=use_pallas
    )


# ---------------------------------------------------------------------------
# batch construction (real arrays for smoke/train, ShapeDtypeStructs for
# dry-run lowering)
# ---------------------------------------------------------------------------


def batch_shapes(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Abstract shapes of one *training* batch for this config."""
    shapes = {}
    if cfg.input_mode == "tokens":
        shapes["tokens"] = ((batch, seq_len), jnp.int32)
        shapes["labels"] = ((batch, seq_len), jnp.int32)
    elif cfg.input_mode == "embeddings":
        # audio stub: precomputed frame embeddings from the (stubbed)
        # conv/mel frontend
        shapes["embeddings"] = ((batch, seq_len, cfg.d_model), jnp.float32)
        shapes["labels"] = ((batch, seq_len), jnp.int32)
    elif cfg.input_mode == "tokens+patches":
        # vlm stub: ViT/projector output patch embeddings + text tokens
        shapes["patches"] = ((batch, cfg.num_patches, cfg.d_model), jnp.float32)
        shapes["tokens"] = ((batch, seq_len), jnp.int32)
        shapes["labels"] = ((batch, seq_len), jnp.int32)
    else:
        raise ValueError(cfg.input_mode)
    return shapes


def make_batch(rng, cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Concrete synthetic batch (used by smoke tests and examples)."""
    out = {}
    ks = jax.random.split(rng, 4)
    for i, (name, (shape, dtype)) in enumerate(batch_shapes(cfg, batch, seq_len).items()):
        if dtype == jnp.int32:
            arr = jax.random.randint(ks[i % 4], shape, 0, cfg.vocab_size, jnp.int32)
        else:
            arr = jax.random.normal(ks[i % 4], shape, jnp.float32) * 0.02
        out[name] = arr
    if cfg.is_encoder_only:
        # hubert-style masked prediction: ~8% of positions carry labels
        mask = jax.random.bernoulli(ks[3], 0.08, out["labels"].shape)
        out["labels"] = jnp.where(mask, out["labels"], -1)
    return out
