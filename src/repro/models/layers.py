"""Shared neural-net building blocks (pure functional JAX).

Conventions
-----------
* Params are nested dicts of jnp arrays, stored in float32; forward passes
  cast to ``cfg.dtype`` (bf16 on TPU) and produce float32 logits.
* Attention projections are kept 3-D ``(d_model, heads, head_dim)`` so the
  sharding rules (repro/sharding) can put the tensor-parallel axis on the
  heads dim when divisible and fall back to the d_model dim otherwise
  (e.g. qwen2-7b's 28 heads on a 16-way model axis).
* Layer stacks are scanned (``lax.scan`` over a leading layer axis) to keep
  HLO size and compile time bounded for 126-layer configs.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, in_axis_size):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def init_attention(key, cfg: ModelConfig) -> dict:
    d, nq, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, nq, hd), d),
        "wk": _dense_init(ks[1], (d, nkv, hd), d),
        "wv": _dense_init(ks[2], (d, nkv, hd), d),
        "wo": _dense_init(ks[3], (nq, hd, d), nq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd), jnp.float32)
        p["bk"] = jnp.zeros((nkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((nkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wi": _dense_init(k1, (d_model, 2, d_ff), d_model),  # [gate, up]
        "wo": _dense_init(k2, (d_ff, d_model), d_ff),
    }


def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# core ops
# ---------------------------------------------------------------------------


def rmsnorm(x, p, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * lax.rsqrt(var + eps) * p["scale"]
    return out.astype(dt)


def _rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, p):
    h = jnp.einsum("...d,dtf->...tf", x, p["wi"].astype(x.dtype))
    gate, up = h[..., 0, :], h[..., 1, :]
    return jnp.einsum(
        "...f,fd->...d", jax.nn.silu(gate) * up, p["wo"].astype(x.dtype)
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _qkv(x, p, cfg: ModelConfig, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rmsnorm(q, {"scale": p["q_norm"]}, cfg.norm_eps)
        k = rmsnorm(k, {"scale": p["k_norm"]}, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_scores_block(q, k, v, scale, mask):
    """Plain attention on one (q-block, kv-block) pair; f32 softmax."""
    s = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", p, v)


def _expand_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def full_attention(q, k, v, *, causal, sliding_window=0, q_offset=0,
                   prefix_global=0):
    """Reference attention (materialises the score matrix). Use for S<=4k."""
    B, Sq, nq, hd = q.shape
    Sk = k.shape[1]
    n_rep = nq // k.shape[2]
    k, v = _expand_kv(k, n_rep), _expand_kv(v, n_rep)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if sliding_window:
        win = qpos[:, None] - kpos[None, :] < sliding_window
        if prefix_global:  # meta/global prefix tokens always attendable
            win |= kpos[None, :] < prefix_global
        mask &= win
    return attention_scores_block(q, k, v, 1.0 / math.sqrt(hd), mask[None, None])


def chunked_attention(
    q, k, v, *, causal, sliding_window=0, q_chunk=512, kv_chunk=1024,
    prefix_global=0,
):
    """Blockwise online-softmax attention in pure jnp (flash-style).

    This is the XLA path used for long sequences (and the oracle the Pallas
    kernel is validated against lives in kernels/flash_attention/ref.py and
    simply calls this). Memory is O(q_chunk * kv_chunk) per block instead of
    O(S^2).
    """
    B, S, nq, hd = q.shape
    n_rep = nq // k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    # largest chunk dividing S (prefix tokens can make S non-power-of-two,
    # e.g. 32768 text + 256 patches = 33024 -> chunk 256)
    q_chunk = math.gcd(min(q_chunk, S), S)
    kv_chunk = math.gcd(min(kv_chunk, S), S)
    nq_blocks, nkv_blocks = S // q_chunk, S // kv_chunk

    qb = q.reshape(B, nq_blocks, q_chunk, nq, hd)
    kb = k.reshape(B, nkv_blocks, kv_chunk, k.shape[2], hd)
    vb = v.reshape(B, nkv_blocks, kv_chunk, v.shape[2], hd)

    def q_block(qi, q_i):
        # online softmax over kv blocks
        acc0 = jnp.zeros((B, q_chunk, nq, hd), jnp.float32)
        m0 = jnp.full((B, nq, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, nq, q_chunk), jnp.float32)

        def kv_step(carry, inp):
            acc, m, l = carry
            ki, k_j, v_j = inp
            k_j = _expand_kv(k_j, n_rep)
            v_j = _expand_kv(v_j, n_rep)
            s = jnp.einsum("bqhk,bshk->bhqs", q_i, k_j).astype(jnp.float32)
            s = s * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if sliding_window:
                win = qpos[:, None] - kpos[None, :] < sliding_window
                if prefix_global:
                    win |= kpos[None, :] < prefix_global
                mask &= win
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhqs,bshk->bqhk", p.astype(q_i.dtype), v_j)
            acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + pv
            return (acc_new, m_new, l_new), None

        ks = jnp.arange(nkv_blocks)
        (acc, m, l), _ = lax.scan(
            kv_step, (acc0, m0, l0), (ks, kb.swapaxes(0, 1), vb.swapaxes(0, 1))
        )
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q.dtype)

    outs = lax.map(lambda args: q_block(*args), (jnp.arange(nq_blocks),
                                                 qb.swapaxes(0, 1)))
    # outs: (nq_blocks, B, q_chunk, nq, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, nq, hd)


# Sequences above this use blockwise online-softmax attention in jnp
# (never materialising the S x S score tensor at once). Perf iteration 3
# (EXPERIMENTS.md section Perf) tried lowering this to 2048 for train_4k
# and was REFUTED: the unfused jnp online-softmax touches each score
# block ~6x (XLA writes every intermediate), 2.5x more HBM traffic than
# the one-shot S^2 softmax. The true fix on TPU is the Pallas flash
# kernel (ops.flash_attention): one VMEM pass, HBM traffic = q+k+v+o.
# (env override kept for reproducing that measurement)
import os as _os

ATTN_CHUNK_THRESHOLD = int(_os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD", 8192))


def attention_block_kv(x, p, cfg: ModelConfig, positions, use_pallas=False):
    """Self-attention over a full sequence; also returns (k, v) for
    prefill cache construction."""
    q, k, v = _qkv(x, p, cfg, positions)
    S = x.shape[1]
    if use_pallas:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=cfg.causal, sliding_window=cfg.sliding_window
        )
    elif S > ATTN_CHUNK_THRESHOLD:
        out = chunked_attention(
            q, k, v, causal=cfg.causal, sliding_window=cfg.sliding_window
        )
    else:
        out = full_attention(
            q, k, v, causal=cfg.causal, sliding_window=cfg.sliding_window
        )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), k, v


def attention_block(x, p, cfg: ModelConfig, positions, use_pallas=False):
    """Self-attention over a full sequence (train / prefill)."""
    out, _, _ = attention_block_kv(x, p, cfg, positions, use_pallas)
    return out


def attention_decode(x, p, cfg: ModelConfig, k_cache, v_cache, pos):
    """One-token decode against a KV cache.

    x: (B, 1, d); k_cache/v_cache: (B, S, nkv, hd); pos: () current index.
    Returns (out (B,1,d), new_k_cache, new_v_cache).
    """
    q, k_new, v_new = _qkv(x, p, cfg, pos[None] if pos.ndim == 0 else pos)
    B = x.shape[0]
    k_cache = lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=1
    )
    v_cache = lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=1
    )
    S = k_cache.shape[1]
    nq, hd = cfg.num_heads, cfg.head_dim
    n_rep = nq // cfg.num_kv_heads
    kk = _expand_kv(k_cache.astype(q.dtype), n_rep)
    vv = _expand_kv(v_cache.astype(q.dtype), n_rep)
    s = jnp.einsum("bqhk,bshk->bhqs", q, kk).astype(jnp.float32)
    s = s / math.sqrt(hd)
    kpos = jnp.arange(S)
    valid = kpos <= pos
    if cfg.sliding_window:
        valid &= kpos > pos - cfg.sliding_window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    prob = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", prob, vv)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"embedding": jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(k2, (cfg.d_model, cfg.vocab_size), cfg.d_model)
    if cfg.meta_tokens:
        p["meta"] = jax.random.normal(
            jax.random.fold_in(key, 7), (cfg.meta_tokens, cfg.d_model)
        ) * 0.02
    if cfg.input_mode == "tokens+patches":
        # projector stub is identity-shaped; learnable patch positional bias
        p["patch_pos"] = jnp.zeros((cfg.num_patches, cfg.d_model), jnp.float32)
    return p


def embed_tokens(p, cfg: ModelConfig, tokens):
    return p["embedding"].astype(jnp.dtype(cfg.dtype))[tokens]


def lm_head(p, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        w = p["embedding"].T
    else:
        w = p["head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype)).astype(jnp.float32)


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy. labels: int32, -1 entries ignored."""
    valid = labels >= 0
    if mask is not None:
        valid &= mask
    labels_c = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)
