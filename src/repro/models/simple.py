"""Small models for the paper-faithful convergence experiments.

The paper's section IV trains 7 CNN families on CIFAR-10. On this CPU-only
container we reproduce the *claims* (momentum accelerates K-AVG; optimal mu
grows with P; optimal K > 1) with the same optimizer code on CPU-feasible
models: an MLP, a small CNN (the CIFAR-10 stand-in) and the tiny
transformer from the assigned pool. Batches are {'x': features, 'y': int
labels} from the teacher stream in repro/data/synthetic.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import _dense_init, cross_entropy

# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(rng, d_in: int, hidden: int, classes: int, depth: int = 2):
    ks = jax.random.split(rng, depth + 1)
    params = {"in": _dense_init(ks[0], (d_in, hidden), d_in)}
    for i in range(depth - 1):
        params[f"h{i}"] = _dense_init(ks[i + 1], (hidden, hidden), hidden)
    params["out"] = _dense_init(ks[-1], (hidden, classes), hidden)
    params["b_out"] = jnp.zeros((classes,))
    return params


def mlp_forward(params, x):
    h = jnp.tanh(x @ params["in"])
    i = 0
    while f"h{i}" in params:
        h = jnp.tanh(h @ params[f"h{i}"])
        i += 1
    return h @ params["out"] + params["b_out"]


def mlp_loss(params, batch):
    logits = mlp_forward(params, batch["x"])
    loss = cross_entropy(logits, batch["y"])
    return loss, {"logits": logits}


def mlp_accuracy(params, batch):
    logits = mlp_forward(params, batch["x"])
    return jnp.mean(jnp.argmax(logits, -1) == batch["y"])


# ---------------------------------------------------------------------------
# small CNN (CIFAR-shaped stand-in; batch['x'] is (B, H, W, C))
# ---------------------------------------------------------------------------


def cnn_init(rng, hw: int = 16, channels: int = 3, width: int = 16,
             classes: int = 10):
    ks = jax.random.split(rng, 4)
    flat = (hw // 4) * (hw // 4) * (2 * width)
    return {
        "c1": _dense_init(ks[0], (3, 3, channels, width), 9 * channels),
        "c2": _dense_init(ks[1], (3, 3, width, 2 * width), 9 * width),
        "out": _dense_init(ks[2], (flat, classes), flat),
        "b_out": jnp.zeros((classes,)),
    }


def _conv(x, w):
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _pool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(params, x):
    h = jax.nn.relu(_conv(x, params["c1"]))
    h = _pool2(h)
    h = jax.nn.relu(_conv(h, params["c2"]))
    h = _pool2(h)
    h = h.reshape(h.shape[0], -1)
    return h @ params["out"] + params["b_out"]


def cnn_loss(params, batch):
    logits = cnn_forward(params, batch["x"])
    return cross_entropy(logits, batch["y"]), {}


def cnn_accuracy(params, batch):
    logits = cnn_forward(params, batch["x"])
    return jnp.mean(jnp.argmax(logits, -1) == batch["y"])
