"""Learner-level optimizers (the paper's inner loop uses plain SGD;
``mavg_mlocal`` — the paper's section-V future-work variant — uses MSGD)."""
from __future__ import annotations

import jax

from repro.utils import tree_axpy, tree_zeros_like


def sgd_apply(params, grads, lr):
    """w <- w - lr * g (Algorithm 1 learner update)."""
    return tree_axpy(-lr, grads, params)


def msgd_init(params):
    return tree_zeros_like(params)


def msgd_apply(params, momentum, grads, lr, mu):
    """Heavy-ball: m <- mu m - lr g; w <- w + m."""
    momentum = jax.tree.map(lambda m, g: mu * m - lr * g, momentum, grads)
    params = jax.tree.map(lambda w, m: w + m, params, momentum)
    return params, momentum
