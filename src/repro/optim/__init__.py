from repro.optim.sgd import sgd_apply, msgd_apply, msgd_init
from repro.optim.schedules import constant, cosine, warmup_cosine
