"""Step-size schedules for the learner lr (gamma_n in the paper)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.float32(lr)


def cosine(lr, total_steps, final_frac=0.1):
    def f(step):
        t = jnp.minimum(step / max(1, total_steps), 1.0)
        return jnp.float32(lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))))

    return f


def warmup_cosine(lr, warmup_steps, total_steps, final_frac=0.1):
    cos = cosine(lr, total_steps, final_frac)

    def f(step):
        warm = lr * (step + 1) / max(1, warmup_steps)
        return jnp.where(step < warmup_steps, jnp.float32(warm), cos(step))

    return f
