"""Step-size schedules for the learner lr (gamma_n in the paper)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.float32(lr)


def cosine(lr, total_steps, final_frac=0.1):
    def f(step):
        t = jnp.minimum(step / max(1, total_steps), 1.0)
        return jnp.float32(lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))))

    return f


def warmup_cosine(lr, warmup_steps, total_steps, final_frac=0.1):
    """Linear warmup to ``lr`` over ``warmup_steps``, then cosine decay
    spanning the remaining ``total_steps - warmup_steps``.

    The cosine phase is re-based at the warmup end so the schedule is
    continuous at ``step == warmup_steps`` (decaying over ``total_steps``
    from step 0 dropped the lr abruptly at the boundary — a ~2% cliff at
    warmup=100/total=1000 that grows with the warmup fraction).
    """
    cos = cosine(lr, max(1, total_steps - warmup_steps), final_frac)

    def f(step):
        warm = lr * (step + 1) / max(1, warmup_steps)
        return jnp.where(
            step < warmup_steps, jnp.float32(warm), cos(step - warmup_steps)
        )

    return f
