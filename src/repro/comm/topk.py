"""TopKReducer: magnitude sparsification of displacements, optionally
composed with int8 quantization of the survivors (the int8_topk scheme).

Per learner and per leaf the largest-|.| k_frac fraction of displacement
entries is kept and the rest zeroed; with error feedback the zeroed mass
returns as residual next round, which is what makes aggressive k_frac
(default 10%) safe. Wire accounting per kept value: a 4-byte index plus
the value itself (4 bytes dense, 1 byte when int8-quantized) — so
int8_topk at k_frac=0.1 ships ~1/8 of dense.

Masked-then-quantized values stay exactly zero through the stochastic
rounding (floor(0/s + u) = 0 for u < 1), so the sparsity pattern survives
the wire.

On the packed flat meta-plane (repro.pack, DESIGN.md §9) the whole
displacement arrives as one leaf, so selection becomes whole-model-vector
top-k — the form the communication-efficient analyses state it in —
rather than per-leaf budgets: a layer with uniformly small displacements
may ship nothing while a hot layer ships more than k_frac of its own
entries (error feedback returns the skipped mass either way). Padding
slots are exact zeros and are never selected (the ``ab > 0`` guard), but
they do inflate ``k = round(k_frac * n)`` by the pad fraction —
negligible on the real configs, conservative (ships more) on tiny ones.
Packed-vs-per-leaf top-k parity is pinned at the trajectory level in
tests/test_pack.py and benchmarks/pack_bench.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm.quant import SCALE_BYTES, VALUE_BYTES, QuantReducer
from repro.comm.reducer import CompressedReducer
from repro.kernels import ops as kops

INDEX_BYTES = 4.0


class TopKReducer(CompressedReducer):
    def __init__(self, k_frac: float = 0.1, quant_dtype: str | None = None,
                 chunk_rows: int = 64, use_pallas: bool = False, seed: int = 0):
        assert 0.0 < k_frac <= 1.0, k_frac
        self.k_frac = k_frac
        self.quant = (
            QuantReducer(dtype=quant_dtype, chunk_rows=chunk_rows,
                         use_pallas=use_pallas, seed=seed)
            if quant_dtype else None
        )
        self.name = f"{quant_dtype}_topk" if quant_dtype else "topk"

    def _compress(self, delta, step):
        leaves, treedef = jax.tree_util.tree_flatten(delta)
        out, wire = [], 0.0
        for i, leaf in enumerate(leaves):
            L = leaf.shape[0]
            flat = leaf.reshape(L, -1)
            n = flat.shape[1]
            k = max(1, int(round(self.k_frac * n)))
            ab = jnp.abs(flat)
            thresh = lax.top_k(ab, k)[0][:, -1:]
            # `ab > 0` guards the all-ties-at-zero case: a mostly-zero leaf
            # has thresh == 0 and `>= thresh` alone would keep everything,
            # breaking the <= k-per-learner wire accounting
            c = jnp.where((ab >= thresh) & (ab > 0), flat, 0.0).reshape(leaf.shape)
            vb = VALUE_BYTES[self.quant.dtype] if self.quant else 4.0
            wire += L * k * (vb + INDEX_BYTES)
            if self.quant:
                c, nchunks = kops.quant_dequant(
                    c, self.quant._leaf_key(i, step), dtype=self.quant.dtype,
                    block=self.quant.chunk_rows,
                    use_pallas=self.quant.use_pallas,
                )
                wire += nchunks * SCALE_BYTES
            out.append(c)
        return jax.tree_util.tree_unflatten(treedef, out), wire
