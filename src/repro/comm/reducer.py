"""The Reducer protocol: how the meta average crosses the wire.

The paper's communication model is one collective per K local steps; this
subsystem makes that collective an explicit, swappable object so its cost
can be modeled (bytes-on-wire metrics), measured (benchmarks/comm_bench),
and reduced (quantization / sparsification with error feedback).

    reduce(learners, gp, residual, step=n) -> (avg, residual', metrics)

``learners`` is the stacked (L, ...) learner pytree, ``gp`` the meta
params w~. Compressed reducers operate on the *displacements*
delta_j = w_j - w~ (small, zero-centred — far friendlier to 8-bit scales
than raw weights) and return avg = w~ + mean_j C(delta_j). ``residual``
is the per-learner error-feedback memory e_j carried in
``MetaState.comm_residual`` (None when EF is off); the EF invariant
(DESIGN.md §5) is

    delta_j + e_j = C(delta_j + e_j) + e'_j      (exactly, per leaf)

so compression error is re-injected next round and the block-momentum
update stays unbiased (Yu, Jin & Yang 2019, PAPERS.md).

Every reducer reports ``comm_bytes`` (modeled wire payload this step),
``comm_bytes_dense`` (what the dense scheme would ship) and
``comm_compression``; bytes are analytic — under SPMD simulation nothing
is physically serialized, but the *numerics* of compression are real
(values really are rounded to the wire grid / zeroed by top-k).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import (
    tree_add,
    tree_cast,
    tree_mean_axis0,
    tree_norm,
    tree_size,
    tree_sub,
)


def dense_bytes(learners) -> float:
    """Wire payload of the uncompressed meta average: every learner ships
    its full displacement at the learner dtype width."""
    return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(learners)))


class Reducer:
    """Base: reduce the learner stack to one averaged parameter tree."""

    name = "reducer"
    # robust aggregation hook (repro.robust, DESIGN.md §14): a callable
    # replacing the trusting learner-stack mean (trimmed mean / median
    # over the L axis). None — the default, and the only value when
    # MAvgConfig.robust is off — keeps the exact mean code path.
    aggregate = None

    def init_residual(self, gp, num_learners: int):
        """Error-feedback state for MetaState.comm_residual (None = off)."""
        return None

    def reduce(self, learners, gp, residual, *, step) -> tuple[Any, Any, dict]:
        raise NotImplementedError


class DenseReducer(Reducer):
    """Today's exact behavior, extracted: a = mean_j w_j, full precision."""

    name = "dense"

    def __init__(self, meta_dtype: str = "float32"):
        self.meta_dtype = meta_dtype

    def reduce(self, learners, gp, residual, *, step):
        if self.aggregate is not None:
            avg = tree_cast(self.aggregate(learners), self.meta_dtype)
        else:
            avg = tree_cast(tree_mean_axis0(learners), self.meta_dtype)
        b = dense_bytes(learners)
        metrics = {
            "comm_bytes": b,
            "comm_bytes_dense": b,
            "comm_compression": 1.0,
        }
        return avg, residual, metrics


class CompressedReducer(Reducer):
    """Shared displacement/EF plumbing; subclasses supply ``_compress``."""

    def _compress(self, delta, step) -> tuple[Any, float]:
        """delta: (L, ...) f32 pytree -> (decompressed C(delta), wire bytes)."""
        raise NotImplementedError

    def _compress_residual(self, delta, step) -> tuple[Any, Any, float]:
        """``_compress`` plus the compression error err = delta - C(delta)
        of the same pass: (c, err, wire bytes).

        The error-feedback compress-only route (gossip neighbor exchange,
        masked hierarchical inner — topology.gossip.compress_stack) needs
        err as the next residual; deriving it here lets reducers whose
        kernel already computed delta - c in-register (QuantReducer on
        the packed plane, kernels/pack_update.py) hand it over without a
        second full-plane subtraction pass. The default is the two-pass
        fallback and is bitwise-identical to it by contract.
        """
        c, wire = self._compress(delta, step)
        return c, tree_sub(delta, c), wire

    def reduce(self, learners, gp, residual, *, step):
        delta = jax.tree.map(
            lambda w, g: w.astype(jnp.float32) - g.astype(jnp.float32)[None],
            learners, gp,
        )
        if residual is not None:
            delta = tree_add(delta, residual)
        c, wire = self._compress(delta, step)
        err = tree_sub(delta, c)  # quantization error: EF residual + metric
        new_residual = err if residual is not None else None
        if self.aggregate is not None:
            avg = tree_add(tree_cast(gp, jnp.float32), self.aggregate(c))
        else:
            avg = jax.tree.map(
                lambda g, ci: (g.astype(jnp.float32) + jnp.mean(ci, axis=0)),
                gp, c,
            )
        db = dense_bytes(learners)
        metrics = {
            "comm_bytes": wire,
            "comm_bytes_dense": db,
            "comm_compression": db / wire,
            "comm_error_norm": tree_norm(err),
        }
        return avg, new_residual, metrics


class ErrorFeedback(Reducer):
    """Wrapper carrying the compression residual e_j across meta steps.

    Supplies a non-None ``init_residual`` so ``MetaState.comm_residual``
    has a stable pytree structure from step 0 (jit/checkpoint friendly);
    the residual algebra itself lives in CompressedReducer.reduce, keyed
    on residual presence.
    """

    def __init__(self, inner: CompressedReducer):
        self.inner = inner

    @property
    def name(self):
        return f"ef+{self.inner.name}"

    def init_residual(self, gp, num_learners: int):
        return jax.tree.map(
            lambda x: jnp.zeros((num_learners,) + x.shape, jnp.float32), gp
        )

    def reduce(self, learners, gp, residual, *, step):
        if residual is None:
            raise ValueError(
                "ErrorFeedback.reduce got residual=None — the MetaState was "
                "built without this reducer's residual buffer. Pass the same "
                "reducer to init_state(params, cfg, reducer=...) that you "
                "inject into meta_step/make_meta_step."
            )
        return self.inner.reduce(learners, gp, residual, step=step)


def make_reducer(cfg, aggregate=None) -> Reducer:
    """Build the reducer described by ``cfg.comm`` (an MAvgConfig)."""
    return make_reducer_for(cfg.comm, meta_dtype=cfg.meta_dtype,
                            aggregate=aggregate)


def make_reducer_for(c, meta_dtype: str = "float32",
                     aggregate=None) -> Reducer:
    """Build a reducer from a bare ``CommConfig`` — the topology subsystem
    instantiates one per edge class (intra-group / cross-group / gossip
    neighbor), each with its own scheme. ``aggregate`` installs the
    robust aggregation hook (repro.robust) on the underlying reducer."""
    from repro.comm.quant import QuantReducer
    from repro.comm.topk import TopKReducer

    if c.scheme == "dense":
        r = DenseReducer(meta_dtype=meta_dtype)
        if aggregate is not None:
            r.aggregate = aggregate
        return r
    if c.scheme in ("int8", "fp8"):
        r = QuantReducer(dtype=c.scheme, chunk_rows=c.chunk_rows,
                         use_pallas=c.use_pallas, seed=c.seed)
    elif c.scheme == "topk":
        r = TopKReducer(k_frac=c.k_frac)
    elif c.scheme == "int8_topk":
        r = TopKReducer(k_frac=c.k_frac, quant_dtype="int8",
                        chunk_rows=c.chunk_rows, use_pallas=c.use_pallas,
                        seed=c.seed)
    else:
        raise ValueError(f"unknown comm scheme {c.scheme!r}")
    if aggregate is not None:
        r.aggregate = aggregate
    if c.error_feedback:
        return ErrorFeedback(r)
    return r


def uses_error_feedback(cfg) -> bool:
    """Does ``cfg`` (an MAvgConfig) carry an EF residual in
    ``MetaState.comm_residual``?

    The single source of truth for 'is comm_residual a pytree or None' —
    init_state and launch.specs.state_shardings must agree on it. Only
    the *flat* topology keeps its residual there; hierarchical/gossip
    carry theirs inside ``MetaState.topo`` (repro.topology owns the
    buffer layout), so comm_residual stays None for them.
    """
    from repro.configs.base import AVERAGING_ALGOS

    return (cfg.algorithm in AVERAGING_ALGOS
            and cfg.topology.kind == "flat"
            and cfg.comm.scheme != "dense" and cfg.comm.error_feedback)


def reducer_residual(params_or_gp, cfg):
    """comm_residual for init_state: None unless EF + a compressed scheme."""
    return make_reducer(cfg).init_residual(params_or_gp, cfg.num_learners)
