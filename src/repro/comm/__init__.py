# Pluggable compressed meta-communication: the Reducer protocol, its four
# implementations, and the factory keyed on MAvgConfig.comm (DESIGN.md §5).
from repro.comm.quant import QuantReducer
from repro.comm.reducer import (
    CompressedReducer,
    DenseReducer,
    ErrorFeedback,
    Reducer,
    dense_bytes,
    make_reducer,
    make_reducer_for,
    reducer_residual,
    uses_error_feedback,
)
from repro.comm.topk import TopKReducer

__all__ = [
    "CompressedReducer",
    "DenseReducer",
    "ErrorFeedback",
    "QuantReducer",
    "Reducer",
    "TopKReducer",
    "dense_bytes",
    "make_reducer",
    "make_reducer_for",
    "reducer_residual",
    "uses_error_feedback",
]
