"""QuantReducer: int8/fp8 displacement quantization with per-chunk scales.

Each learner's displacement leaf is flattened to the (rows, 128) wire
layout, split into chunk_rows x 128 chunks, and quantized against each
chunk's max-abs scale with unbiased stochastic rounding (the Pallas
kernels in kernels/quantize.py, or their jnp oracle). Wire accounting:
1 byte per value (int8/fp8) + 4 bytes per chunk scale — vs. 4 bytes per
value dense, i.e. ~3.9x before sparsification.

On the packed flat meta-plane (repro.pack — the learner stack arrives as
ONE (L, rows, 128) array) the int8/int4 reduce short-circuits into the
fused pack_update kernel: displacement + EF-residual add + quantize in a
single HBM pass instead of the generic path's three, with per-learner
scale chunks (DESIGN.md §9). Wire bytes are modeled over the plane's
element count here; core.meta.meta_step rescales every comm_bytes*
metric by the real-parameter fraction so padding never counts as
payload.

The dither stream is keyed on (seed, leaf index, meta step) so every
leaf/step draws independent uniforms while staying reproducible and
jit-stable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.reducer import CompressedReducer, dense_bytes
from repro.kernels import ops as kops
from repro.utils import tree_norm

VALUE_BYTES = {"int8": 1.0, "int4": 0.5, "fp8": 1.0}
SCALE_BYTES = 4.0
QMAX = {"int8": 127, "int4": 7}


class QuantReducer(CompressedReducer):
    def __init__(self, dtype: str = "int8", chunk_rows: int = 64,
                 use_pallas: bool = False, seed: int = 0):
        assert dtype in VALUE_BYTES, dtype
        self.dtype = dtype
        self.chunk_rows = chunk_rows
        self.use_pallas = use_pallas
        self.seed = seed
        self.name = dtype

    def _leaf_key(self, i, step):
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), i), step
        )

    def reduce(self, learners, gp, residual, *, step):
        # packed meta-plane fast path: the whole learner stack is one
        # (L, rows, 128) array — fuse delta/EF/quantize into one pass.
        # The shape check (not just the type) keeps bare-array param
        # pytrees on the generic per-leaf path.
        if (isinstance(learners, jax.Array) and learners.ndim == 3
                and learners.shape[-1] == 128 and self.dtype in QMAX):
            return self._reduce_packed(learners, gp, residual, step)
        return super().reduce(learners, gp, residual, step=step)

    def _reduce_packed(self, learners, gp, residual, step):
        L, rows, lanes = learners.shape
        u = jax.random.uniform(
            self._leaf_key(0, step), learners.shape, jnp.float32
        )
        c, err, scales = kops.pack_update(
            learners, gp, residual, u, qmax=QMAX[self.dtype],
            block=self.chunk_rows, use_pallas=self.use_pallas,
        )
        avg = gp.astype(jnp.float32) + jnp.mean(c, axis=0)
        wire = (learners.size * VALUE_BYTES[self.dtype]
                + scales.size * SCALE_BYTES)
        db = dense_bytes(learners)
        metrics = {
            "comm_bytes": wire,
            "comm_bytes_dense": db,
            "comm_compression": db / wire,
            "comm_error_norm": tree_norm(err),
        }
        return avg, (err if residual is not None else None), metrics

    def _is_packed(self, delta) -> bool:
        return (isinstance(delta, jax.Array) and delta.ndim == 3
                and delta.shape[-1] == 128 and self.dtype in QMAX)

    def _compress_packed(self, delta, step, with_err=True):
        """(c, err, wire) of the packed (L, rows, 128) displacement plane
        via the compress-only kernel (kernels/pack_update.pack_compress_3d):
        same chunk geometry and dither stream as _reduce_packed's fused
        pack_update, so the compress-only routes (gossip, masked
        hierarchical inner) stay bitwise consistent with the fused
        reduce — but without the zero-gp plane the old route synthesized
        just to subtract, one full-plane HBM read fewer per mix.
        ``with_err=False`` (the non-EF route) also drops the err-plane
        write — a pallas_call output cannot be DCE'd, so it must not
        exist when nobody keeps the residual."""
        u = jax.random.uniform(
            self._leaf_key(0, step), delta.shape, jnp.float32
        )
        c, err, scales = kops.pack_compress(
            delta, u, qmax=QMAX[self.dtype], block=self.chunk_rows,
            with_err=with_err, use_pallas=self.use_pallas,
        )
        wire = (delta.size * VALUE_BYTES[self.dtype]
                + scales.size * SCALE_BYTES)
        return c, err, wire

    def _compress(self, delta, step):
        if self._is_packed(delta):
            c, _err, wire = self._compress_packed(delta, step,
                                                  with_err=False)
            return c, wire
        leaves, treedef = jax.tree_util.tree_flatten(delta)
        out, wire = [], 0.0
        for i, leaf in enumerate(leaves):
            dq, nchunks = kops.quant_dequant(
                leaf, self._leaf_key(i, step), dtype=self.dtype,
                block=self.chunk_rows, use_pallas=self.use_pallas,
            )
            out.append(dq)
            wire += leaf.size * VALUE_BYTES[self.dtype] + nchunks * SCALE_BYTES
        return jax.tree_util.tree_unflatten(treedef, out), wire

    def _compress_residual(self, delta, step):
        # the packed kernel computed err = delta - c in the same pass;
        # hand it to the EF route instead of re-deriving it tree-wide
        if self._is_packed(delta):
            return self._compress_packed(delta, step)
        return super()._compress_residual(delta, step)
