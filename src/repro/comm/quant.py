"""QuantReducer: int8/fp8 displacement quantization with per-chunk scales.

Each learner's displacement leaf is flattened to the (rows, 128) wire
layout, split into chunk_rows x 128 chunks, and quantized against each
chunk's max-abs scale with unbiased stochastic rounding (the Pallas
kernels in kernels/quantize.py, or their jnp oracle). Wire accounting:
1 byte per value (int8/fp8) + 4 bytes per chunk scale — vs. 4 bytes per
value dense, i.e. ~3.9x before sparsification.

The dither stream is keyed on (seed, leaf index, meta step) so every
leaf/step draws independent uniforms while staying reproducible and
jit-stable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.reducer import CompressedReducer
from repro.kernels import ops as kops

VALUE_BYTES = {"int8": 1.0, "int4": 0.5, "fp8": 1.0}
SCALE_BYTES = 4.0


class QuantReducer(CompressedReducer):
    def __init__(self, dtype: str = "int8", chunk_rows: int = 64,
                 use_pallas: bool = False, seed: int = 0):
        assert dtype in VALUE_BYTES, dtype
        self.dtype = dtype
        self.chunk_rows = chunk_rows
        self.use_pallas = use_pallas
        self.seed = seed
        self.name = dtype

    def _leaf_key(self, i, step):
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), i), step
        )

    def _compress(self, delta, step):
        leaves, treedef = jax.tree_util.tree_flatten(delta)
        out, wire = [], 0.0
        for i, leaf in enumerate(leaves):
            dq, nchunks = kops.quant_dequant(
                leaf, self._leaf_key(i, step), dtype=self.dtype,
                block=self.chunk_rows, use_pallas=self.use_pallas,
            )
            out.append(dq)
            wire += leaf.size * VALUE_BYTES[self.dtype] + nchunks * SCALE_BYTES
        return jax.tree_util.tree_unflatten(treedef, out), wire
