"""The packed flat meta-plane: the whole parameter pytree as ONE
lane-aligned (rows, 128) buffer (DESIGN.md §9).

The paper's meta level treats the model as a single vector (Algorithm 1's
w~, v are vectors; the communication analyses of Yu, Jin & Yang 2019 and
Zhou & Cong 2017 are vector analyses). Per-leaf execution pays O(leaves)
where the math is O(1): every meta-plane op — block momentum, quantize,
neighbor mix, EF algebra — launched one kernel per pytree leaf and padded
each leaf to its own 8x128 tile. For the production configs (llama3-405b,
qwen1.5-110b: hundreds of leaves) that is hundreds of tiny launches per
meta step and up to 1023 wasted padded elements per leaf.

``PackSpec`` is the static layout, computed once from the param pytree:

  * every leaf occupies ``[offset, offset + size)`` of the flat vector,
    with ``offset`` a multiple of LANES=128 (lane-aligned: each leaf
    starts on a lane boundary, bounding per-leaf waste to < 128 elements
    instead of < 1024);
  * the total is padded once to ``rows * 128`` with ``rows % 8 == 0``
    (the sublane multiple every Pallas kernel in this repo assumes);
  * padding slots are ALWAYS ZERO — pack() writes zeros, and every meta
    op preserves them (elementwise updates of 0 by 0, quantize of 0 is 0,
    doubly-stochastic mixes of 0 are 0), so norms/means over the packed
    plane equal their per-leaf values exactly.

The spec is hashable and compares by value, so it can ride in
``MetaState.spec`` as a *static* pytree field: jit caches on it, state
pytrees from ``init_state`` / ``abstract_state`` / ``state_shardings``
match structurally, and ``meta_step`` can unpack at the learner boundary
without being handed the layout separately.

Stacked planes: a leading learner/group axis is just vmap —
``pack_stacked`` / ``unpack_stacked`` map the same layout over axis 0,
giving the (L, rows, 128) learner plane and (G, rows, 128) group planes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

LANES = 128
SUBLANES = 8


def _path_key(p) -> str:
    """Same key format as checkpoint/npz.py (slash-joined tree paths)."""
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _align(n: int, to: int) -> int:
    return -(-n // to) * to


@dataclass(frozen=True)
class PackSpec:
    """Static layout of one parameter pytree in the flat meta-plane.

    All fields are hashable (tuples / strings / a treedef), so the spec
    itself is a valid static jit argument and a valid static field of a
    registered dataclass pytree.
    """

    treedef: Any  # jax PyTreeDef of the parameter pytree
    paths: tuple  # slash-joined tree path per leaf (checkpoint keys)
    shapes: tuple  # original leaf shapes
    dtypes: tuple  # original leaf dtype names (round-trip restore)
    offsets: tuple  # lane-aligned start offset of each leaf
    sizes: tuple  # element count of each leaf
    rows: int  # buffer rows; rows % 8 == 0
    dtype: str  # buffer dtype name

    # ------------------------------------------------------------------
    @property
    def num_leaves(self) -> int:
        return len(self.sizes)

    @property
    def total(self) -> int:
        """Padded element count of the packed buffer."""
        return self.rows * LANES

    def plane_bytes(self, dtype=None) -> int:
        """Bytes of ONE (rows, 128) plane at ``dtype`` (default: the
        buffer dtype) — the unit the meta-phase HBM budget model counts
        in (DESIGN.md §10; learner/group stacks are L or G planes)."""
        return self.total * jnp.dtype(
            self.dtype if dtype is None else dtype
        ).itemsize

    @property
    def pad_waste(self) -> int:
        """Padded-but-unused elements of the packed layout (alignment
        gaps between leaves + the single tail pad)."""
        return self.total - sum(self.sizes)

    def per_leaf_pad_waste(self) -> int:
        """Padded elements the legacy per-leaf (rows, 128) layout wastes:
        each leaf independently padded to an 8x128 tile multiple."""
        return sum(
            _align(_align(n, LANES) // LANES, SUBLANES) * LANES - n
            for n in self.sizes
        )

    # ------------------------------------------------------------------
    def pack(self, tree, dtype=None):
        """tree -> (rows, 128) buffer in ``dtype`` (default: spec dtype).

        Leaves are cast to the buffer dtype; alignment gaps and the tail
        pad are written as zeros (the padding invariant every packed op
        relies on).
        """
        dt = jnp.dtype(self.dtype if dtype is None else dtype)
        leaves = self.treedef.flatten_up_to(tree)
        parts = []
        end = 0
        for leaf, off, size in zip(leaves, self.offsets, self.sizes):
            if off > end:  # alignment gap before this leaf
                parts.append(jnp.zeros((off - end,), dt))
            parts.append(jnp.asarray(leaf).reshape(-1).astype(dt))
            end = off + size
        if self.total > end:
            parts.append(jnp.zeros((self.total - end,), dt))
        return jnp.concatenate(parts).reshape(self.rows, LANES)

    def unpack(self, buf, dtype=None):
        """(rows, 128) buffer -> tree.

        ``dtype=None`` restores each leaf's recorded dtype (bit-exact
        round trip for f32/bf16 params through an f32 buffer);
        ``dtype=...`` casts every leaf to that dtype instead (the learner
        boundary keeps leaves in the buffer's compute dtype).
        """
        flat = buf.reshape(-1)
        leaves = [
            flat[off:off + size].reshape(shape).astype(
                dt if dtype is None else dtype
            )
            for off, size, shape, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes
            )
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- stacked planes: leading learner/group axes are vmapped layout --
    def pack_stacked(self, tree, dtype=None):
        """(lead, ...) leaves -> (lead, rows, 128); any single lead axis."""
        return jax.vmap(lambda t: self.pack(t, dtype))(tree)

    def unpack_stacked(self, buf, dtype=None):
        """(lead, rows, 128) -> tree of (lead, ...) leaves."""
        return jax.vmap(lambda b: self.unpack(b, dtype))(buf)

    # ------------------------------------------------------------------
    def pack_numpy(self, leaves, dtype=None) -> np.ndarray:
        """Host-side pack of numpy leaves (checkpoint legacy load): the
        leaves may carry any shared leading stack axes (L / G / tau)
        before each recorded leaf shape."""
        dt = np.dtype(self.dtype if dtype is None else dtype)
        lead = tuple(leaves[0].shape[:leaves[0].ndim - len(self.shapes[0])])
        buf = np.zeros(lead + (self.total,), dt)
        for arr, off, size, shape in zip(
            leaves, self.offsets, self.sizes, self.shapes
        ):
            assert tuple(arr.shape) == lead + tuple(shape), (
                arr.shape, lead, shape
            )
            buf[..., off:off + size] = arr.reshape(lead + (-1,))
        return buf.reshape(lead + (self.rows, LANES))

    def layout_dict(self) -> dict:
        """JSON-able layout (saved alongside packed checkpoints so a
        packed .npz can be decoded without re-deriving the spec)."""
        return {
            "paths": list(self.paths),
            "shapes": [list(s) for s in self.shapes],
            "dtypes": list(self.dtypes),
            "offsets": list(self.offsets),
            "sizes": list(self.sizes),
            "rows": self.rows,
            "dtype": self.dtype,
        }


def make_pack_spec(tree, dtype=None) -> PackSpec:
    """Compute the lane-aligned flat layout of ``tree`` once.

    ``dtype``: buffer dtype (default: the jnp result type of all leaf
    dtypes — f32 for f32/bf16 param trees, keeping every leaf's pack ->
    unpack round trip bit-exact).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = tuple("/".join(_path_key(p) for p in path) for path, _ in flat)
    leaves = [leaf for _, leaf in flat]
    shapes = tuple(tuple(x.shape) for x in leaves)
    dtypes = tuple(jnp.dtype(x.dtype).name for x in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1 for s in shapes)
    offsets, off = [], 0
    for n in sizes:
        offsets.append(off)
        off = _align(off + n, LANES)
    rows = _align(_align(off, LANES) // LANES, SUBLANES)
    if dtype is None:
        dtype = jnp.result_type(*[jnp.dtype(d) for d in dtypes]).name
    return PackSpec(
        treedef=treedef, paths=paths, shapes=shapes, dtypes=dtypes,
        offsets=tuple(offsets), sizes=sizes, rows=max(rows, SUBLANES),
        dtype=jnp.dtype(dtype).name,
    )


def unpack_params(state):
    """Global params of a MetaState as the model pytree — identity on
    per-leaf (packed=False) states, spec.unpack on packed ones. The
    eval/serve boundary helper."""
    spec = getattr(state, "spec", None)
    if spec is None:
        return state.global_params
    return spec.unpack(state.global_params)
