from repro.checkpoint.npz import load_state, save_state
