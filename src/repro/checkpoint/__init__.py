from repro.checkpoint.npz import (
    CheckpointVerifyError,
    checkpoint_step,
    latest_checkpoint,
    latest_verified_checkpoint,
    load_packspec,
    load_state,
    prune_checkpoints,
    save_state,
    verified_checkpoints,
    verify_checkpoint,
)
