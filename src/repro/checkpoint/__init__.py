from repro.checkpoint.npz import (
    latest_checkpoint,
    load_packspec,
    load_state,
    save_state,
)
