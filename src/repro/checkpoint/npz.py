"""Flat-npz pytree checkpointer (no orbax dependency).

Saves the full MetaState — global params, block momentum, learner copies,
the comm error-feedback residual and the topology buffers (group params /
momentum under hierarchical, per-learner params / momentum / residual
under gossip, riding in ``MetaState.topo`` as a dict pytree) — so a
resumed run is bit-identical (tested in tests/test_checkpoint.py and
tests/test_topology.py). Keys are slash-joined tree paths; optional
fields that are None contribute no leaves, so the layout only changes
when a feature is on.

Packed meta-plane states (``MetaState.spec`` set — repro.pack, DESIGN.md
§9) save each plane as its single (rows, 128) / (lead, rows, 128) buffer
under the plain field key, plus a ``__packspec__`` JSON sidecar entry
recording the leaf layout (paths / shapes / dtypes / offsets), so a
packed .npz is decodable without re-deriving the spec from code. Loading
is layout-converting in the legacy direction: a per-leaf checkpoint
restores into a packed template by packing each plane's leaves through
the template's spec (same leading stack axes: L / G / tau), so pre-pack
runs resume bit-identically on the packed path.
"""
from __future__ import annotations

import io
import json
import os
import zlib

import jax
import jax.numpy as jnp
import numpy as np

# the slash-joined key format is shared with PackSpec.paths — the legacy
# per-leaf restore matches spec paths against npz keys, so both sides
# must use the same helper
from repro.pack import _path_key
from repro.utils.retry import retry_io

PACKSPEC_KEY = "__packspec__"

# per-snapshot integrity sidecar: ``step_<n>.npz.crc32.json`` records the
# byte size of the npz and a CRC32 + shape/dtype per entry, written
# atomically AFTER the npz itself — a snapshot without a (matching)
# sidecar is by definition unverified (torn mid-save)
CRC_SUFFIX = ".crc32.json"


class CheckpointVerifyError(RuntimeError):
    """A snapshot failed integrity verification (torn write, bit rot,
    entry-set mismatch, or — with ``check_finite`` — a poisoned state).
    ``latest_verified_checkpoint`` skips such snapshots; the Supervisor
    (core/supervisor.py) treats one raised at restore time like a
    ``HealthHalt`` and rolls back further."""


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat["/".join(_path_key(p) for p in path)] = np.asarray(leaf)
    return flat


def _atomic_write(path: str, data: bytes) -> None:
    """tmp + flush + fsync + rename — a reader never observes a partial
    file at ``path``; transient OSErrors get the shared bounded retry."""

    def write():
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    retry_io(write)


def _entry_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save_state(directory: str, state, step: int, manifest=None, *,
               keep: int = 0, fault=None) -> str:
    """Snapshot ``state`` to ``directory/step_<step>.npz``, atomically and
    with a CRC32 integrity sidecar.

    The write order is the crash-safety contract: (1) the whole npz is
    serialized in memory and landed via tmp + fsync + rename, (2) the
    sidecar (``<path>.crc32.json``: npz byte size + per-entry CRC32 /
    shape / dtype) lands the same way, (3) the directory ``manifest.json``
    is rewritten, also atomically. A crash between any two leaves either
    no new snapshot or an npz without a sidecar — both of which
    ``latest_verified_checkpoint`` skips; it can never leave a snapshot
    that verifies but restores garbage.

    ``manifest`` (optional): a ``repro.obs.run_manifest`` dict written to
    ``directory/manifest.json`` alongside the snapshots, so a checkpoint
    directory is self-describing — the config / topology / packspec-hash
    needed to resume it travels with it (DESIGN.md §11). Rewritten on
    every save (cheap, and a resumed run refreshes the environment info).

    ``keep``: retention — after a successful save, prune snapshots older
    than the ``keep`` newest sidecar-complete ones (0 = keep everything).
    The survivors are the rollback chain the Supervisor walks.

    ``fault``: chaos injection hook (repro.chaos, test/bench only):
    ``"torn"`` writes a truncated npz at the final path with NO sidecar —
    the pre-atomic failure mode (or a disk-level tear) the verified chain
    exists to survive; ``"corrupt"`` completes the full atomic save and
    then flips one byte of the final npz in place (post-write media rot,
    caught by the CRC sidecar). ``None`` (the default) is the only
    production value.

    Host-sync discipline: one ``jax.block_until_ready`` on the whole
    state up front, then the per-leaf ``np.asarray`` fetches are plain
    device->host copies of already-finished buffers. Without it the
    first ``np.asarray`` mid-run blocked the host on whatever compute
    was still enqueued leaf by leaf, serializing dispatch at every save
    cadence (the same lesson as Trainer.run's metric flushing).

    Donation contract (``MAvgConfig.donate``, DESIGN.md §10): pass the
    state a step RETURNED, never one you later feed to a donated step —
    a donated input's buffers are dead after dispatch and the fetch here
    would raise. The Trainer saves ``self.state`` immediately after
    rebinding it to the step's return value, which is the pattern to
    copy.
    """
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    flat = _flatten(jax.block_until_ready(state))
    spec = getattr(state, "spec", None)
    if spec is not None:
        flat[PACKSPEC_KEY] = np.asarray(json.dumps(spec.layout_dict()))
    buf = io.BytesIO()
    np.savez(buf, **flat)
    data = buf.getvalue()
    if fault == "torn":
        # simulated mid-save crash: half the bytes at the FINAL path, no
        # sidecar — exactly what the old non-atomic np.savez left behind
        with open(path, "wb") as f:
            f.write(data[: len(data) // 2])
        return path
    sidecar = {
        "step": int(step),
        "npz_bytes": len(data),
        "entries": {
            k: {
                "crc32": _entry_crc(v),
                "shape": list(np.shape(v)),
                "dtype": str(np.asarray(v).dtype),
            }
            for k, v in flat.items()
        },
    }
    _atomic_write(path, data)
    _atomic_write(path + CRC_SUFFIX,
                  json.dumps(sidecar, sort_keys=True).encode())
    if fault == "corrupt":
        with open(path, "r+b") as f:
            f.seek(len(data) // 2)
            b = f.read(1)
            f.seek(len(data) // 2)
            f.write(bytes([b[0] ^ 0x10]))
    if manifest is not None:
        _atomic_write(
            os.path.join(directory, "manifest.json"),
            json.dumps(manifest, indent=2, sort_keys=True,
                       default=str).encode(),
        )
    if keep:
        prune_checkpoints(directory, keep)
    return path


def _sidecar_ok(path: str) -> bool:
    """Cheap (no-read-of-the-npz) verification: the sidecar exists, parses,
    and records the npz's actual byte size — enough to distinguish a
    completed atomic save from a torn one without paying a full CRC pass
    (retention uses this; resume uses the full ``verify_checkpoint``)."""
    try:
        with open(path + CRC_SUFFIX) as f:
            sc = json.load(f)
        return sc.get("npz_bytes") == os.path.getsize(path)
    except (OSError, ValueError):
        return False


def prune_checkpoints(directory: str, keep: int) -> list[str]:
    """Delete snapshots older than the ``keep`` newest sidecar-complete
    ones (their sidecars too, and any older torn/unverified leftovers —
    useless for rollback). Returns the removed npz paths.

    Removal order is sidecar FIRST, npz second: if the pair's deletion is
    interrupted between the two unlinks, what survives is an npz with no
    sidecar — indistinguishable from a torn save, skipped by rollback and
    swept by the next prune. The opposite order would strand an orphaned
    ``.crc32.json`` that nothing ever lists (retention iterates the npz
    files); any such pre-existing orphans are swept here too.
    """
    assert keep >= 1, keep
    if not os.path.isdir(directory):
        return []
    names = os.listdir(directory)
    snaps = sorted(
        f for f in names
        if f.endswith(".npz") and not f.endswith(".npz.tmp")
    )
    removed = []
    # sweep sidecars whose snapshot is already gone (stranded by an
    # interrupted delete under the old npz-first order, or by an external
    # partial cleanup) — harmless to rollback but they accumulate forever
    for f in names:
        if not f.endswith(CRC_SUFFIX):
            continue
        if f[: -len(CRC_SUFFIX)] not in snaps:
            try:
                os.remove(os.path.join(directory, f))
            except OSError:
                pass
    verified = [f for f in snaps if _sidecar_ok(os.path.join(directory, f))]
    if len(verified) <= keep:
        return []
    cutoff = verified[-keep]
    for f in snaps:
        if f >= cutoff:
            continue
        p = os.path.join(directory, f)
        try:
            if os.path.exists(p + CRC_SUFFIX):
                os.remove(p + CRC_SUFFIX)
            os.remove(p)
            removed.append(p)
        except OSError:
            pass  # retention is best-effort; verify guards correctness
    return removed


def verify_checkpoint(path: str, *, check_finite: bool = True) -> None:
    """Raise ``CheckpointVerifyError`` unless ``path`` is a complete,
    uncorrupted snapshot: sidecar present and parseable, npz size and
    entry set match it, every entry's CRC32 matches, and (with
    ``check_finite``) no float entry carries NaN/Inf — a snapshot of a
    poisoned state is not a rollback target (semantic verification, the
    "NaN never re-enters MetaState via resume" half of the chaos
    contract)."""
    try:
        size = os.path.getsize(path)
    except OSError as e:
        raise CheckpointVerifyError(f"{path}: unreadable ({e})")
    try:
        with open(path + CRC_SUFFIX) as f:
            sidecar = json.load(f)
    except OSError:
        raise CheckpointVerifyError(
            f"{path}: no {CRC_SUFFIX} sidecar (save died before the "
            f"sidecar landed, or a pre-integrity-chain snapshot)"
        )
    except ValueError as e:
        raise CheckpointVerifyError(f"{path}: torn sidecar ({e})")
    entries = sidecar.get("entries")
    if not isinstance(entries, dict):
        raise CheckpointVerifyError(f"{path}: sidecar has no entry table")
    if sidecar.get("npz_bytes") != size:
        raise CheckpointVerifyError(
            f"{path}: size {size} != sidecar npz_bytes "
            f"{sidecar.get('npz_bytes')} (torn write)"
        )
    try:
        with np.load(path) as data:
            keys, want = set(data.files), set(entries)
            if keys != want:
                raise CheckpointVerifyError(
                    f"{path}: entry set mismatch vs sidecar (missing "
                    f"{sorted(want - keys)[:4]}, extra "
                    f"{sorted(keys - want)[:4]})"
                )
            for k, meta in entries.items():
                arr = np.asarray(data[k])
                if _entry_crc(arr) != meta.get("crc32"):
                    raise CheckpointVerifyError(
                        f"{path}: CRC32 mismatch on entry {k!r} (bit rot "
                        f"or in-place corruption)"
                    )
                if check_finite:
                    try:
                        finite = bool(np.isfinite(arr).all())
                    except TypeError:
                        finite = True  # non-float / exotic dtypes
                    if not finite:
                        raise CheckpointVerifyError(
                            f"{path}: non-finite values in entry {k!r} — "
                            f"a poisoned snapshot is not a rollback target"
                        )
    except CheckpointVerifyError:
        raise
    except Exception as e:  # zip/zlib/np errors on a damaged archive
        raise CheckpointVerifyError(f"{path}: unreadable npz ({e})")


def checkpoint_step(path: str) -> int:
    """Step encoded in a ``step_<n>.npz`` checkpoint filename."""
    name = os.path.basename(path)
    assert name.startswith("step_") and name.endswith(".npz"), path
    return int(name[len("step_"): -len(".npz")])


def verified_checkpoints(directory: str, *, before_step=None,
                         check_finite: bool = True) -> list[str]:
    """Ascending list of the snapshots in ``directory`` that pass
    ``verify_checkpoint`` — the rollback chain the Supervisor walks.

    ``before_step`` keeps only snapshots whose encoded step is strictly
    below it. The Supervisor needs this because verification is
    necessary but not sufficient for a rollback target: the emergency
    halt snapshot of a *diverged-but-finite* state (a mis-scaled payload
    blows the params up without ever minting a NaN) verifies cleanly,
    and resuming from it replays the sick state forever. Integrity says
    "this is exactly what was saved"; only causality — strictly before
    the fault — says it is worth resuming from."""
    if not os.path.isdir(directory):
        return []
    files = sorted(
        f for f in os.listdir(directory)
        if f.endswith(".npz") and not f.endswith(".npz.tmp")
    )
    out = []
    for f in files:
        path = os.path.join(directory, f)
        if before_step is not None and checkpoint_step(path) >= before_step:
            continue
        try:
            verify_checkpoint(path, check_finite=check_finite)
            out.append(path)
        except CheckpointVerifyError:
            continue
    return out


def latest_verified_checkpoint(directory: str, *,
                               check_finite: bool = True):
    """Newest snapshot in ``directory`` that passes ``verify_checkpoint``
    (None when none does) — the resume/rollback entry point: torn,
    corrupt and (by default) non-finite snapshots are skipped, walking
    back through the retention chain."""
    if not os.path.isdir(directory):
        return None
    files = sorted(
        f for f in os.listdir(directory)
        if f.endswith(".npz") and not f.endswith(".npz.tmp")
    )
    for f in reversed(files):
        path = os.path.join(directory, f)
        try:
            verify_checkpoint(path, check_finite=check_finite)
            return path
        except CheckpointVerifyError:
            continue
    return None


def _is_packed_plane(spec, leaf) -> bool:
    """Does this template leaf have the packed-buffer trailing shape?"""
    return (leaf.ndim >= 2 and leaf.shape[-2] == spec.rows
            and leaf.shape[-1] == 128)


def _pack_legacy(spec, data, key: str, leaf):
    """Assemble the packed plane ``key`` from a per-leaf checkpoint's
    ``key/<leaf path>`` entries (or None if they aren't all present)."""
    subkeys = [f"{key}/{p}" for p in spec.paths]
    if not all(k in data for k in subkeys):
        return None
    buf = spec.pack_numpy([np.asarray(data[k]) for k in subkeys],
                          dtype=leaf.dtype)
    return buf, set(subkeys)


def load_state(path: str, template):
    """Restore into the structure of ``template`` (same treedef).

    When ``template`` is a packed MetaState (``template.spec`` set) and
    the checkpoint was saved by the legacy per-leaf path, each plane is
    packed through the template's spec on load.
    """
    with np.load(path) as data:
        return _load_state(path, data, template)


def _load_state(path, data, template):
    spec = getattr(template, "spec", None)
    if PACKSPEC_KEY in data.files:
        # a packed plane of the wrong leaf layout can still have the
        # template's (rows, 128) shape (rows quantizes to 8x128 tiles),
        # so shape checks alone would let renamed/reordered/resized
        # leaves restore at wrong offsets — validate the saved layout
        # against the template's spec explicitly
        saved = json.loads(str(data[PACKSPEC_KEY][()]))
        want = spec.layout_dict() if spec is not None else None
        if saved != want:
            raise ValueError(
                f"checkpoint {path} was saved with a different packed "
                f"meta-plane layout than the restore template expects "
                f"(leaf paths/shapes/offsets differ — e.g. renamed or "
                f"reordered model params, or a per-leaf template for a "
                f"packed checkpoint); resume with the model/MAvgConfig "
                f"the run was saved under"
            )
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    seen = {PACKSPEC_KEY} if PACKSPEC_KEY in data.files else set()
    for (p, leaf) in paths:
        key = "/".join(_path_key(q) for q in p)
        if key in data:
            seen.add(key)
            arr = jnp.asarray(data[key], dtype=leaf.dtype)
        else:
            packed = (
                _pack_legacy(spec, data, key, leaf)
                if spec is not None and _is_packed_plane(spec, leaf)
                else None
            )
            if packed is None:
                raise KeyError(
                    f"checkpoint {path} has no entry {key!r} — it was saved "
                    f"under a different MAvgConfig (comm / topology buffers "
                    f"only exist when the feature was on at save time)"
                )
            buf, consumed = packed
            seen |= consumed
            arr = jnp.asarray(buf, dtype=leaf.dtype)
        if arr.shape != leaf.shape:
            raise ValueError(
                f"checkpoint {path} entry {key!r} has shape {arr.shape} but "
                f"the restore template expects {leaf.shape} — the run was "
                f"saved under a different MAvgConfig (e.g. another learner "
                f"count, or a different elastic membership schedule / "
                f"TopologyConfig.elastic period)"
            )
        leaves.append(arr)
    extra = sorted(set(data.files) - seen)
    if extra:
        # silently dropping saved state (e.g. resuming a gossip run with
        # --topology flat would discard topo/params) diverges the run
        raise ValueError(
            f"checkpoint {path} carries entries the restore template does "
            f"not expect ({extra[:4]}{'...' if len(extra) > 4 else ''}) — "
            f"resume with the MAvgConfig the run was saved under"
        )
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_packspec(path: str) -> dict | None:
    """The ``__packspec__`` layout sidecar of a packed checkpoint (the
    spec-keyed decode map for external tools), or None for per-leaf
    checkpoints."""
    with np.load(path) as data:
        if PACKSPEC_KEY not in data.files:
            return None
        return json.loads(str(data[PACKSPEC_KEY][()]))


def latest_checkpoint(directory: str):
    if not os.path.isdir(directory):
        return None
    files = sorted(f for f in os.listdir(directory) if f.endswith(".npz"))
    return os.path.join(directory, files[-1]) if files else None
