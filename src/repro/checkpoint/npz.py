"""Flat-npz pytree checkpointer (no orbax dependency).

Saves the full MetaState — global params, block momentum, learner copies,
the comm error-feedback residual and the topology buffers (group params /
momentum under hierarchical, per-learner params / momentum / residual
under gossip, riding in ``MetaState.topo`` as a dict pytree) — so a
resumed run is bit-identical (tested in tests/test_checkpoint.py and
tests/test_topology.py). Keys are slash-joined tree paths; optional
fields that are None contribute no leaves, so the layout only changes
when a feature is on.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _path_key(p):
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat["/".join(_path_key(p) for p in path)] = np.asarray(leaf)
    return flat


def save_state(directory: str, state, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    np.savez(path, **_flatten(state))
    return path


def load_state(path: str, template):
    """Restore into the structure of ``template`` (same treedef)."""
    data = np.load(path)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    seen = set()
    for (p, leaf) in paths:
        key = "/".join(_path_key(q) for q in p)
        if key not in data:
            raise KeyError(
                f"checkpoint {path} has no entry {key!r} — it was saved "
                f"under a different MAvgConfig (comm / topology buffers "
                f"only exist when the feature was on at save time)"
            )
        seen.add(key)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        if arr.shape != leaf.shape:
            raise ValueError(
                f"checkpoint {path} entry {key!r} has shape {arr.shape} but "
                f"the restore template expects {leaf.shape} — the run was "
                f"saved under a different MAvgConfig (e.g. another learner "
                f"count, or a different elastic membership schedule / "
                f"TopologyConfig.elastic period)"
            )
        leaves.append(arr)
    extra = sorted(set(data.files) - seen)
    if extra:
        # silently dropping saved state (e.g. resuming a gossip run with
        # --topology flat would discard topo/params) diverges the run
        raise ValueError(
            f"checkpoint {path} carries entries the restore template does "
            f"not expect ({extra[:4]}{'...' if len(extra) > 4 else ''}) — "
            f"resume with the MAvgConfig the run was saved under"
        )
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_checkpoint(directory: str):
    if not os.path.isdir(directory):
        return None
    files = sorted(f for f in os.listdir(directory) if f.endswith(".npz"))
    return os.path.join(directory, files[-1]) if files else None
