"""Flat-npz pytree checkpointer (no orbax dependency).

Saves the full MetaState — global params, block momentum, learner copies,
the comm error-feedback residual and the topology buffers (group params /
momentum under hierarchical, per-learner params / momentum / residual
under gossip, riding in ``MetaState.topo`` as a dict pytree) — so a
resumed run is bit-identical (tested in tests/test_checkpoint.py and
tests/test_topology.py). Keys are slash-joined tree paths; optional
fields that are None contribute no leaves, so the layout only changes
when a feature is on.

Packed meta-plane states (``MetaState.spec`` set — repro.pack, DESIGN.md
§9) save each plane as its single (rows, 128) / (lead, rows, 128) buffer
under the plain field key, plus a ``__packspec__`` JSON sidecar entry
recording the leaf layout (paths / shapes / dtypes / offsets), so a
packed .npz is decodable without re-deriving the spec from code. Loading
is layout-converting in the legacy direction: a per-leaf checkpoint
restores into a packed template by packing each plane's leaves through
the template's spec (same leading stack axes: L / G / tau), so pre-pack
runs resume bit-identically on the packed path.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

# the slash-joined key format is shared with PackSpec.paths — the legacy
# per-leaf restore matches spec paths against npz keys, so both sides
# must use the same helper
from repro.pack import _path_key

PACKSPEC_KEY = "__packspec__"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat["/".join(_path_key(p) for p in path)] = np.asarray(leaf)
    return flat


def save_state(directory: str, state, step: int, manifest=None) -> str:
    """Snapshot ``state`` to ``directory/step_<step>.npz``.

    ``manifest`` (optional): a ``repro.obs.run_manifest`` dict written to
    ``directory/manifest.json`` alongside the snapshots, so a checkpoint
    directory is self-describing — the config / topology / packspec-hash
    needed to resume it travels with it (DESIGN.md §11). Rewritten on
    every save (cheap, and a resumed run refreshes the environment info).

    Host-sync discipline: one ``jax.block_until_ready`` on the whole
    state up front, then the per-leaf ``np.asarray`` fetches are plain
    device->host copies of already-finished buffers. Without it the
    first ``np.asarray`` mid-run blocked the host on whatever compute
    was still enqueued leaf by leaf, serializing dispatch at every save
    cadence (the same lesson as Trainer.run's metric flushing).

    Donation contract (``MAvgConfig.donate``, DESIGN.md §10): pass the
    state a step RETURNED, never one you later feed to a donated step —
    a donated input's buffers are dead after dispatch and the fetch here
    would raise. The Trainer saves ``self.state`` immediately after
    rebinding it to the step's return value, which is the pattern to
    copy.
    """
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    flat = _flatten(jax.block_until_ready(state))
    spec = getattr(state, "spec", None)
    if spec is not None:
        flat[PACKSPEC_KEY] = np.asarray(json.dumps(spec.layout_dict()))
    np.savez(path, **flat)
    if manifest is not None:
        with open(os.path.join(directory, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True, default=str)
    return path


def _is_packed_plane(spec, leaf) -> bool:
    """Does this template leaf have the packed-buffer trailing shape?"""
    return (leaf.ndim >= 2 and leaf.shape[-2] == spec.rows
            and leaf.shape[-1] == 128)


def _pack_legacy(spec, data, key: str, leaf):
    """Assemble the packed plane ``key`` from a per-leaf checkpoint's
    ``key/<leaf path>`` entries (or None if they aren't all present)."""
    subkeys = [f"{key}/{p}" for p in spec.paths]
    if not all(k in data for k in subkeys):
        return None
    buf = spec.pack_numpy([np.asarray(data[k]) for k in subkeys],
                          dtype=leaf.dtype)
    return buf, set(subkeys)


def load_state(path: str, template):
    """Restore into the structure of ``template`` (same treedef).

    When ``template`` is a packed MetaState (``template.spec`` set) and
    the checkpoint was saved by the legacy per-leaf path, each plane is
    packed through the template's spec on load.
    """
    with np.load(path) as data:
        return _load_state(path, data, template)


def _load_state(path, data, template):
    spec = getattr(template, "spec", None)
    if PACKSPEC_KEY in data.files:
        # a packed plane of the wrong leaf layout can still have the
        # template's (rows, 128) shape (rows quantizes to 8x128 tiles),
        # so shape checks alone would let renamed/reordered/resized
        # leaves restore at wrong offsets — validate the saved layout
        # against the template's spec explicitly
        saved = json.loads(str(data[PACKSPEC_KEY][()]))
        want = spec.layout_dict() if spec is not None else None
        if saved != want:
            raise ValueError(
                f"checkpoint {path} was saved with a different packed "
                f"meta-plane layout than the restore template expects "
                f"(leaf paths/shapes/offsets differ — e.g. renamed or "
                f"reordered model params, or a per-leaf template for a "
                f"packed checkpoint); resume with the model/MAvgConfig "
                f"the run was saved under"
            )
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    seen = {PACKSPEC_KEY} if PACKSPEC_KEY in data.files else set()
    for (p, leaf) in paths:
        key = "/".join(_path_key(q) for q in p)
        if key in data:
            seen.add(key)
            arr = jnp.asarray(data[key], dtype=leaf.dtype)
        else:
            packed = (
                _pack_legacy(spec, data, key, leaf)
                if spec is not None and _is_packed_plane(spec, leaf)
                else None
            )
            if packed is None:
                raise KeyError(
                    f"checkpoint {path} has no entry {key!r} — it was saved "
                    f"under a different MAvgConfig (comm / topology buffers "
                    f"only exist when the feature was on at save time)"
                )
            buf, consumed = packed
            seen |= consumed
            arr = jnp.asarray(buf, dtype=leaf.dtype)
        if arr.shape != leaf.shape:
            raise ValueError(
                f"checkpoint {path} entry {key!r} has shape {arr.shape} but "
                f"the restore template expects {leaf.shape} — the run was "
                f"saved under a different MAvgConfig (e.g. another learner "
                f"count, or a different elastic membership schedule / "
                f"TopologyConfig.elastic period)"
            )
        leaves.append(arr)
    extra = sorted(set(data.files) - seen)
    if extra:
        # silently dropping saved state (e.g. resuming a gossip run with
        # --topology flat would discard topo/params) diverges the run
        raise ValueError(
            f"checkpoint {path} carries entries the restore template does "
            f"not expect ({extra[:4]}{'...' if len(extra) > 4 else ''}) — "
            f"resume with the MAvgConfig the run was saved under"
        )
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_packspec(path: str) -> dict | None:
    """The ``__packspec__`` layout sidecar of a packed checkpoint (the
    spec-keyed decode map for external tools), or None for per-leaf
    checkpoints."""
    with np.load(path) as data:
        if PACKSPEC_KEY not in data.files:
            return None
        return json.loads(str(data[PACKSPEC_KEY][()]))


def latest_checkpoint(directory: str):
    if not os.path.isdir(directory):
        return None
    files = sorted(f for f in os.listdir(directory) if f.endswith(".npz"))
    return os.path.join(directory, files[-1]) if files else None
