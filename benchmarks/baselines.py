"""E4 — paper section IV baseline comparison: (K/M)-AVG vs Downpour vs
EAMSGD (+ sync MSGD and the learner-momentum variant) at equal samples."""
from __future__ import annotations

from benchmarks.common import run_mlp

CASES = [
    ("mavg", dict(mu=0.7)),
    ("kavg", dict(mu=0.0)),
    ("mavg_mlocal", dict(mu=0.5, local_momentum=0.5)),
    ("sync", dict(mu=0.7)),           # K forced to 1 below
    ("eamsgd", dict(mu=0.7, elastic_alpha=0.05)),
    ("downpour", dict(mu=0.0, staleness=2)),
]


def main(quick: bool = False):
    """Primary metric: samples to a target loss (the paper's section-IV
    comparison is accuracy-per-samples; wall-clock communication costs are
    covered by the dry-run roofline, EXPERIMENTS.md section Roofline).

    Note: on this low-noise CPU task the paper's *final-accuracy* gaps
    between the averaging family and Downpour/EAMSGD largely vanish —
    Theorem 1 predicts exactly that (variance terms dominate only in the
    noisy large-scale regime) — so the hard assertion is on the
    acceleration ordering, and final numbers are reported for the record.
    """
    from benchmarks.common import samples_to_target

    steps = 40 if quick else 80
    target = 1.1
    results = {}
    for algo, kw in CASES:
        K = 1 if algo == "sync" else 4
        algo_steps = steps * (4 if algo == "sync" else 1)
        losses, acc = run_mlp(algo, P=4, K=K, lr=0.15, steps=algo_steps,
                              batch=8, **kw)
        stt = samples_to_target(losses, target, 4, K, 8)
        results[algo] = (losses[-1], acc, stt)
        print(f"baselines,{algo},final_loss={losses[-1]:.4f},"
              f"val_acc={acc:.4f},samples_to_{target}={stt}")
    # every algorithm must reach the target; M-AVG at worst matches the
    # slowest of the stale/elastic baselines on samples-to-target
    assert results["mavg"][2] is not None
    for other in ("downpour", "eamsgd"):
        if results[other][2]:
            assert results["mavg"][2] <= 1.5 * results[other][2], (
                results["mavg"][2], other, results[other][2]
            )
    return results


if __name__ == "__main__":
    main()
