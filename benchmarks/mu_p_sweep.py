"""E2 — paper Figures 9-12 / Lemma 6: with more processors P, the optimal
momentum mu increases (and very large mu is only good at large P)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_mlp

MUS = (0.0, 0.3, 0.5, 0.7, 0.9)
PS = (2, 4, 8, 16)


def main(quick: bool = False, seeds=(0, 1, 2)):
    steps = 40 if quick else 80
    if quick:
        seeds = seeds[:1]
    table = {}
    for P in PS:
        for mu in MUS:
            accs = []
            for s in seeds:
                _, acc = run_mlp("mavg", P=P, K=4, mu=mu, lr=0.15,
                                 steps=steps, batch=8, seed=s)
                accs.append(acc)
            table[(P, mu)] = float(np.mean(accs))
            print(f"mu_p_sweep,P={P},mu={mu},val_acc={table[(P, mu)]:.4f}")
    best = {P: max(MUS, key=lambda m: table[(P, m)]) for P in PS}
    print("mu_p_sweep,best_mu_per_P," +
          ",".join(f"P{P}={best[P]}" for P in PS))
    # Lemma 6 direction: optimal mu is non-decreasing-ish in P
    assert best[PS[-1]] >= best[PS[0]], best
    return table, best


if __name__ == "__main__":
    main()
