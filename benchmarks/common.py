"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.data import classif_batch_fn, classif_eval_set
from repro.models.simple import mlp_accuracy, mlp_init, mlp_loss
from repro.pack import unpack_params

D_IN, CLASSES, HIDDEN = 32, 10, 64


def run_mlp(algorithm: str, *, P: int, K: int, mu: float, lr: float = 0.2,
            steps: int = 60, batch: int = 16, seed: int = 0,
            local_momentum: float = 0.0, staleness: int = 1,
            elastic_alpha: float = 0.05, comm=None, topology=None):
    """Train the teacher-classification MLP; returns (losses, val_acc).

    ``comm``: optional CommConfig selecting the meta-communication
    compression scheme (default dense / exact averaging). ``topology``:
    optional TopologyConfig selecting the meta-level mixing structure
    (default flat all-reduce).
    """
    extra = {} if comm is None else {"comm": comm}
    if topology is not None:
        extra["topology"] = topology
    cfg = MAvgConfig(
        algorithm=algorithm, num_learners=P, k_steps=K, learner_lr=lr,
        momentum=mu, local_momentum=local_momentum, staleness=staleness,
        elastic_alpha=elastic_alpha, **extra,
    )
    params = mlp_init(jax.random.PRNGKey(seed), D_IN, HIDDEN, CLASSES)
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    bf = classif_batch_fn(D_IN, CLASSES, P, K, batch)
    losses = []
    for i in range(steps):
        b = bf(jax.random.fold_in(jax.random.PRNGKey(seed + 1), i), i)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    eval_set = classif_eval_set(D_IN, CLASSES)
    acc = float(mlp_accuracy(unpack_params(state), eval_set))
    return losses, acc


def samples_to_target(losses, target: float, P: int, K: int, batch: int):
    """First sample count at which the running-min loss crosses target.

    This is the paper's speed-up metric (Lemma 4): M-AVG reaches a target
    accuracy with fewer samples than K-AVG. Returns None if never reached.
    """
    best = float("inf")
    for i, l in enumerate(losses):
        best = min(best, l)
        if best <= target:
            return (i + 1) * P * K * batch
    return None


def write_rows(path: str, rows: list, suite: str) -> str:
    """The one ``--json PATH`` writer every bench shares.

    Emits the ``repro.obs`` run-log envelope (DESIGN.md §11): a
    ``{"kind": "manifest", ...}`` first line carrying the suite name plus
    the jax/device environment, then one ``{"kind": "row", ...}`` line
    per result row — the same JSONL stream format Trainer run logs use,
    so one reader (and ``tools/check_telemetry.py``) covers both.
    """
    from repro.obs import JsonlSink, run_manifest

    sink = JsonlSink(path)
    sink.open_run(run_manifest(suite=suite))
    for r in rows:
        rec = dict(r)
        # benches use "kind" for their own row taxonomy (parity /
        # hbm_passes / ...); the envelope tag must stay "row", so the
        # bench taxonomy moves to "row_kind"
        if "kind" in rec:
            rec["row_kind"] = rec.pop("kind")
        sink.append({"kind": "row", **rec})
    sink.close()
    return path


def timeit(fn, *args, iters: int = 10, warmup: int = 2):
    """Median wall-clock of ``fn(*args)`` in µs, through the shared
    steady-state harness (obs.profile: warmup, block_until_ready,
    median-of-N). Use ``steady(...)`` when the IQR noise bar is wanted
    too — every reported bench number shares one methodology."""
    return steady(fn, *args, iters=iters, warmup=warmup).median_us


def steady(fn, *args, iters: int = 10, warmup: int = 2):
    """The full ``obs.profile.Timing`` (median + IQR) of ``fn(*args)``."""
    from repro.obs.profile import steady_timeit

    return steady_timeit(fn, *args, iters=iters, warmup=warmup)
