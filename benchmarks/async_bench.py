"""Async bounded-staleness server benchmark (repro.topology.async_server).

The experiment the synchronizer refactor exists for: under a skewed
per-learner step-time profile, the synchronous barrier pays the
straggler's block time every round (idle = 1 - mean/max of the profile),
while the async server keeps every learner busy and applies pushes with
staleness-decayed weight. Three arms at EQUAL EFFECTIVE SAMPLES
(completed K-step blocks x K x batch):

  sync     flat M-AVG — the barrier; wall-clock charged max(profile)
           ticks per round
  async    bounded-staleness server on the same skewed profile — one
           tick per dispatch, pushes when ready
  elastic  masking the straggler out instead of waiting for it (the §8
           alternative: drop vs lag) — runs at the fast learners' pace
           but throws the straggler's samples away

Acceptance (ROADMAP): at 4x skew the async arm lands within 5% of the
synchronous final loss at equal effective samples, while the barrier
would idle >= 40% of wall-clock; applied staleness stays <= tau on every
tick. A modeled layer prices the per-tick wire under the same profile
(roofline.topology_wire_bytes "async" arm).

Prints ``async,...`` CSV lines; ``--json PATH`` dumps every row as the
CI artifact. ``--smoke`` shrinks steps for CI.
"""
from __future__ import annotations

import argparse
import math
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/async_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import jax

from benchmarks.common import CLASSES, D_IN, HIDDEN
from repro.configs.base import (
    AsyncConfig,
    CommConfig,
    ElasticConfig,
    MAvgConfig,
    TopologyConfig,
    get_config,
)
from repro.core.meta import init_state, make_meta_step
from repro.data import classif_batch_fn, classif_eval_set
from repro.models.simple import mlp_accuracy, mlp_init, mlp_loss
from repro.pack import unpack_params
from repro.roofline import DCN_LINK_BW, ICI_LINK_BW, topology_wire_bytes
from repro.topology import make_topology

P, K, MU, LR, BATCH = 8, 4, 0.7, 0.2, 16

# 4x skew: half the learners at full speed, a 2x and a 4x straggler pair
PROFILE = (1, 1, 1, 1, 2, 2, 4, 4)
TAU = max(PROFILE) - 1


def _run(topology, ticks, *, seed=0):
    """Train the teacher-classification MLP for ``ticks`` meta steps,
    returning (losses, val_acc, per-step metrics, topology instance)."""
    cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=K,
                     learner_lr=LR, momentum=MU, topology=topology)
    topo = make_topology(cfg)
    params = mlp_init(jax.random.PRNGKey(seed), D_IN, HIDDEN, CLASSES)
    state = init_state(params, cfg, topology=topo)
    step = jax.jit(make_meta_step(mlp_loss, cfg, topology=topo))
    bf = classif_batch_fn(D_IN, CLASSES, P, K, BATCH)
    losses, metrics = [], []
    for i in range(ticks):
        b = bf(jax.random.fold_in(jax.random.PRNGKey(seed + 1), i), i)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
        metrics.append({k: float(v) for k, v in m.items()})
    acc = float(mlp_accuracy(unpack_params(state),
                             classif_eval_set(D_IN, CLASSES)))
    return losses, acc, metrics, topo


def _final(losses):
    tail = losses[-5:]
    return sum(tail) / len(tail)


def measured(quick: bool) -> list[dict]:
    sync_rounds = 15 if quick else 60
    prof = PROFILE
    target_blocks = sync_rounds * P  # the sync arm's completed blocks
    samples_per_block = K * BATCH

    # --- sync: the barrier pays the straggler every round -----------------
    losses, acc, _, _ = _run(TopologyConfig(kind="flat"), sync_rounds)
    sync_wall = sync_rounds * max(prof)
    sync_idle = 1.0 - (sum(prof) / len(prof)) / max(prof)
    rows = [{
        "kind": "async_measured", "cell": "sync_barrier",
        "final_loss": _final(losses), "val_acc": acc,
        "effective_samples": target_blocks * samples_per_block,
        "wall_clock_ticks": sync_wall, "idle_frac": sync_idle,
        "staleness_max": 0.0,
    }]

    # --- async: run until the same number of blocks completed -------------
    atopo = TopologyConfig(kind="async",
                           server=AsyncConfig(staleness=TAU, step_time=prof))
    probe = make_topology(MAvgConfig(num_learners=P, k_steps=K,
                                     topology=atopo))
    ticks = 1
    while probe.work_completed(ticks - 1) < target_blocks:
        ticks += 1
    losses, acc, metrics, topo = _run(atopo, ticks)
    stale_worst = max(m["staleness_max"] for m in metrics)
    rows.append({
        "kind": "async_measured", "cell": f"async_skew{max(prof)}x",
        "final_loss": _final(losses), "val_acc": acc,
        "effective_samples":
            topo.work_completed(ticks - 1) * samples_per_block,
        "wall_clock_ticks": ticks, "idle_frac": 0.0,
        "staleness_max": stale_worst, "staleness_bound": TAU,
    })

    # --- elastic masking: drop the stragglers instead of waiting ----------
    # (drop vs lag, §8 vs §12): 25% absent ~= masking out the 4x pair;
    # present learners run at full speed, the absentees' samples are lost
    etopo = TopologyConfig(kind="hierarchical", groups=2, outer_every=1,
                           elastic=ElasticConfig(period=8, drop_frac=0.25))
    presence = 0.75
    eticks = math.ceil(sync_rounds / presence)
    losses, acc, _, _ = _run(etopo, eticks)
    rows.append({
        "kind": "async_measured", "cell": "elastic_mask25",
        "final_loss": _final(losses), "val_acc": acc,
        "effective_samples":
            int(eticks * P * presence) * samples_per_block,
        "wall_clock_ticks": eticks, "idle_frac": 0.0,
        "staleness_max": 0.0,
    })

    for r in rows:
        print(f"async,{r['cell']},final_loss,{r['final_loss']:.4f},"
              f"wall,{r['wall_clock_ticks']},idle,{r['idle_frac']:.2f},"
              f"stale_max,{r['staleness_max']:.0f}")

    # --- acceptance -------------------------------------------------------
    sync_row = rows[0]
    async_row = rows[1]
    gap = async_row["final_loss"] / sync_row["final_loss"]
    accept = {
        "kind": "async_accept",
        "loss_vs_sync_at_equal_samples": gap,
        "within_5pct": bool(gap <= 1.05),
        "sync_idle_frac": sync_idle,
        "sync_idles_40pct": bool(sync_idle >= 0.40),
        "staleness_max": stale_worst,
        "staleness_bound": TAU,
        "staleness_bounded": bool(stale_worst <= TAU),
        "wall_clock_speedup": sync_wall / async_row["wall_clock_ticks"],
    }
    rows.append(accept)
    print(f"async_accept,loss_vs_sync,{gap:.3f},within_5pct,"
          f"{accept['within_5pct']},sync_idle,{sync_idle:.2f},"
          f"speedup,{accept['wall_clock_speedup']:.2f}x")
    return rows


def modeled(arch: str = "qwen3-1.7b") -> list[dict]:
    n = get_config(arch).param_count()
    cells = (
        ("flat_dense", TopologyConfig()),
        ("async_uniform", TopologyConfig(
            kind="async", server=AsyncConfig())),
        ("async_skew4", TopologyConfig(
            kind="async", server=AsyncConfig(staleness=TAU,
                                             step_time=PROFILE))),
    )
    rows = []
    for name, topo in cells:
        edge = topology_wire_bytes(n, CommConfig(), topo, num_learners=P)
        wire_s = (edge["intra_bytes"] / ICI_LINK_BW
                  + edge["inter_bytes"] / DCN_LINK_BW)
        rows.append({"kind": "async_model", "cell": name, "arch": arch,
                     **edge, "wire_s": wire_s})
        print(f"async_model,{arch},{name},inter,"
              f"{edge['inter_bytes']:.3e},B,{wire_s:.4f},s")
    return rows


def main(quick: bool = False, json_path: str | None = None) -> list[dict]:
    rows = measured(quick) + modeled()
    if json_path:
        from benchmarks.common import write_rows

        write_rows(json_path, rows, suite="async")
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="few steps (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (CI artifact)")
    args = ap.parse_args()
    main(quick=args.smoke, json_path=args.json)
