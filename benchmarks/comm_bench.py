"""Meta-communication benchmark: step time, bytes-on-wire, and final loss
per compression scheme (the repro.comm subsystem).

Two layers of numbers:

1. *Measured* — jitted meta-step wall time and the reducer's own
   ``comm_bytes`` metrics on the teacher-classification MLP, plus final
   training loss so compression quality is visible next to its savings.
   CPU step times are not TPU-representative (and interpret-mode Pallas
   slower still); the bytes and loss columns are the point.
2. *Modeled* — roofline.meta_wire_bytes on a full-scale config
   (qwen3-1.7b), showing what each scheme ships per meta step at
   production size and the resulting ICI link time.

Prints ``comm,...`` CSV lines. ``--smoke`` (or quick=True) shrinks steps
for CI.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax

if __package__ in (None, ""):  # `python benchmarks/comm_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

from benchmarks.common import CLASSES, D_IN, HIDDEN, run_mlp, timeit
from repro.configs.base import CommConfig, MAvgConfig, get_config
from repro.core.meta import init_state, make_meta_step
from repro.data import classif_batch_fn
from repro.models.simple import mlp_init, mlp_loss
from repro.roofline import ICI_LINK_BW, meta_wire_bytes

SCHEMES = ("dense", "int8", "fp8", "topk", "int8_topk")


def _comm(scheme: str) -> CommConfig:
    return CommConfig(scheme=scheme, error_feedback=scheme != "dense")


def measured(quick: bool, *, P=4, K=4, mu=0.7, use_pallas=False):
    steps = 15 if quick else 60
    dense_loss = None
    for scheme in SCHEMES:
        comm = CommConfig(scheme=scheme, error_feedback=scheme != "dense",
                          use_pallas=use_pallas)
        losses, acc = run_mlp("mavg", P=P, K=K, mu=mu, steps=steps, comm=comm)

        # one jitted step on a fixed batch for timing + metrics
        cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=K,
                         learner_lr=0.2, momentum=mu, comm=comm)
        params = mlp_init(jax.random.PRNGKey(0), D_IN, HIDDEN, CLASSES)
        state = init_state(params, cfg)
        step = jax.jit(make_meta_step(mlp_loss, cfg))
        b = classif_batch_fn(D_IN, CLASSES, P, K, 16)(jax.random.PRNGKey(1), 0)
        _, m = step(state, b)
        t_us = timeit(lambda s, bb: step(s, bb)[0], state, b,
                      iters=3 if quick else 10, warmup=1)

        wire = float(m["comm_bytes"])
        dense_b = float(m["comm_bytes_dense"])
        final = sum(losses[-5:]) / len(losses[-5:])
        if scheme == "dense":
            dense_loss = final
        print(f"comm,{scheme},bytes_wire,{wire:.0f},B")
        print(f"comm,{scheme},compression,{dense_b / wire:.2f},x")
        print(f"comm,{scheme},step_time,{t_us:.0f},us")
        print(f"comm,{scheme},final_loss,{final:.4f},"
              f"{final / dense_loss:.3f}x_dense")
        print(f"comm,{scheme},val_acc,{acc:.3f},frac")


def modeled(arch: str = "qwen3-1.7b", P: int = 8):
    n = get_config(arch).param_count()
    for scheme in SCHEMES:
        dense, wire = meta_wire_bytes(n, _comm(scheme), num_learners=P)
        print(f"comm_model,{arch},{scheme},{wire:.3e},B,"
              f"{dense / wire:.2f},x,{wire / ICI_LINK_BW:.4f},s")


def main(quick: bool = False):
    measured(quick)
    modeled()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="few steps / few timing iters (CI)")
    args = ap.parse_args()
    main(quick=args.smoke)
