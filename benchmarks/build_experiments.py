"""Regenerate the data tables inside EXPERIMENTS.md §Dry-run/§Roofline
from benchmarks/results/dryrun/*.json. Hand-written sections (Perf logs,
Claims) live in EXPERIMENTS.md directly; this script only rewrites the
blocks between the AUTOGEN markers."""
from __future__ import annotations

import json
import os
import re

from benchmarks.roofline_table import load_results

HERE = os.path.dirname(os.path.abspath(__file__))
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")

LEVER = {
    # bottleneck -> generic lever sentence fragments, specialised by family
    ("memory", "ssm"): "chunkwise-parallel recurrence (done: 173x) and fused scan cells",
    ("memory", "hybrid"): "fused mamba-scan kernel; bf16 scan states",
    ("memory", "dense"): "Pallas flash attention (S^2 softmax chain is the bulk of HBM traffic)",
    ("memory", "vlm"): "Pallas flash attention; fewer remat passes",
    ("memory", "audio"): "Pallas flash attention (bidirectional)",
    ("memory", "moe"): "bf16 token exchange at MoE boundary; flash attention",
    ("collective", "dense"): "FSDP weight-gather instead of TP activation all-reduce (done for qwen3: 1.9x); DP learners where the model fits a chip",
    ("collective", "moe"): "shard_map all-to-all token dispatch instead of gather/scatter resharding",
    ("collective", "vlm"): "FSDP weight-gather; overlap meta all-reduce with local steps",
    ("collective", "ssm"): "decode state is tiny - batch the meta sync",
    ("compute", "dense"): "already near roofline; reduce remat recompute",
}


def lever(row):
    rf = row["roofline"]
    cfgfam = _family(row["arch"])
    frag = LEVER.get((rf["bottleneck"], cfgfam))
    if frag is None:
        frag = "reduce %s term via sharding/fusion" % rf["bottleneck"]
    return frag


def _family(arch):
    from repro.configs import get_config

    return get_config(arch).family


def _f(x):
    return f"{x:.3g}"


def dryrun_table():
    lines = [
        "| arch | shape | mesh | per-dev args | per-dev temp | collectives (by type, bytes/dev/step) |",
        "|---|---|---|---|---|---|",
    ]
    rows = load_results(mesh="single") + load_results(mesh="multi")
    for r in rows:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — |"
                f" SKIP: {r['reason']} |"
            )
            continue
        mem = r.get("memory", {})
        args = mem.get("argument_size_in_bytes", 0) / 2**30
        temp = mem.get("temp_size_in_bytes", 0) / 2**30
        coll = ", ".join(
            f"{k}={v / 1e9:.1f}GB" for k, v in r["collectives"]["by_type"].items()
        ) or "none"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {args:.2f}GiB |"
            f" {temp:.2f}GiB | {coll} |"
        )
    return "\n".join(lines)


def roofline_table():
    lines = [
        "| arch | shape | mesh | HLO FLOPs/dev | HBM B/dev | coll B/dev |"
        " compute s | memory s | collective s | bound | MODEL/HLO | lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    rows = load_results(mesh="single") + load_results(mesh="multi")
    for r in rows:
        if r.get("skipped"):
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {rf['hlo_flops']:.2e} | {rf['hlo_bytes']:.2e} |"
            f" {rf['collective_bytes']:.2e} |"
            f" {_f(rf['compute_s'])} | {_f(rf['memory_s'])} |"
            f" {_f(rf['collective_s'])} | **{rf['bottleneck']}** |"
            f" {rf['useful_ratio']:.2f} | {lever(r)} |"
        )
    return "\n".join(lines)


def replace_block(text, marker, content):
    pattern = re.compile(
        rf"(<!-- AUTOGEN:{marker} -->).*?(<!-- /AUTOGEN:{marker} -->)",
        re.DOTALL,
    )
    return pattern.sub(rf"\1\n{content}\n\2", text)


def main():
    with open(EXP) as f:
        text = f.read()
    text = replace_block(text, "dryrun", dryrun_table())
    text = replace_block(text, "roofline", roofline_table())
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
