"""Ablations beyond the paper: block-momentum flavours.

* heavy-ball (the paper's Algorithm 1)
* Nesterov block momentum (lookahead at the meta level)
* learner-level MSGD under block momentum (the paper's §V note)
* meta_lr (eta) scaling of the displacement

All at the same (P, K, B, samples).
"""
from __future__ import annotations

from benchmarks.common import run_mlp, samples_to_target
from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.pack import unpack_params
from repro.data import classif_batch_fn, classif_eval_set
from repro.models.simple import mlp_accuracy, mlp_init, mlp_loss

import jax


def run_cfg(tag, steps=60, **kw):
    cfg = MAvgConfig(algorithm=kw.pop("algorithm", "mavg"), num_learners=4,
                     k_steps=4, learner_lr=0.15, **kw)
    params = mlp_init(jax.random.PRNGKey(0), 32, 64, 10)
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    bf = classif_batch_fn(32, 10, 4, 4, 8)
    losses = []
    for i in range(steps):
        b = bf(jax.random.fold_in(jax.random.PRNGKey(1), i), i)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    acc = float(mlp_accuracy(unpack_params(state), classif_eval_set(32, 10)))
    stt = samples_to_target(losses, 1.1, 4, 4, 8)
    print(f"ablations,{tag},final_loss={losses[-1]:.4f},val_acc={acc:.4f},"
          f"samples_to_1.1={stt}")
    return losses, acc, stt


def main(quick: bool = False):
    steps = 40 if quick else 80
    results = {}
    results["heavy_ball"] = run_cfg("heavy_ball", steps, momentum=0.6)
    results["nesterov"] = run_cfg("nesterov", steps, momentum=0.6,
                                  nesterov=True)
    results["mlocal"] = run_cfg("mlocal", steps, algorithm="mavg_mlocal",
                                momentum=0.4, local_momentum=0.5)
    results["eta_0.5"] = run_cfg("eta_0.5", steps, momentum=0.6, meta_lr=0.5)
    results["eta_1.5"] = run_cfg("eta_1.5", steps, momentum=0.6, meta_lr=1.5)
    # all variants must train
    for tag, (losses, acc, stt) in results.items():
        assert losses[-1] < losses[0], tag
    return results


if __name__ == "__main__":
    main()
