"""E3 — paper Lemmas 5 and 7: at fixed sample budget S = N*K,
(a) the optimal K is > 1 (communication can be delayed for free or
    better), and
(b) adding momentum shifts the optimal K downward (K_opt(mu) <= K_opt(0)).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_mlp

KS = (1, 2, 4, 8, 16)
TOTAL_LOCAL_STEPS = 128  # S = N * K held constant


def sweep(mu, seeds=(0, 1, 2), lr=0.15):
    accs = {}
    for K in KS:
        N = TOTAL_LOCAL_STEPS // K
        vals = []
        for s in seeds:
            _, acc = run_mlp("mavg", P=4, K=K, mu=mu, lr=lr, steps=N,
                             batch=8, seed=s)
            vals.append(acc)
        accs[K] = float(np.mean(vals))
        print(f"k_sweep,mu={mu},K={K},N={N},val_acc={accs[K]:.4f}")
    return accs


def _time_proxy(acc, comm_ratio: float):
    """Simulated wall-clock to equal samples: N meta-steps cost
    N * (K * t_local + t_comm) with t_comm = comm_ratio * t_local.
    comm_ratio comes from the dry-run roofline (qwen3 train_4k:
    collective term / compute term per meta-step, see EXPERIMENTS.md)."""
    out = {}
    for K in KS:
        N = TOTAL_LOCAL_STEPS // K
        out[K] = N * (K + comm_ratio)
    return out


def main(quick: bool = False, comm_ratio: float = 14.0):
    seeds = (0,) if quick else (0, 1, 2)
    acc0 = sweep(0.0, seeds)
    acc7 = sweep(0.7, seeds)
    k_opt0 = max(acc0, key=acc0.get)
    k_opt7 = max(acc7, key=acc7.get)
    print(f"k_sweep,K_opt_statistical(mu=0)={k_opt0},K_opt(mu=0.7)={k_opt7}")
    # Lemma 5 statistical side: K>1 loses (almost) nothing per sample...
    assert max(acc0[k] for k in KS if k > 1) >= acc0[1] - 0.02
    # Lemma 7: momentum prefers equal-or-smaller K
    assert k_opt7 <= max(k_opt0, 8), (k_opt0, k_opt7)
    # ...and wins outright once communication is priced in (the paper's
    # low-communication-cost claim). comm_ratio=14 measured by the
    # dry-run roofline for qwen3-1.7b train_4k on the single-pod mesh.
    times = _time_proxy(acc0, comm_ratio)
    eff = {K: acc0[K] / times[K] for K in KS}
    k_opt_time = max(eff, key=eff.get)
    for K in KS:
        print(f"k_sweep,time_proxy,K={K},time={times[K]},acc_per_time="
              f"{eff[K]:.2e}")
    print(f"k_sweep,K_opt_with_comm_cost={k_opt_time}")
    assert k_opt_time > 1
    return acc0, acc7


if __name__ == "__main__":
    main()
