"""Chaos/recovery benchmark (repro.chaos + core.supervisor, DESIGN.md §13).

The acceptance experiment the fault-injection subsystem exists for: under
the STANDARD fault schedule (a learner crash window, a NaN batch burst,
payload scale+bitflip corruption, a straggle spike and a torn checkpoint
write — repro.chaos.standard_chaos), a supervised run with the in-step
finite guard and the verified checkpoint chain must converge within 5%
of the fault-free final loss at equal effective samples, with zero
non-finite values ever entering ``MetaState``.

Arms:

  fault_free        the same config, no chaos, no guard — the loss bar
  chaos_supervised  standard chaos + finite_guard + Supervisor rollback/
                    retry over the verified checkpoint chain
  injectors_off     chaos installed but EMPTY (corruptor idle, guard on)
                    vs vanilla — final state must be BITWISE identical
  kill_mid_save     a torn write at the head of the checkpoint chain —
                    ``latest_verified_checkpoint`` must fall back to the
                    previous snapshot bit-exactly

Prints ``chaos,...`` CSV lines; ``--json PATH`` dumps every row as the
CI artifact (gated by benchmarks/expected/chaos.json via
tools/bench_compare.py). ``--smoke`` shrinks steps for CI.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

if __package__ in (None, ""):  # `python benchmarks/chaos_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import numpy as np

import jax

from benchmarks.common import CLASSES, D_IN, HIDDEN
from repro.chaos import ChaosConfig, standard_chaos
from repro.checkpoint import (
    latest_verified_checkpoint,
    load_state,
    save_state,
    verify_checkpoint,
)
from repro.configs.base import (
    AsyncConfig,
    MAvgConfig,
    ObsConfig,
    TopologyConfig,
    TrainConfig,
)
from repro.core import RecoveryPolicy, Supervisor, Trainer
from repro.data import classif_batch_fn
from repro.models.simple import mlp_init, mlp_loss

P, K, MU, LR, BATCH = 4, 4, 0.7, 0.2, 16
TAU = 2


def _make_trainer(steps, *, chaos=None, guard=False, salt=0, lr_scale=1.0,
                  ckpt_dir=None, health=False, momentum_scale=1.0):
    mcfg = MAvgConfig(
        algorithm="mavg", num_learners=P, k_steps=K,
        learner_lr=LR * lr_scale, momentum=MU * momentum_scale,
        finite_guard=guard,
        topology=TopologyConfig(kind="async",
                                server=AsyncConfig(staleness=TAU)),
    )
    tcfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=BATCH, meta_steps=steps,
        seed=0, log_every=2,
        checkpoint_dir=ckpt_dir,
        checkpoint_every=2 if ckpt_dir else 0,
        checkpoint_keep=4 if ckpt_dir else 0,
        chaos=chaos, data_salt=salt,
        obs=ObsConfig(sink="none", health=health),
    )
    return Trainer(
        tcfg, mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D_IN, HIDDEN, CLASSES),
        batch_fn=classif_batch_fn(D_IN, CLASSES, P, K, BATCH),
    )


def _final_loss(history):
    tail = [r["loss"] for r in history[-5:]]
    return sum(tail) / len(tail)


def _state_finite(state) -> bool:
    planes = [state.global_params, state.momentum, state.learners]
    return all(
        bool(np.isfinite(np.asarray(p)).all()) for p in planes
        if p is not None
    )


def measured(quick: bool) -> list[dict]:
    # smoke needs enough post-fault room for a full rollback replay to
    # re-converge: the supervisor resumes from the newest snapshot
    # STRICTLY before the fault, so one recovery re-pays a few steps
    steps = 24 if quick else 40
    rows: list[dict] = []

    # --- fault-free bar ---------------------------------------------------
    tr = _make_trainer(steps)
    base_hist = tr.run(log=None)
    base_loss = _final_loss(base_hist)
    base_samples = base_hist[-1]["samples"]
    tr.close()
    rows.append({
        "kind": "chaos_measured", "cell": "fault_free",
        "final_loss": base_loss, "effective_samples": base_samples,
        "state_finite": _state_finite(tr.state),
    })

    def base_loss_at(samples):
        """Fault-free loss at ``samples`` effective samples — the equal-
        effective-samples bar (crash windows and quarantine probation
        cost the supervised run samples it never gets back; the fair
        comparison charges the fault-free arm the same budget)."""
        upto = (
            [r for r in base_hist if r["samples"] <= samples]
            or base_hist[:1]
        )
        return _final_loss(upto)

    # --- supervised run under the standard fault schedule -----------------
    chaos = standard_chaos(P, steps, seed=0)
    tmp = tempfile.mkdtemp(prefix="chaos_bench_")
    ckpt_dir = os.path.join(tmp, "ckpt")

    def make_trainer(plan):
        return _make_trainer(
            steps, chaos=chaos, guard=True, salt=plan.data_salt,
            lr_scale=plan.lr_scale, momentum_scale=plan.momentum_scale,
            ckpt_dir=ckpt_dir, health=True,
        )

    sup = Supervisor(
        make_trainer, target_steps=steps, checkpoint_dir=ckpt_dir,
        policy=RecoveryPolicy(max_retries=3,
                              quarantine_steps=max(steps // 8, 2)),
    )
    tr, hist = sup.run(log=None)
    sup_loss = _final_loss(tr.history)
    sup_samples = tr.history[-1]["samples"]
    retries = max(
        (r["attempt"] for r in sup.records if r.get("kind") == "recovery"),
        default=0,
    )
    sup_finite = _state_finite(tr.state)
    # every retained snapshot of the chain must verify finite too — the
    # "zero non-finite values ever entering MetaState" claim is checked
    # at each point the state was durably observed
    chain_ok = True
    for f in sorted(os.listdir(ckpt_dir)):
        if f.endswith(".npz"):
            try:
                verify_checkpoint(os.path.join(ckpt_dir, f))
            except Exception:
                chain_ok = False
    tr.close()
    rows.append({
        "kind": "chaos_measured", "cell": "chaos_supervised",
        "final_loss": sup_loss, "effective_samples": sup_samples,
        "state_finite": sup_finite, "chain_verified": chain_ok,
        "retries_used": retries,
        "faults_injected": len(chaos.faults),
    })

    # --- injectors off == bitwise identity --------------------------------
    tr_a = _make_trainer(max(steps // 4, 8))
    tr_a.run(log=None)
    tr_b = _make_trainer(max(steps // 4, 8),
                         chaos=ChaosConfig(seed=0, horizon=steps, faults=()),
                         guard=True)
    tr_b.run(log=None)
    bitwise_off = bool(
        np.array_equal(np.asarray(tr_a.state.global_params),
                       np.asarray(tr_b.state.global_params))
        and np.array_equal(np.asarray(tr_a.state.learners),
                           np.asarray(tr_b.state.learners))
        and np.array_equal(np.asarray(tr_a.state.momentum),
                           np.asarray(tr_b.state.momentum))
    )
    rows.append({
        "kind": "chaos_measured", "cell": "injectors_off",
        "bitwise_identical": bitwise_off,
    })

    # --- kill mid-save: the chain falls back bit-exactly -------------------
    kdir = os.path.join(tmp, "killsave")
    good = save_state(kdir, tr_a.state, 8)
    save_state(kdir, tr_a.state, 9, fault="torn")
    fallback = latest_verified_checkpoint(kdir)
    resume_ok = fallback == good
    if resume_ok:
        restored = load_state(good, tr_a.state)
        resume_ok = bool(np.array_equal(
            np.asarray(restored.global_params),
            np.asarray(tr_a.state.global_params),
        ))
    rows.append({
        "kind": "chaos_measured", "cell": "kill_mid_save",
        "resume_verified": bool(resume_ok),
    })

    for r in rows:
        print("chaos," + ",".join(
            f"{k}={v}" for k, v in r.items() if k != "kind"
        ))

    # --- acceptance -------------------------------------------------------
    bar = base_loss_at(sup_samples)
    gap = sup_loss / bar
    accept = {
        "kind": "chaos_accept",
        "loss_fault_free": bar,
        "loss_fault_free_full": base_loss,
        "loss_supervised": sup_loss,
        "loss_vs_fault_free": gap,
        "within_5pct": bool(gap <= 1.05),
        "samples_vs_fault_free": sup_samples / max(base_samples, 1),
        "state_finite": bool(sup_finite and chain_ok),
        "bitwise_off": bitwise_off,
        "resume_verified": bool(resume_ok),
        "retries_used": retries,
        "ok": bool(
            gap <= 1.05 and sup_finite and chain_ok and bitwise_off
            and resume_ok
        ),
    }
    rows.append(accept)
    print(f"chaos_accept,loss_vs_fault_free,{gap:.3f},within_5pct,"
          f"{accept['within_5pct']},state_finite,{accept['state_finite']},"
          f"bitwise_off,{bitwise_off},resume_verified,{resume_ok},"
          f"retries,{retries}")
    return rows


def main(quick: bool = False, json_path: str | None = None) -> list[dict]:
    rows = measured(quick)
    if json_path:
        from benchmarks.common import write_rows

        write_rows(json_path, rows, suite="chaos")
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="few steps (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (CI artifact)")
    args = ap.parse_args()
    main(quick=args.smoke, json_path=args.json)
