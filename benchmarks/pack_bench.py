"""Packed flat meta-plane benchmark (repro.pack, DESIGN.md §9).

Three layers of numbers:

1. *Parity* — the packed meta step against the legacy per-leaf path on
   the teacher-classification MLP, per topology (flat / hierarchical /
   gossip) and comm scheme (dense / int8+EF). Dense cells must match to
   f32 tolerances (identical algebra, different layout); int8+EF cells
   agree to quantization noise (the packed wire uses per-learner chunks
   over the packed layout, the per-leaf wire chunks each leaf — same
   scheme, different chunk boundaries) and must land within 2% final
   loss.
2. *Launch/padding* — the O(leaves) -> O(1) collapse of meta-phase
   kernel launches per op, and the per-leaf 8x128 tile padding vs the
   packed lane-aligned layout, on the real configs' abstract param trees
   (exact static analysis, no allocation).
3. *Timing* — wall-clock of the jitted meta step, packed vs per-leaf, on
   an enlarged MLP (CPU/XLA: what's measured here is mostly dispatch and
   fusion-count overhead — the per-leaf path's O(leaves) ops — not TPU
   HBM behavior).

Prints ``pack,...`` CSV lines; ``--json PATH`` dumps every row as JSON
(the CI artifact, like comm/topology/elastic benches). ``--smoke``
shrinks steps for CI.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/pack_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.configs.base import CommConfig, MAvgConfig, TopologyConfig
from repro.core.meta import init_state, make_meta_step
from repro.models.simple import mlp_init, mlp_loss
from repro.pack import make_pack_spec, unpack_params

P, K, MU = 8, 4, 0.7
D, C, H = 32, 10, 64

CELLS = (
    ("flat_dense", TopologyConfig(), CommConfig()),
    ("flat_int8_ef", TopologyConfig(),
     CommConfig(scheme="int8", error_feedback=True)),
    ("hier_dense", TopologyConfig(kind="hierarchical", groups=2,
                                  outer_every=2), CommConfig()),
    ("hier_int8_ef",
     TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                    inner_comm=CommConfig(scheme="int8",
                                          error_feedback=True)),
     CommConfig()),
    ("gossip_ring_dense", TopologyConfig(kind="gossip", graph="ring"),
     CommConfig()),
    ("gossip_exp_int8_ef",
     TopologyConfig(kind="gossip", graph="exponential",
                    inner_comm=CommConfig(scheme="int8",
                                          error_feedback=True)),
     CommConfig()),
    # packed top-k is whole-model-vector selection (per-leaf budgets on
    # the legacy path) — parity is trajectory-level, like int8
    ("flat_topk_ef", TopologyConfig(),
     CommConfig(scheme="topk", error_feedback=True)),
    ("flat_int8topk_ef", TopologyConfig(),
     CommConfig(scheme="int8_topk", error_feedback=True)),
)


def _batches(seed, L, K, B=8):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _train(cfg, steps, params):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    losses = []
    for i in range(steps):
        state, m = step(state, _batches(i, cfg.num_learners, cfg.k_steps))
        losses.append(float(m["loss"]))
    return state, losses


def parity(quick: bool) -> list[dict]:
    steps = 10 if quick else 40
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    rows = []
    for name, topo, comm in CELLS:
        cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=K,
                         learner_lr=0.2, momentum=MU, comm=comm,
                         topology=topo)
        s_packed, l_packed = _train(cfg, steps, params)
        s_leaf, l_leaf = _train(dc.replace(cfg, packed=False), steps, params)
        gp_p = jax.tree.leaves(unpack_params(s_packed))
        gp_l = jax.tree.leaves(unpack_params(s_leaf))
        diff = max(
            float(jnp.max(jnp.abs(a - b))) for a, b in zip(gp_p, gp_l)
        )
        scale = max(float(jnp.max(jnp.abs(b))) for b in gp_l)
        # dense: pure layout change, bitwise; int8: same scheme, moved
        # chunk boundaries -> quantization noise; topk: a different
        # sparsification operator (whole-model vs per-leaf selection),
        # so trajectories diverge at the param level and the pin is the
        # matched convergence (loss_ratio)
        tol = 3e-1 if "topk" in name else 5e-2 if "int8" in name else 1e-5
        loss_ratio = l_packed[-1] / l_leaf[-1]
        ok = diff / scale < tol and abs(loss_ratio - 1) < 0.02
        rows.append({
            "kind": "pack_parity", "cell": name, "steps": steps,
            "max_abs_diff": diff, "rel_diff": diff / scale,
            "final_loss_packed": l_packed[-1],
            "final_loss_per_leaf": l_leaf[-1],
            "loss_ratio": loss_ratio, "ok": bool(ok),
        })
        print(f"pack,parity,{name},rel_diff={diff / scale:.2e},"
              f"loss_ratio={loss_ratio:.4f},{'ok' if ok else 'FAIL'}")
        assert ok, rows[-1]
    return rows


def launches(quick: bool) -> list[dict]:
    from benchmarks.kernel_bench import meta_plane_rows

    return meta_plane_rows(quick=quick)


def timing(quick: bool) -> list[dict]:
    """Full jitted meta step on plain XLA CPU, packed vs per-leaf.

    XLA CPU fuses the per-leaf jnp ops into a handful of loops anyway, so
    this does NOT demonstrate the launch-count win (that is a TPU /
    pallas_call property, reported statically by ``launches``); it bounds
    the overhead of the learner-boundary pack/unpack copies the packed
    path adds — the one cost the refactor introduces.
    """
    depth, hidden = (4, 256) if quick else (8, 512)
    params = mlp_init(jax.random.PRNGKey(0), D, hidden, C, depth=depth)
    spec = make_pack_spec(params)
    rows = []
    times = {}
    for packed in (False, True):
        cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=2,
                         learner_lr=0.2, momentum=MU, packed=packed)
        state = init_state(params, cfg)
        step = jax.jit(make_meta_step(mlp_loss, cfg))
        b = _batches(0, P, 2)
        times[packed] = timeit(lambda s: step(s, b)[0], state,
                               iters=5, warmup=2)
        print(f"pack,meta_step_xla_cpu_us,"
              f"{'packed' if packed else 'per_leaf'},{times[packed]:.0f}")
    rows.append({
        "kind": "pack_timing_xla_cpu", "n_leaves": spec.num_leaves,
        "meta_step_us_per_leaf": times[False],
        "meta_step_us_packed": times[True],
        "packed_over_per_leaf": times[True] / times[False],
    })
    return rows


def main(quick: bool = False, json_path: str | None = None):
    rows = []
    rows += parity(quick)
    rows += launches(quick)
    rows += timing(quick)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"pack,json,{json_path},written")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: fewer steps")
    ap.add_argument("--json", default=None, help="dump rows as JSON")
    args = ap.parse_args()
    main(quick=args.smoke, json_path=args.json)
