"""Packed flat meta-plane benchmark (repro.pack, DESIGN.md §9/§10).

Four layers of numbers:

1. *Parity* — the packed meta step against the legacy per-leaf path on
   the teacher-classification MLP, per topology (flat / hierarchical /
   gossip) and comm scheme (dense / int8+EF). Dense cells must be
   BITWISE (identical algebra, different layout); int8+EF cells agree to
   quantization noise (the packed wire uses per-learner chunks over the
   packed layout, the per-leaf wire chunks each leaf — same scheme,
   different chunk boundaries) and must land within 2% final loss.
2. *Launch/padding* — the O(leaves) -> O(1) collapse of meta-phase
   kernel launches per op, and the per-leaf 8x128 tile padding vs the
   packed lane-aligned layout, on the real configs' abstract param trees
   (exact static analysis, no allocation).
3. *Meta-phase HBM table* (DESIGN.md §10) — peak meta-state memory of
   the donated vs functional meta mix and the HBM traffic of the fused
   momentum->broadcast and compress-only kernels, at the llama3_405b
   dry-run config. Peak memory and the compress-only gp-read removal are
   MEASURED off the compiled dry-run HLO (roofline.hlo_cost.jit_cost —
   AOT, nothing allocated); the fused-kernel pass counts are the Pallas
   kernel's structural reads/writes (on CPU the interpret-mode lowering
   dissolves the kernel boundary, so XLA-CPU traffic cannot show them).
   Every zero-copy route is pinned bitwise against the functional / PR 4
   path it replaces.
4. *Timing* — wall-clock of the jitted meta step, packed vs per-leaf, on
   an enlarged MLP (CPU/XLA: what's measured here is mostly dispatch and
   fusion-count overhead — the per-leaf path's O(leaves) ops — not TPU
   HBM behavior).

Prints ``pack,...`` CSV lines; ``--json PATH`` dumps every row as JSON
(the CI artifact, like comm/topology/elastic benches). ``--smoke``
shrinks steps for CI. Any row with ``ok: false`` makes the process (and
benchmarks/run.py) exit non-zero.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/pack_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import steady
from repro.configs.base import CommConfig, MAvgConfig, TopologyConfig
from repro.core.meta import init_state, make_meta_step
from repro.models.simple import mlp_init, mlp_loss
from repro.pack import make_pack_spec, unpack_params

P, K, MU = 8, 4, 0.7
D, C, H = 32, 10, 64

CELLS = (
    ("flat_dense", TopologyConfig(), CommConfig()),
    ("flat_int8_ef", TopologyConfig(),
     CommConfig(scheme="int8", error_feedback=True)),
    ("hier_dense", TopologyConfig(kind="hierarchical", groups=2,
                                  outer_every=2), CommConfig()),
    ("hier_int8_ef",
     TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                    inner_comm=CommConfig(scheme="int8",
                                          error_feedback=True)),
     CommConfig()),
    ("gossip_ring_dense", TopologyConfig(kind="gossip", graph="ring"),
     CommConfig()),
    ("gossip_exp_int8_ef",
     TopologyConfig(kind="gossip", graph="exponential",
                    inner_comm=CommConfig(scheme="int8",
                                          error_feedback=True)),
     CommConfig()),
    # packed top-k is whole-model-vector selection (per-leaf budgets on
    # the legacy path) — parity is trajectory-level, like int8
    ("flat_topk_ef", TopologyConfig(),
     CommConfig(scheme="topk", error_feedback=True)),
    ("flat_int8topk_ef", TopologyConfig(),
     CommConfig(scheme="int8_topk", error_feedback=True)),
)


def _batches(seed, L, K, B=8):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(kx, (L, K, B, D)),
        "y": jax.random.randint(ky, (L, K, B), 0, C),
    }


def _train(cfg, steps, params):
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(mlp_loss, cfg))
    losses = []
    for i in range(steps):
        state, m = step(state, _batches(i, cfg.num_learners, cfg.k_steps))
        losses.append(float(m["loss"]))
    return state, losses


def parity(quick: bool) -> list[dict]:
    steps = 10 if quick else 40
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    rows = []
    for name, topo, comm in CELLS:
        cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=K,
                         learner_lr=0.2, momentum=MU, comm=comm,
                         topology=topo)
        s_packed, l_packed = _train(cfg, steps, params)
        s_leaf, l_leaf = _train(dc.replace(cfg, packed=False), steps, params)
        gp_p = jax.tree.leaves(unpack_params(s_packed))
        gp_l = jax.tree.leaves(unpack_params(s_leaf))
        diff = max(
            float(jnp.max(jnp.abs(a - b))) for a, b in zip(gp_p, gp_l)
        )
        scale = max(float(jnp.max(jnp.abs(b))) for b in gp_l)
        # dense: pure layout change, BITWISE (diff exactly 0 — the pin
        # that the fused momentum->broadcast route stayed on the PR 4
        # trajectory); int8: same scheme, moved chunk boundaries ->
        # quantization noise; topk: a different sparsification operator
        # (whole-model vs per-leaf selection), so trajectories diverge at
        # the param level and the pin is the matched convergence
        # (loss_ratio)
        bitwise = "topk" not in name and "int8" not in name
        tol = 3e-1 if "topk" in name else 5e-2
        loss_ratio = l_packed[-1] / l_leaf[-1]
        ok = ((diff == 0.0 if bitwise else diff / scale < tol)
              and abs(loss_ratio - 1) < 0.02)
        rows.append({
            "kind": "pack_parity", "cell": name, "steps": steps,
            "max_abs_diff": diff, "rel_diff": diff / scale,
            "bitwise": bool(bitwise and diff == 0.0),
            "final_loss_packed": l_packed[-1],
            "final_loss_per_leaf": l_leaf[-1],
            "loss_ratio": loss_ratio, "ok": bool(ok),
        })
        print(f"pack,parity,{name},rel_diff={diff / scale:.2e},"
              f"bitwise={rows[-1]['bitwise']},"
              f"loss_ratio={loss_ratio:.4f},{'ok' if ok else 'FAIL'}")
    return rows


def launches(quick: bool) -> list[dict]:
    from benchmarks.kernel_bench import meta_plane_rows

    return meta_plane_rows(quick=quick)


# ---------------------------------------------------------------------------
# meta-phase HBM table (DESIGN.md §10): donated peak memory + fused passes
# ---------------------------------------------------------------------------

HBM_ARCH = "llama3-405b"
HBM_L = 8  # dry-run learner count of the donated/functional comparison
HBM_MU = 0.7


def hbm_table(quick: bool) -> list[dict]:
    """The zero-copy meta phase, measured at the llama3_405b dry-run
    config (AOT lowering on abstract planes — nothing is allocated, so
    the full-scale numbers are exact on this CPU container)."""
    from repro.configs.base import get_config
    from repro.kernels import ref as kref
    from repro.launch.specs import abstract_params
    from repro.roofline.hlo_cost import jit_cost

    spec = make_pack_spec(abstract_params(get_config(HBM_ARCH)))
    rows_n, L = spec.rows, HBM_L
    plane_b = spec.plane_bytes("float32")  # one (rows, 128) meta plane
    sds = jax.ShapeDtypeStruct
    gp = sds((rows_n, 128), jnp.float32)
    v = sds((rows_n, 128), jnp.float32)
    lrn = sds((L, rows_n, 128), jnp.float32)
    avg = sds((rows_n, 128), jnp.float32)
    out = []

    def emit(row, line):
        out.append(row)
        print(line)

    # ---- peak meta-state memory: functional vs donated (MEASURED) ------
    # the dense flat meta mix on the packed planes: average + fused
    # momentum->broadcast, state planes in and out
    def meta_mix(gp, v, lrn):
        a = jnp.mean(lrn.astype(jnp.float32), axis=0)
        return kref.fused_momentum_broadcast_ref(
            gp, v, a, HBM_MU, 1.0, L, lrn.dtype
        )

    fun = jit_cost(meta_mix, gp, v, lrn)
    don = jit_cost(meta_mix, gp, v, lrn, donate_argnums=(0, 1, 2))
    ratio = don.peak_state_bytes / fun.peak_state_bytes
    ok = ratio <= 0.6 and don.alias_bytes > 0
    emit({
        "kind": "hbm_peak_state", "arch": HBM_ARCH, "learners": L,
        "plane_bytes": plane_b,
        "peak_functional_bytes": fun.peak_state_bytes,
        "peak_donated_bytes": don.peak_state_bytes,
        "peak_functional_planes": fun.peak_state_bytes / plane_b,
        "peak_donated_planes": don.peak_state_bytes / plane_b,
        "alias_planes": don.alias_bytes / plane_b,
        "ratio": ratio, "ok": bool(ok),
    }, f"pack,hbm,peak_meta_state,{HBM_ARCH},"
       f"functional={fun.peak_state_bytes / 1e12:.2f}TB"
       f"({fun.peak_state_bytes / plane_b:.0f} planes),"
       f"donated={don.peak_state_bytes / 1e12:.2f}TB"
       f"({don.peak_state_bytes / plane_b:.0f} planes),"
       f"ratio={ratio:.2f},{'ok(<=0.6)' if ok else 'FAIL'}")

    # ---- fused momentum->broadcast: kernel pass structure --------------
    # the Pallas kernel's reads/writes (exact on TPU, where the
    # pallas_call is opaque; CPU interpret-mode lowering dissolves the
    # boundary, so XLA-CPU traffic cannot display this row)
    unfused_r, unfused_w = 3 + 1, 2 + L  # bm(3R+2W) + broadcast(1R+LW)
    fused_r, fused_w = 3, 2 + L  # fused_meta: 3R + (2+L)W
    saved = unfused_r - fused_r
    emit({
        "kind": "hbm_fused_momentum_broadcast", "arch": HBM_ARCH,
        "learners": L, "plane_bytes": plane_b,
        "reads_unfused": unfused_r, "writes_unfused": unfused_w,
        "reads_fused": fused_r, "writes_fused": fused_w,
        "plane_reads_removed": saved,
        "bytes_removed": saved * plane_b, "ok": saved >= 1,
    }, f"pack,hbm,fused_momentum_broadcast,{HBM_ARCH},"
       f"passes={unfused_r}R+{unfused_w}W->{fused_r}R+{fused_w}W,"
       f"reads_removed={saved}({saved * plane_b / 1e12:.2f}TB/step),"
       f"{'ok(>=1)' if saved >= 1 else 'FAIL'}")

    # ---- compress-only kernel: gp-plane read removal (MEASURED) --------
    # pack_update takes the gp plane as an argument and reads it even
    # when the caller synthesized zeros (the compress-stage routes);
    # pack_compress drops the argument, so the read disappears from the
    # compiled HLO — measurable even on the jnp oracles
    d = sds((L, rows_n, 128), jnp.float32)
    u = sds((L, rows_n, 128), jnp.float32)
    block = 64
    old_c = jit_cost(
        lambda d, g, u: kref.pack_update_ref(d, g, None, u, 127, block),
        d, gp, u,
    )
    new_c = jit_cost(
        lambda d, u: kref.pack_compress_ref(d, u, 127, block), d, u
    )
    delta = (old_c.hbm_bytes - new_c.hbm_bytes) / plane_b
    # kernel structure: 3R+3W (d, zero-gp, u -> c, err, scales) vs
    # 2R+3W on the EF route (err IS the next residual) / 2R+2W without
    # EF (the err plane is never allocated — a pallas_call output can't
    # be DCE'd, so with_err=False removes the write entirely)
    emit({
        "kind": "hbm_compress_only", "arch": HBM_ARCH, "learners": L,
        "plane_bytes": plane_b,
        "hbm_bytes_zero_gp": old_c.hbm_bytes,
        "hbm_bytes_compress_only": new_c.hbm_bytes,
        "plane_reads_removed_measured": delta,
        "kernel_passes_ef": "3R+3W->2R+3W",
        "kernel_passes_no_ef": "3R+3W->2R+2W", "ok": delta >= 1,
    }, f"pack,hbm,compress_only,{HBM_ARCH},"
       f"measured_plane_reads_removed={delta:.1f},"
       f"kernel_passes=3R+3W->2R+3W(ef)/2R+2W(no-ef),"
       f"{'ok(>=1)' if delta >= 1 else 'FAIL'}")

    # ---- parity: every zero-copy route bitwise vs the PR 4 path --------
    out += hbm_parity(quick)
    return out


def hbm_parity(quick: bool) -> list[dict]:
    """Bitwise pins of the zero-copy routes against the functional / PR 4
    paths they replace (the cheap MLP versions of tests/test_zero_copy)."""
    import jax.random as jr

    from repro.core.meta import make_jit_meta_step
    from repro.kernels import ops as kops, ref as kref
    from repro.topology.base import block_momentum_update
    from repro.utils import tree_broadcast_learners, tree_cast

    rows = []
    steps = 4 if quick else 10
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)

    # donated == functional, per topology cell
    for name, topo, comm in (CELLS[0], CELLS[3], CELLS[5]):
        cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=K,
                         learner_lr=0.2, momentum=MU, comm=comm,
                         topology=topo)
        outs = {}
        for donate in (False, True):
            state = init_state(params, cfg)
            step = make_jit_meta_step(mlp_loss, cfg, donate=donate)
            for i in range(steps):
                state, _ = step(state, _batches(i, P, K))
            outs[donate] = state
        same = all(
            bool(jnp.array_equal(a, b)) for a, b in zip(
                jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])
            )
        )
        rows.append({"kind": "hbm_parity", "cell": f"donate_{name}",
                     "steps": steps, "bitwise": same, "ok": same})
        print(f"pack,hbm_parity,donate_{name},steps={steps},"
              f"bitwise={same},{'ok' if same else 'FAIL'}")

    # fused momentum->broadcast route == unfused two-step route
    key = jr.PRNGKey(7)
    w, v, a = (jr.normal(jr.fold_in(key, i), (24, 128), jnp.float32)
               for i in range(3))
    f_out = jax.jit(lambda w, v, a: kref.fused_momentum_broadcast_ref(
        w, v, a, MU, 1.0, P, jnp.float32))(w, v, a)

    def unfused(w, v, a):
        gp, vv = block_momentum_update(w, v, a, mu=MU, eta=1.0)
        return gp, vv, tree_broadcast_learners(
            tree_cast(gp, jnp.float32), P)

    u_out = jax.jit(unfused)(w, v, a)
    same = all(bool(jnp.array_equal(x, y)) for x, y in zip(f_out, u_out))
    rows.append({"kind": "hbm_parity", "cell": "fused_momentum_broadcast",
                 "bitwise": same, "ok": same})
    print(f"pack,hbm_parity,fused_momentum_broadcast,bitwise={same},"
          f"{'ok' if same else 'FAIL'}")

    # compress-only kernel == pack_update on a zero gp plane
    d = jr.normal(jr.fold_in(key, 3), (P, 16, 128), jnp.float32) * 0.1
    u = jr.uniform(jr.fold_in(key, 4), (P, 16, 128), jnp.float32)
    co = kops.pack_compress(d, u, use_pallas=False)
    pu = kops.pack_update(d, jnp.zeros((16, 128), jnp.float32), None, u,
                          use_pallas=False)
    same = all(bool(jnp.array_equal(x, y)) for x, y in zip(co, pu))
    rows.append({"kind": "hbm_parity", "cell": "compress_only_zero_gp",
                 "bitwise": same, "ok": same})
    print(f"pack,hbm_parity,compress_only_zero_gp,bitwise={same},"
          f"{'ok' if same else 'FAIL'}")
    return rows


def timing(quick: bool) -> list[dict]:
    """Full jitted meta step on plain XLA CPU, packed vs per-leaf.

    XLA CPU fuses the per-leaf jnp ops into a handful of loops anyway, so
    this does NOT demonstrate the launch-count win (that is a TPU /
    pallas_call property, reported statically by ``launches``); it bounds
    the overhead of the learner-boundary pack/unpack copies the packed
    path adds — the one cost the refactor introduces.
    """
    depth, hidden = (4, 256) if quick else (8, 512)
    params = mlp_init(jax.random.PRNGKey(0), D, hidden, C, depth=depth)
    spec = make_pack_spec(params)
    rows = []
    times = {}
    for packed in (False, True):
        cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=2,
                         learner_lr=0.2, momentum=MU, packed=packed)
        state = init_state(params, cfg)
        step = jax.jit(make_meta_step(mlp_loss, cfg))
        b = _batches(0, P, 2)
        times[packed] = steady(lambda s: step(s, b)[0], state,
                               iters=5, warmup=2)
        t = times[packed]
        print(f"pack,meta_step_xla_cpu_us,"
              f"{'packed' if packed else 'per_leaf'},"
              f"{t.median_us:.0f}±{t.iqr_us:.0f}")
    rows.append({
        "kind": "pack_timing_xla_cpu", "n_leaves": spec.num_leaves,
        "meta_step_us_per_leaf": times[False].median_us,
        "meta_step_us_packed": times[True].median_us,
        "meta_step_iqr_us_per_leaf": times[False].iqr_us,
        "meta_step_iqr_us_packed": times[True].iqr_us,
        "packed_over_per_leaf": (
            times[True].median_us / times[False].median_us
        ),
    })
    return rows


def phase_attribution(quick: bool) -> list[dict]:
    """Measured-vs-modeled attribution of the training phases: whole
    jitted step vs local phase vs meta mix, on the packed MLP config
    (obs.profile.profile_phases — steady-state timing joined against the
    compiled-HLO modeled bytes). The split the K/μ autotuner consumes:
    on what fraction of the step does raising K actually save time?"""
    from repro.obs.profile import measured_peak_gbps, profile_phases

    cfg = MAvgConfig(algorithm="mavg", num_learners=P, k_steps=2,
                     learner_lr=0.2, momentum=MU)
    params = mlp_init(jax.random.PRNGKey(0), D, H, C)
    state = init_state(params, cfg)
    iters, warmup = (5, 2) if quick else (10, 3)
    rows = profile_phases(
        mlp_loss, cfg, state, _batches(0, P, 2),
        iters=iters, warmup=warmup, peak_gbps=measured_peak_gbps(),
    )
    for r in rows:
        print(f"pack,attr,{r['op']},{r['median_us']:.1f}"
              f"±{r['iqr_us']:.1f}us,"
              f"{r['achieved_gbps']:.2f}GB/s,"
              f"{r['pct_of_bound']:.0f}%of_bound")
    return rows


def main(quick: bool = False, json_path: str | None = None):
    rows = []
    rows += parity(quick)
    rows += launches(quick)
    rows += hbm_table(quick)
    rows += timing(quick)
    rows += phase_attribution(quick)
    if json_path:
        from benchmarks.common import write_rows

        write_rows(json_path, rows, suite="pack_bench")
        print(f"pack,json,{json_path},written")
    bad = [r for r in rows if r.get("ok") is False]
    if bad:
        raise SystemExit(
            f"pack_bench: {len(bad)} cell(s) FAILED: "
            f"{[r.get('cell', r['kind']) for r in bad]}"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: fewer steps")
    ap.add_argument("--json", default=None, help="dump rows as JSON")
    args = ap.parse_args()
    main(quick=args.smoke, json_path=args.json)
