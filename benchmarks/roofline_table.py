"""Aggregate dry-run JSON results into the roofline tables for
EXPERIMENTS.md (section Dry-run and section Roofline)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results/dryrun")


def load_results(mesh=None, mode="faithful", algorithm="mavg"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("mode", "faithful") != mode:
            continue
        if r.get("algorithm", "mavg") != algorithm:
            continue
        rows.append(r)
    return rows


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def markdown_table(rows, *, include_memory=True) -> str:
    header = (
        "| arch | shape | mesh | per-dev args | temp | HLO FLOPs/dev |"
        " HBM bytes/dev | coll bytes/dev | compute s | memory s |"
        " collective s | bound | useful | comm | wire bytes | wire s |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                f" SKIP: {r['reason']} |||||||||||||"
            )
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        # wire columns: modeled repro.comm payload (absent in pre-comm JSONs)
        wire_b = rf.get("wire_bytes")
        wire_s = rf.get("wire_s")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {_fmt_bytes(mem.get('argument_size_in_bytes'))} |"
            f" {_fmt_bytes(mem.get('temp_size_in_bytes'))} |"
            f" {rf['hlo_flops']:.2e} | {rf['hlo_bytes']:.2e} |"
            f" {rf['collective_bytes']:.2e} |"
            f" {rf['compute_s']:.3g} | {rf['memory_s']:.3g} |"
            f" {rf['collective_s']:.3g} | **{rf['bottleneck']}** |"
            f" {rf['useful_ratio']:.2f} |"
            f" {rf.get('comm_scheme', '-')} |"
            f" {_fmt_bytes(wire_b) if wire_b else '-'} |"
            f" {f'{wire_s:.3g}' if wire_s else '-'} |"
        )
    return header + "\n".join(lines) + "\n"


def summarize(rows):
    out = []
    for r in rows:
        if r.get("skipped"):
            continue
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / dom if dom else 0
        out.append(
            dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                 bottleneck=rf["bottleneck"], dominant_s=dom,
                 roofline_fraction=frac,
                 collective_ratio=rf["collective_s"] / max(dom, 1e-12))
        )
    return out


def main():
    for mesh in ("single", "multi"):
        rows = load_results(mesh=mesh)
        print(f"\n===== {mesh}-pod ({len(rows)} combos) =====")
        print(markdown_table(rows))


if __name__ == "__main__":
    main()
