"""Robust-aggregation benchmark (repro.robust, DESIGN.md §14).

The acceptance experiment the subsystem exists for: under STICKY finite
payload corruption — a learner whose wire payloads are persistently
mis-scaled and bit-flipped, huge but finite, invisible to the in-step
finite guard — robust aggregation (trimmed mean + trailing-median norm
clip) must stay within 5% of the fault-free final loss at equal
effective samples with ZERO supervisor rollbacks, while the trusting
plain mean degrades badly. Graceful degradation, not detect-and-rollback.

Arms:

  fault_free      no chaos, robust off — the loss bar
  corrupt_mean    sticky finite corruption, plain mean — must degrade
                  (the threat is real; without this cell the 5% bound is
                  vacuous)
  corrupt_robust  same corruption, trimmed mean + norm clip + anomaly
                  scores, run under a Supervisor — within 5% of the bar
                  and zero recovery records
  robust_off      RobustConfig(mean, no clip, no score) vs robust=None —
                  final state must be BITWISE identical (the hooks cost
                  nothing when they don't act)

Prints ``robust,...`` CSV lines; ``--json PATH`` dumps every row as the
CI artifact (gated by benchmarks/expected/robust.json via
tools/bench_compare.py). ``--smoke`` shrinks steps for CI.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/robust_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import numpy as np

from benchmarks.common import CLASSES, D_IN, HIDDEN
from repro.chaos import ChaosConfig, FaultSpec
from repro.configs.base import (
    MAvgConfig,
    ObsConfig,
    RobustConfig,
    TrainConfig,
)
from repro.core import RecoveryPolicy, Supervisor, Trainer
from repro.data import classif_batch_fn
from repro.models.simple import mlp_init, mlp_loss

P, K, MU, LR, BATCH = 4, 4, 0.7, 0.2, 16
BAD = P - 1  # the persistently-corrupt learner

ROBUST = RobustConfig(estimator="trimmed", trim=1, clip_mult=3.0,
                      clip_window=4, score=True)
INERT = RobustConfig(estimator="mean", clip_mult=0.0, score=False)


def _sticky_corruption(steps: int) -> ChaosConfig:
    """Learner BAD ships finite-but-corrupt payloads: a STUCK exponent
    bit (bit 29 flipped on one element of every payload, all run long —
    broken SerDes lane) plus a 3-step burst where the whole plane is
    scaled x12 (a mis-scaled wire payload). Both are huge-but-finite —
    invisible to the finite guard — and both are order-statistic /
    norm-budget outliers the robust mix can reject.

    Deliberately NOT in the schedule: a *persistent* full-plane scale.
    Scaling w = gp + d by m makes the displacement (m-1)*gp + m*d — a
    gp-ALIGNED vector whose per-coordinate values hide inside the benign
    spread on low-|gp| coordinates, so coordinate-wise trimming admits an
    O(spread) bias that momentum compounds into slow divergence. That
    failure mode needs the inline quarantine (membership-capable
    topologies, pinned in tests/test_robust.py) — bounding influence per
    step cannot fix a forever-biased learner (DESIGN.md §14)."""
    return ChaosConfig(seed=0, horizon=steps, faults=(
        FaultSpec("finite_bitflip", step=0, learner=BAD, duration=steps,
                  bit=29, sticky=True),
        FaultSpec("finite_scale", step=steps // 4, learner=BAD, duration=3,
                  magnitude=12.0, sticky=True),
    ))


def _make_trainer(steps, *, chaos=None, robust=None, guard=False, salt=0,
                  lr_scale=1.0, momentum_scale=1.0):
    mcfg = MAvgConfig(
        algorithm="mavg", num_learners=P, k_steps=K,
        learner_lr=LR * lr_scale, momentum=MU * momentum_scale,
        finite_guard=guard, robust=robust,
    )
    tcfg = TrainConfig(
        model=None, mavg=mcfg, batch_per_learner=BATCH, meta_steps=steps,
        seed=0, log_every=2, chaos=chaos, data_salt=salt,
        obs=ObsConfig(sink="none"),
    )
    return Trainer(
        tcfg, mlp_loss,
        init_params_fn=lambda rng: mlp_init(rng, D_IN, HIDDEN, CLASSES),
        batch_fn=classif_batch_fn(D_IN, CLASSES, P, K, BATCH),
    )


def _final_loss(history):
    tail = [r["loss"] for r in history[-5:]]
    return sum(tail) / len(tail)


def _state_finite(state) -> bool:
    planes = [state.global_params, state.momentum, state.learners]
    return all(
        bool(np.isfinite(np.asarray(p)).all()) for p in planes
        if p is not None
    )


def measured(quick: bool) -> list[dict]:
    steps = 16 if quick else 32
    rows: list[dict] = []

    # --- fault-free bar ---------------------------------------------------
    tr = _make_trainer(steps)
    base_hist = tr.run(log=None)
    base_loss = _final_loss(base_hist)
    base_samples = base_hist[-1]["samples"]
    tr.close()
    rows.append({
        "kind": "robust_measured", "cell": "fault_free",
        "final_loss": base_loss, "effective_samples": base_samples,
        "state_finite": _state_finite(tr.state),
    })

    def base_loss_at(samples):
        upto = (
            [r for r in base_hist if r["samples"] <= samples]
            or base_hist[:1]
        )
        return _final_loss(upto)

    chaos = _sticky_corruption(steps)

    # --- plain mean under sticky finite corruption: the threat is real ----
    tr = _make_trainer(steps, chaos=chaos, guard=True)
    mean_hist = tr.run(log=None)
    mean_loss = _final_loss(mean_hist)
    tr.close()
    mean_gap = mean_loss / base_loss_at(mean_hist[-1]["samples"])
    rows.append({
        "kind": "robust_measured", "cell": "corrupt_mean",
        "final_loss": mean_loss, "loss_vs_fault_free": mean_gap,
        "effective_samples": mean_hist[-1]["samples"],
    })

    # --- robust aggregation under the SAME corruption, supervised ---------
    def make_trainer(plan):
        return _make_trainer(
            steps, chaos=chaos, robust=ROBUST, guard=True,
            salt=plan.data_salt, lr_scale=plan.lr_scale,
            momentum_scale=plan.momentum_scale,
        )

    sup = Supervisor(make_trainer, target_steps=steps, checkpoint_dir=None,
                     policy=RecoveryPolicy(max_retries=2))
    tr, _ = sup.run(log=None)
    rob_loss = _final_loss(tr.history)
    rob_samples = tr.history[-1]["samples"]
    rollbacks = sum(1 for r in sup.records if r.get("kind") == "recovery")
    rob_finite = _state_finite(tr.state)
    n_robust_records = len(tr.robust_records)
    max_score = max(
        (max(rb.get("scores", [0.0])) for rb in tr.robust_records),
        default=0.0,
    )
    # the single-element stuck bit is below the anomaly noise floor on
    # quiet steps (by design — see _sticky_corruption); the pin is that
    # the MOST anomalous observation of the run fingers the bad learner
    scored = [rb for rb in tr.robust_records if "scores" in rb]
    anomalous_is_bad = bool(scored) and int(np.argmax(
        max(scored, key=lambda rb: max(rb["scores"]))["scores"]
    )) == BAD
    tr.close()
    rows.append({
        "kind": "robust_measured", "cell": "corrupt_robust",
        "final_loss": rob_loss, "effective_samples": rob_samples,
        "state_finite": rob_finite, "rollbacks": rollbacks,
        "robust_records": n_robust_records,
        "max_anomaly_score": float(max_score),
        "anomalous_is_corrupt_learner": bool(anomalous_is_bad),
    })

    # --- robust hooks off == bitwise identity -----------------------------
    short = max(steps // 2, 8)
    tr_a = _make_trainer(short)
    tr_a.run(log=None)
    tr_b = _make_trainer(short, robust=INERT)
    tr_b.run(log=None)
    bitwise_off = bool(
        np.array_equal(np.asarray(tr_a.state.global_params),
                       np.asarray(tr_b.state.global_params))
        and np.array_equal(np.asarray(tr_a.state.learners),
                           np.asarray(tr_b.state.learners))
        and np.array_equal(np.asarray(tr_a.state.momentum),
                           np.asarray(tr_b.state.momentum))
    )
    tr_a.close()
    tr_b.close()
    rows.append({
        "kind": "robust_measured", "cell": "robust_off",
        "bitwise_identical": bitwise_off,
    })

    for r in rows:
        print("robust," + ",".join(
            f"{k}={v}" for k, v in r.items() if k != "kind"
        ))

    # --- acceptance -------------------------------------------------------
    bar = base_loss_at(rob_samples)
    gap = rob_loss / bar
    # the corrupted plain mean must be demonstrably WORSE than the robust
    # run — otherwise the injected corruption is too weak for the 5%
    # bound to mean anything
    mean_degrades = mean_gap > 1.5 * max(gap, 1.0)
    accept = {
        "kind": "robust_accept",
        "loss_fault_free": bar,
        "loss_fault_free_full": base_loss,
        "loss_robust": rob_loss,
        "loss_mean_corrupt": mean_loss,
        "loss_vs_fault_free": gap,
        "within_5pct": bool(gap <= 1.05),
        "mean_degrades": bool(mean_degrades),
        "samples_vs_fault_free": rob_samples / max(base_samples, 1),
        "rollbacks": rollbacks,
        "state_finite": bool(rob_finite),
        "bitwise_off": bitwise_off,
        "anomalous_is_corrupt_learner": bool(anomalous_is_bad),
        "ok": bool(
            gap <= 1.05 and mean_degrades and rollbacks == 0
            and rob_finite and bitwise_off and anomalous_is_bad
        ),
    }
    rows.append(accept)
    print(f"robust_accept,loss_vs_fault_free,{gap:.3f},within_5pct,"
          f"{accept['within_5pct']},mean_degrades,{mean_degrades},"
          f"rollbacks,{rollbacks},bitwise_off,{bitwise_off},"
          f"anomalous_is_corrupt_learner,{anomalous_is_bad}")
    return rows


def main(quick: bool = False, json_path: str | None = None) -> list[dict]:
    rows = measured(quick)
    if json_path:
        from benchmarks.common import write_rows

        write_rows(json_path, rows, suite="robust")
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="few steps (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (CI artifact)")
    args = ap.parse_args()
    main(quick=args.smoke, json_path=args.json)
