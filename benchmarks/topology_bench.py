"""Topology benchmark: convergence + per-edge-class bytes-on-wire for the
repro.topology subsystem, sweeping topology x comm scheme.

Two layers of numbers, mirroring comm_bench.py:

1. *Measured* — final loss / val accuracy of the teacher-classification
   MLP under each (topology, comm) cell at equal meta-iterations, plus
   the topology's own per-step comm metrics. The acceptance row: the
   hierarchical cell with int8_topk cross-group traffic must ship >= 4x
   fewer modeled inter-node bytes than flat dense while landing within
   5% of flat mavg's final loss.
2. *Modeled* — roofline.topology_wire_bytes on a full-scale config
   (qwen3-1.7b): per-meta-step intra-node (ICI) vs inter-node (DCN)
   payloads and link times per topology at production size.

Prints ``topo,...`` CSV lines; ``--json PATH`` additionally dumps every
row as JSON (the CI artifact, so the bench trajectory accumulates).
``--smoke`` shrinks steps for CI.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/topology_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

from benchmarks.common import run_mlp
from repro.configs.base import CommConfig, TopologyConfig, get_config
from repro.roofline import DCN_LINK_BW, ICI_LINK_BW, topology_wire_bytes

P, K, MU = 8, 4, 0.7

# the sweep: name -> (TopologyConfig, CommConfig) cells
CELLS = (
    ("flat_dense", TopologyConfig(), CommConfig()),
    ("flat_int8", TopologyConfig(),
     CommConfig(scheme="int8", error_feedback=True)),
    # mu_out = 0 on purpose: the inner level already carries the block
    # momentum, and stacking a second momentum on the outer displacement
    # over-accelerates on this problem (mu_out=0.5 diverges — swept in
    # EXPERIMENTS-style runs; the knob stays exercised by the tests)
    ("hier_dense", TopologyConfig(kind="hierarchical", groups=2,
                                  outer_every=2),
     CommConfig()),
    # the acceptance cell: dense intra-group, int8_topk cross-group
    ("hier_int8topk_outer",
     TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                    outer_comm=CommConfig(scheme="int8_topk",
                                          error_feedback=True)),
     CommConfig()),
    ("gossip_ring", TopologyConfig(kind="gossip", graph="ring"), CommConfig()),
    ("gossip_exp_mt", TopologyConfig(kind="gossip", graph="exponential",
                                     momentum_tracking=True), CommConfig()),
)


def measured(quick: bool) -> list[dict]:
    steps = 20 if quick else 80
    rows, flat_loss = [], None
    for name, topo, comm in CELLS:
        losses, acc = run_mlp("mavg", P=P, K=K, mu=MU, steps=steps,
                              comm=comm, topology=topo)
        final = sum(losses[-5:]) / len(losses[-5:])
        if name == "flat_dense":
            flat_loss = final
        # modeled per-edge-class bytes on the MLP-sized problem are noise;
        # report the full-scale model instead (see modeled()) and keep the
        # measured rows about convergence quality
        row = {
            "kind": "topo_measured", "cell": name,
            "topology": topo.kind, "graph": topo.graph,
            "groups": topo.groups, "outer_every": topo.outer_every,
            "final_loss": final, "vs_flat": final / flat_loss,
            "val_acc": acc, "meta_steps": steps,
        }
        rows.append(row)
        print(f"topo,{name},final_loss,{final:.4f},{final / flat_loss:.3f}x_flat")
        print(f"topo,{name},val_acc,{acc:.3f},frac")
    return rows


def modeled(arch: str = "qwen3-1.7b", num_learners: int = P) -> list[dict]:
    n = get_config(arch).param_count()
    rows = []
    for name, topo, comm in CELLS:
        edge = topology_wire_bytes(n, comm, topo, num_learners=num_learners)
        wire_s = (edge["intra_bytes"] / ICI_LINK_BW
                  + edge["inter_bytes"] / DCN_LINK_BW)
        row = {
            "kind": "topo_model", "cell": name, "arch": arch,
            **edge, "wire_s": wire_s,
        }
        rows.append(row)
        print(f"topo_model,{arch},{name},intra,{edge['intra_bytes']:.3e},B,"
              f"inter,{edge['inter_bytes']:.3e},B,{wire_s:.4f},s")
    flat = next(r for r in rows if r["cell"] == "flat_dense")
    hier = next(r for r in rows if r["cell"] == "hier_int8topk_outer")
    ratio = flat["inter_bytes"] / max(hier["inter_bytes"], 1.0)
    rows.append({"kind": "topo_accept", "arch": arch,
                 "inter_reduction_vs_flat": ratio})
    print(f"topo_accept,{arch},inter_reduction,{ratio:.1f},x")
    return rows


def main(quick: bool = False, json_path: str | None = None) -> list[dict]:
    rows = measured(quick) + modeled()
    if json_path:
        from benchmarks.common import write_rows

        write_rows(json_path, rows, suite="topology_bench")
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="few steps / few timing iters (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (CI artifact)")
    args = ap.parse_args()
    main(quick=args.smoke, json_path=args.json)
