"""E1 — paper Figures 1-6 + Table I: M-AVG accelerates convergence and
reaches better accuracy than K-AVG at the same number of samples.

The paper trains 7 CNNs on CIFAR-10 with P GPUs; this CPU container runs
the same optimizer code on three CPU-feasible model families (MLP, CNN,
tiny transformer) over the teacher streams (DESIGN.md section 6). The
claim validated is the paper's: same (N, K, P, B) -> M-AVG achieves
lower loss / higher validation accuracy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import run_mlp
from repro.configs.base import MAvgConfig
from repro.core.meta import init_state, make_meta_step
from repro.pack import unpack_params
from repro.data import classif_batch_fn, classif_eval_set, lm_batch_fn
from repro.models import api as model_api
from repro.configs import get_config
from repro.models.simple import cnn_accuracy, cnn_init, cnn_loss


def run_cnn(algorithm, *, P=4, K=4, mu=0.7, lr=0.1, steps=40, batch=8,
            seed=0):
    hw = 12
    cfg = MAvgConfig(algorithm=algorithm, num_learners=P, k_steps=K,
                     learner_lr=lr, momentum=mu)
    params = cnn_init(jax.random.PRNGKey(seed), hw=hw, classes=10)
    state = init_state(params, cfg)
    step = jax.jit(make_meta_step(cnn_loss, cfg))
    bf = classif_batch_fn(hw * hw * 3, 10, P, K, batch)

    def reshape(b):
        x = b["x"].reshape(P, K, batch, hw, hw, 3)
        return {"x": x, "y": b["y"]}

    losses = []
    for i in range(steps):
        b = reshape(bf(jax.random.fold_in(jax.random.PRNGKey(seed + 1), i), i))
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    ev = classif_eval_set(hw * hw * 3, 10, n=512)
    ev = {"x": ev["x"].reshape(-1, hw, hw, 3), "y": ev["y"]}
    return losses, float(cnn_accuracy(unpack_params(state), ev))


def run_tiny_transformer(algorithm, *, P=4, K=2, mu=0.6, lr=0.5, steps=20,
                         batch=8, seed=0):
    cfg = get_config("qwen3-1.7b").reduced()
    mcfg = MAvgConfig(algorithm=algorithm, num_learners=P, k_steps=K,
                      learner_lr=lr, momentum=mu)
    params = model_api.init_params(jax.random.PRNGKey(seed), cfg)
    state = init_state(params, mcfg)
    step = jax.jit(make_meta_step(
        lambda p, b: model_api.loss_fn(p, cfg, b), mcfg))
    bf = lm_batch_fn(cfg, P, K, batch, 32)
    losses = []
    for i in range(steps):
        b = bf(jax.random.fold_in(jax.random.PRNGKey(seed + 1), i), i)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return losses, float(jnp.exp(jnp.asarray(losses[-5:]).mean()))


def main(quick: bool = False):
    """Primary metric: samples-to-target loss (the paper's Lemma-4
    speed-up). Secondary: final loss / val metric (paper Table I)."""
    from benchmarks.common import samples_to_target

    rows = []
    steps = 30 if quick else 60
    cases = (
        ("mlp", run_mlp, dict(P=4, K=4, lr=0.2, steps=steps, batch=16), 1.0),
        ("cnn", run_cnn, dict(P=4, K=4, lr=0.1, steps=max(20, steps // 2)),
         2.2),
        ("tiny-transformer", run_tiny_transformer,
         dict(P=4, K=2, lr=0.5, steps=max(15, steps // 3)), 5.5),
    )
    for model, runner, kw, target in cases:
        curves = {}
        for algo, mu in (("kavg", 0.0), ("mavg", 0.7)):
            kw2 = dict(kw)
            kw2["mu"] = mu
            losses, metric = runner(algo, **kw2)
            batch = kw.get("batch", 8)
            stt = samples_to_target(losses, target, kw["P"], kw["K"], batch)
            curves[algo] = (losses, stt)
            rows.append((model, algo, mu, losses[-1], metric, stt))
            print(f"convergence,{model},{algo},mu={mu},final_loss="
                  f"{losses[-1]:.4f},metric={metric:.4f},"
                  f"samples_to_{target}={stt}")
        k_stt, m_stt = curves["kavg"][1], curves["mavg"][1]
        if k_stt and m_stt:
            print(f"convergence,{model},speedup,{k_stt / m_stt:.2f}x")
            # paper's acceleration claim: M-AVG no slower (10% tolerance)
            assert m_stt <= 1.1 * k_stt, (model, m_stt, k_stt)
    return rows


if __name__ == "__main__":
    main()
