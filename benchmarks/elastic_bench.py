"""Elastic & heterogeneous execution benchmark (repro.topology).

Three layers of numbers, mirroring topology_bench.py:

1. *Measured churn* — final loss / val accuracy of the teacher-
   classification MLP under simulated learner dropout (deterministic
   membership schedules, 12.5%-37.5% churn) against the static topology
   at equal meta-iterations. The acceptance row: <= 25% churn must land
   within 5% of the static final loss (mean preservation through the
   masked doubly-stochastic mixing is what makes this hold).
2. *Heterogeneous K* — the Lemma-5 harness per group: sweeping
   ``group_k`` cells (uniform and skewed) shows the optimal-K trade-off
   shifting per group the way the paper's Lemma 5 predicts it globally —
   more local steps buy sample throughput at a consensus cost, so the
   best skew keeps the slow-edge group high-K and the fast group low-K.
3. *Modeled* — roofline.topology_wire_bytes with the degree-over-time
   wire model on a full-scale config (qwen3-1.7b): time-averaged degree
   for one-peer exponential, learner/edge presence factors under churn.

Prints ``elastic,...`` CSV lines; ``--json PATH`` dumps every row as the
CI artifact. ``--smoke`` shrinks steps for CI.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/elastic_bench.py --smoke`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

from benchmarks.common import run_mlp
from repro.configs.base import (
    CommConfig,
    ElasticConfig,
    TopologyConfig,
    get_config,
)
from repro.roofline import DCN_LINK_BW, ICI_LINK_BW, topology_wire_bytes

P, K, MU = 8, 4, 0.7

# churn sweep: name -> (TopologyConfig, baseline-cell name). Each elastic
# cell is scored against *its own* static topology at equal
# meta-iterations. (CommConfig stays dense — the comm x topology product
# is topology_bench's job.) Ring at degree 2 genuinely degrades under 25%
# churn (~10% — every dead edge cuts a third of a learner's mixing mass);
# the exponential graph and the hierarchical group average absorb it.
CHURN_CELLS = (
    ("gossip_ring_static", TopologyConfig(kind="gossip", graph="ring"),
     None),
    ("gossip_ring_drop12", TopologyConfig(
        kind="gossip", graph="ring",
        elastic=ElasticConfig(period=8, drop_frac=0.125)),
     "gossip_ring_static"),
    ("gossip_ring_drop25", TopologyConfig(
        kind="gossip", graph="ring",
        elastic=ElasticConfig(period=8, drop_frac=0.25)),
     "gossip_ring_static"),
    ("gossip_ring_drop37", TopologyConfig(
        kind="gossip", graph="ring",
        elastic=ElasticConfig(period=8, drop_frac=0.375)),
     "gossip_ring_static"),
    ("gossip_exp_static", TopologyConfig(kind="gossip",
                                         graph="exponential"), None),
    ("gossip_exp_drop25", TopologyConfig(
        kind="gossip", graph="exponential",
        elastic=ElasticConfig(period=8, drop_frac=0.25)),
     "gossip_exp_static"),
    ("gossip_one_peer", TopologyConfig(
        kind="gossip", graph="one_peer_exponential"), "gossip_ring_static"),
    ("hier_static", TopologyConfig(kind="hierarchical", groups=2,
                                   outer_every=2), None),
    ("hier_drop25", TopologyConfig(
        kind="hierarchical", groups=2, outer_every=2,
        elastic=ElasticConfig(period=8, drop_frac=0.25)),
     "hier_static"),
)

# heterogeneous-K sweep (Lemma 5 per group): uniform cells bracket the
# skewed ones so the per-group optimal-K shift is visible in one table
HETERO_K_CELLS = (
    ("group_k_1_1", (1, 1)),
    ("group_k_2_2", (2, 2)),
    ("group_k_4_4", (4, 4)),
    ("group_k_1_4", (1, 4)),
    ("group_k_2_4", (2, 4)),
    ("group_k_4_1", (4, 1)),
)

MODEL_CELLS = (
    ("flat_dense", TopologyConfig()),
    ("gossip_ring", TopologyConfig(kind="gossip", graph="ring")),
    ("gossip_exponential", TopologyConfig(kind="gossip",
                                          graph="exponential")),
    ("gossip_one_peer", TopologyConfig(kind="gossip",
                                       graph="one_peer_exponential")),
    ("gossip_ring_drop25", TopologyConfig(
        kind="gossip", graph="ring",
        elastic=ElasticConfig(period=8, drop_frac=0.25))),
    ("hier_drop25", TopologyConfig(
        kind="hierarchical", groups=2, outer_every=2,
        elastic=ElasticConfig(period=8, drop_frac=0.25))),
)


def measured_churn(quick: bool) -> list[dict]:
    steps = 20 if quick else 80
    rows, finals = [], {}
    for name, topo, baseline in CHURN_CELLS:
        losses, acc = run_mlp("mavg", P=P, K=K, mu=MU, steps=steps,
                              topology=topo)
        final = sum(losses[-5:]) / len(losses[-5:])
        finals[name] = final
        drop = topo.elastic.drop_frac if topo.elastic else 0.0
        vs = final / finals[baseline] if baseline else 1.0
        row = {
            "kind": "elastic_measured", "cell": name,
            "topology": topo.kind, "graph": topo.graph, "drop_frac": drop,
            "final_loss": final, "vs_static": vs,
            "val_acc": acc, "meta_steps": steps,
        }
        rows.append(row)
        print(f"elastic,{name},final_loss,{final:.4f},{vs:.3f}x_static")
        print(f"elastic,{name},val_acc,{acc:.3f},frac")
    # acceptance: the hierarchical cell — the group average renormalizes
    # over present members, so 25% churn lands within 5% of static
    accept = next(r for r in rows if r["cell"] == "hier_drop25")
    rows.append({"kind": "elastic_accept",
                 "loss_vs_static_at_25pct_churn": accept["vs_static"],
                 "within_5pct": bool(accept["vs_static"] <= 1.05)})
    print(f"elastic_accept,hier_drop25_vs_static,{accept['vs_static']:.3f},"
          f"within_5pct,{accept['vs_static'] <= 1.05}")
    return rows


def measured_hetero_k(quick: bool) -> list[dict]:
    steps = 20 if quick else 80
    rows = []
    for name, gk in HETERO_K_CELLS:
        topo = TopologyConfig(kind="hierarchical", groups=2, outer_every=2,
                              group_k=gk)
        losses, acc = run_mlp("mavg", P=P, K=K, mu=MU, steps=steps,
                              topology=topo)
        final = sum(losses[-5:]) / len(losses[-5:])
        # samples actually consumed reflect the per-group step counts
        samples = steps * (P // 2) * sum(gk) * 16
        row = {
            "kind": "hetero_k_measured", "cell": name, "group_k": list(gk),
            "final_loss": final, "val_acc": acc, "samples": samples,
            "loss_per_ksample": final / max(samples / 1e3, 1e-9),
        }
        rows.append(row)
        print(f"elastic,{name},final_loss,{final:.4f},"
              f"samples,{samples}")
    return rows


def modeled(arch: str = "qwen3-1.7b", num_learners: int = P) -> list[dict]:
    n = get_config(arch).param_count()
    rows = []
    for name, topo in MODEL_CELLS:
        edge = topology_wire_bytes(n, CommConfig(), topo,
                                   num_learners=num_learners)
        wire_s = (edge["intra_bytes"] / ICI_LINK_BW
                  + edge["inter_bytes"] / DCN_LINK_BW)
        row = {
            "kind": "elastic_model", "cell": name, "arch": arch,
            **edge, "wire_s": wire_s,
        }
        rows.append(row)
        print(f"elastic_model,{arch},{name},inter,{edge['inter_bytes']:.3e},B,"
              f"avg_deg,{edge['avg_degree']:.1f},"
              f"edge_presence,{edge['edge_presence']:.3f},"
              f"{wire_s:.4f},s")
    return rows


def main(quick: bool = False, json_path: str | None = None) -> list[dict]:
    rows = measured_churn(quick) + measured_hetero_k(quick) + modeled()
    if json_path:
        from benchmarks.common import write_rows

        write_rows(json_path, rows, suite="elastic_bench")
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="few steps (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (CI artifact)")
    args = ap.parse_args()
    main(quick=args.smoke, json_path=args.json)
