"""Kernel micro-benchmarks.

CPU numbers are NOT TPU-representative (the Pallas kernels run in
interpret mode here); what this bench proves is (a) functional parity at
realistic sizes and (b) the op-count reduction of the fused update, which
is the TPU win: 3 reads + 2 writes instead of 4 reads + 2 writes + extra
kernel launches. The XLA-path timing comparison below times the jnp
reference against the fused-jnp expression to show the fusion headroom
XLA itself finds on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.kernels import ops, ref


def main(quick: bool = False):
    n = 1 << 20 if not quick else 1 << 16
    key = jax.random.PRNGKey(0)
    w, v, a = (jax.random.normal(jax.random.fold_in(key, i), (n,))
               for i in range(3))

    # unfused: four separate jitted passes (what a naive meta update does)
    @jax.jit
    def unfused(w, v, a):
        d = a - w
        d = jax.block_until_ready(d) if False else d
        v2 = 0.9 * v
        v2 = v2 + d
        w2 = w + v2
        return w2, v2

    @jax.jit
    def fused_jnp(w, v, a):
        return ref.block_momentum_ref(w, v, a, 0.9, 1.0)

    t_unfused = timeit(unfused, w, v, a)
    t_fused = timeit(fused_jnp, w, v, a)
    print(f"kernel,block_momentum_unfused_xla,{t_unfused:.1f},us")
    print(f"kernel,block_momentum_fused_xla,{t_fused:.1f},us")

    # analytic HBM-pass count (the TPU roofline argument for the kernel)
    bytes_naive = 4 * (3 * 4 * n) // 3  # 4 reads + 2 writes equivalent
    bytes_fused = (3 + 2) * 4 * n
    print(f"kernel,block_momentum_hbm_bytes_naive,{6 * 4 * n},bytes")
    print(f"kernel,block_momentum_hbm_bytes_fused,{bytes_fused},bytes")

    # flash attention: interpret-mode correctness timing at a macro size
    B, S, H, KV, D = (1, 512, 8, 2, 128) if not quick else (1, 128, 4, 2, 64)
    q = jax.random.normal(jax.random.fold_in(key, 5), (B, S, H, D)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 6), (B, S, KV, D)) * 0.3
    vv = jax.random.normal(jax.random.fold_in(key, 7), (B, S, KV, D)) * 0.3
    oracle = jax.jit(
        lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True)
    )
    t_oracle = timeit(oracle, q, k, vv, iters=3, warmup=1)
    print(f"kernel,attention_oracle_xla,{t_oracle:.1f},us")
    out = ops.flash_attention(q, k, vv, causal=True)
    err = float(jnp.max(jnp.abs(out - oracle(q, k, vv))))
    print(f"kernel,flash_attention_interpret_maxerr,{err:.2e},abs")
    assert err < 5e-3


if __name__ == "__main__":
    main()
