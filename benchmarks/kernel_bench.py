"""Kernel micro-benchmarks.

CPU numbers are NOT TPU-representative (the Pallas kernels run in
interpret mode here); what this bench proves is (a) functional parity at
realistic sizes, (b) the HBM-pass reduction of the fused updates (the TPU
win: the block-momentum update is 3 reads + 2 writes instead of 4 reads +
2 writes, and the packed compressed displacement is one pass instead of
three), and (c) the meta-phase launch-count / padding-waste collapse of
the packed flat meta-plane (repro.pack): O(1) whole-model kernel launches
per op instead of one per pytree leaf. The XLA-path timing comparison
below times the jnp reference against the fused-jnp expression to show
the fusion headroom XLA itself finds on CPU.

``--json PATH`` dumps the launch/padding/HBM rows as JSON (the CI
artifact shape shared with comm/topology/elastic/pack benches).
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/kernel_bench.py --quick`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import jax
import jax.numpy as jnp

from benchmarks.common import steady
from repro.kernels import ops, ref

# native Pallas kernels only exist on TPU; elsewhere the attribution
# times the jnp reference route (interpret mode executes the kernel body
# block-by-block in Python — its wall-clock is meaningless)
USE_PALLAS = jax.default_backend() == "tpu"

# the real configs the packed meta-plane targets (layer-stacked param
# trees: 11-31 leaves each; the leafiest and the padding-heaviest)
LAUNCH_COUNT_ARCHS = ("llama3-405b", "qwen1.5-110b", "xlstm-350m",
                      "hymba-1.5b")
# per-leaf meta-phase kernel launches per op family (block momentum,
# quantize, dequantize each launched once per leaf; packed launches once)
META_OPS = ("block_momentum", "quantize", "dequantize")


def meta_plane_rows(quick: bool = False) -> list[dict]:
    """Meta-phase launch count and padding waste: per-leaf vs packed.

    Static analysis over the real configs' abstract param trees (no
    device allocation — jax.eval_shape), so the full-scale numbers are
    exact, not extrapolated from a toy model.
    """
    from repro.configs.base import get_config
    from repro.launch.specs import abstract_params
    from repro.pack import make_pack_spec

    rows = []
    del quick  # static analysis via eval_shape: free at any scale
    for arch in LAUNCH_COUNT_ARCHS:
        cfg = get_config(arch)
        spec = make_pack_spec(abstract_params(cfg))
        per_leaf_launches = spec.num_leaves  # per op, per meta step
        rows.append({
            "kind": "meta_plane", "arch": arch,
            "n_leaves": spec.num_leaves,
            "launches_per_op_per_leaf": per_leaf_launches,
            "launches_per_op_packed": 1,
            "launch_reduction": per_leaf_launches,
            "pad_waste_elems_per_leaf": spec.per_leaf_pad_waste(),
            "pad_waste_elems_packed": spec.pad_waste,
            "params": sum(spec.sizes),
            "packed_rows": spec.rows,
        })
        r = rows[-1]
        print(f"kernel,meta_launches_per_op,{arch},"
              f"{r['launches_per_op_per_leaf']}->1")
        print(f"kernel,meta_pad_waste_elems,{arch},"
              f"{r['pad_waste_elems_per_leaf']}->"
              f"{r['pad_waste_elems_packed']}")
    return rows


def attribution_rows(quick: bool = False) -> list[dict]:
    """Measured-vs-modeled attribution of the meta-phase kernels.

    Each kernel is steady-state timed (obs.profile: warmup +
    block_until_ready + median/IQR) and joined against its compiled
    program's modeled HBM bytes (roofline.hlo_cost.jit_cost), yielding
    achieved GB/s and % of the machine's MEASURED peak bandwidth — the
    cross-machine-comparable number ``tools/bench_compare.py`` gates on.
    On CPU the jnp reference route is what's timed (USE_PALLAS).
    """
    from repro.obs.profile import measured_peak_gbps, profile_fn

    key = jax.random.PRNGKey(3)
    rows_n, L = (1024, 4) if quick else (8192, 8)
    peak = measured_peak_gbps()
    print(f"kernel,attr,measured_peak_gbps,{peak:.1f}")

    gp = jax.random.normal(jax.random.fold_in(key, 0), (rows_n, 128))
    v = jax.random.normal(jax.random.fold_in(key, 1), (rows_n, 128))
    a = jax.random.normal(jax.random.fold_in(key, 2), (rows_n, 128))
    lrn = jax.random.normal(
        jax.random.fold_in(key, 3), (L, rows_n, 128)
    ) * 0.1
    u = jax.random.uniform(jax.random.fold_in(key, 4), (L, rows_n, 128))
    # degree-2 ring mixing matrix (doubly stochastic)
    eye = jnp.eye(L)
    ring = 0.5 * eye + 0.25 * jnp.roll(eye, 1, 0) + 0.25 * jnp.roll(eye, -1, 0)

    targets = [
        ("pack_update",
         lambda lrn, gp, u: ops.pack_update(lrn, gp, None, u,
                                            use_pallas=USE_PALLAS),
         (lrn, gp, u)),
        ("fused_meta",
         lambda gp, v, a: ops.fused_momentum_broadcast(
             gp, v, a, mu=0.9, eta=1.0, num_learners=L,
             ldtype=jnp.float32, use_pallas=USE_PALLAS),
         (gp, v, a)),
        ("neighbor_mix",
         lambda lrn, m: ops.neighbor_mix_tree(lrn, m,
                                              use_pallas=USE_PALLAS),
         (lrn, ring)),
        ("quantize",
         lambda gp, k: ops.quantize(gp, k, use_pallas=USE_PALLAS)[:2],
         (gp, jax.random.fold_in(key, 5))),
    ]
    iters, warmup = (5, 2) if quick else (20, 3)
    rows = []
    for op, fn, args in targets:
        row = profile_fn(op, fn, *args, iters=iters, warmup=warmup,
                         peak_gbps=peak,
                         extra={"rows": rows_n, "learners": L,
                                "use_pallas": USE_PALLAS})
        rows.append(row)
        print(f"kernel,attr,{op},{row['median_us']:.1f}"
              f"±{row['iqr_us']:.1f}us,"
              f"{row['achieved_gbps']:.1f}GB/s,"
              f"{row['pct_of_bound']:.0f}%of_bound")
    return rows


def main(quick: bool = False, json_path: str | None = None):
    n = 1 << 20 if not quick else 1 << 16
    key = jax.random.PRNGKey(0)
    w, v, a = (jax.random.normal(jax.random.fold_in(key, i), (n,))
               for i in range(3))

    # unfused: four separate jitted passes (what a naive meta update does)
    @jax.jit
    def unfused(w, v, a):
        d = a - w
        v2 = 0.9 * v
        v2 = v2 + d
        w2 = w + v2
        return w2, v2

    @jax.jit
    def fused_jnp(w, v, a):
        return ref.block_momentum_ref(w, v, a, 0.9, 1.0)

    t_unfused = steady(unfused, w, v, a)
    t_fused = steady(fused_jnp, w, v, a)
    print(f"kernel,block_momentum_unfused_xla,"
          f"{t_unfused.median_us:.1f}±{t_unfused.iqr_us:.1f},us")
    print(f"kernel,block_momentum_fused_xla,"
          f"{t_fused.median_us:.1f}±{t_fused.iqr_us:.1f},us")

    # analytic HBM-pass count (the TPU roofline argument for the kernel):
    # naive = 4 reads (w, v, a, and the materialized d) + 2 writes;
    # fused = 3 reads (w, v, a) + 2 writes — all f32
    bytes_naive = (4 + 2) * 4 * n
    bytes_fused = (3 + 2) * 4 * n
    print(f"kernel,block_momentum_hbm_bytes_naive,{bytes_naive},bytes")
    print(f"kernel,block_momentum_hbm_bytes_fused,{bytes_fused},bytes")

    # packed meta plane: launch count + padding waste (the repro.pack win)
    rows = meta_plane_rows(quick=quick)
    rows.append({
        "kind": "hbm_passes", "op": "block_momentum",
        "bytes_naive": bytes_naive, "bytes_fused": bytes_fused,
        "passes_naive": 6, "passes_fused": 5,
    })
    rows.append({
        # the fused packed displacement kernel (kernels/pack_update.py):
        # naive = delta pass + EF-add pass + quantize pass over the plane
        # (2 reads + 1 write each) vs one fused 4-read / 3-write pass
        "kind": "hbm_passes", "op": "pack_update",
        "passes_naive": 9, "passes_fused": 7,
    })
    rows.append({
        # fused momentum->broadcast (kernels/fused_meta.py): block
        # momentum (3R+2W of the meta plane) + tree_broadcast_learners'
        # re-read of w~' (1R) collapse into one pass — the learner-plane
        # writes (L per step) are identical on both sides and excluded
        "kind": "hbm_passes", "op": "fused_momentum_broadcast",
        "passes_naive": 6, "passes_fused": 5,
        "plane_reads_removed": 1,
    })
    rows.append({
        # compress-only variant (pack_update.pack_compress_3d): the
        # compress-stage routes no longer read a synthesized zero gp
        # plane per mix; without error feedback the err plane is not
        # written either (with_err=False -> 4 passes)
        "kind": "hbm_passes", "op": "pack_compress",
        "passes_naive": 6, "passes_fused": 5, "passes_fused_no_ef": 4,
        "plane_reads_removed": 1,
    })
    for r in rows[-2:]:
        print(f"kernel,hbm_passes,{r['op']},"
              f"{r['passes_naive']}->{r['passes_fused']}")

    # measured-vs-modeled attribution: the judgment layer over the
    # structural claims above (achieved GB/s vs the machine's roofline)
    rows += attribution_rows(quick=quick)

    # fused momentum->broadcast: interpret-kernel parity at a macro size
    rows_n, L = (512, 8) if not quick else (64, 4)
    w2 = jax.random.normal(jax.random.fold_in(key, 8), (rows_n, 128))
    v2 = jax.random.normal(jax.random.fold_in(key, 9), (rows_n, 128))
    a2 = jax.random.normal(jax.random.fold_in(key, 10), (rows_n, 128))
    fk = ops.fused_momentum_broadcast(
        w2, v2, a2, mu=0.9, eta=1.0, num_learners=L,
        ldtype=jnp.bfloat16, use_pallas=True, interpret=True,
    )
    fr = ref.fused_momentum_broadcast_ref(
        w2, v2, a2, 0.9, 1.0, L, jnp.bfloat16
    )
    err2 = max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(fk, fr)
    )
    print(f"kernel,fused_momentum_broadcast_interpret_maxerr,{err2:.2e},abs")
    assert err2 < 1e-5

    # flash attention: interpret-mode correctness timing at a macro size
    B, S, H, KV, D = (1, 512, 8, 2, 128) if not quick else (1, 128, 4, 2, 64)
    q = jax.random.normal(jax.random.fold_in(key, 5), (B, S, H, D)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 6), (B, S, KV, D)) * 0.3
    vv = jax.random.normal(jax.random.fold_in(key, 7), (B, S, KV, D)) * 0.3
    oracle = jax.jit(
        lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True)
    )
    t_oracle = steady(oracle, q, k, vv, iters=3, warmup=1)
    print(f"kernel,attention_oracle_xla,"
          f"{t_oracle.median_us:.1f}±{t_oracle.iqr_us:.1f},us")
    out = ops.flash_attention(q, k, vv, causal=True)
    err = float(jnp.max(jnp.abs(out - oracle(q, k, vv))))
    print(f"kernel,flash_attention_interpret_maxerr,{err:.2e},abs")
    assert err < 5e-3

    if json_path:
        from benchmarks.common import write_rows

        write_rows(json_path, rows, suite="kernel_bench")
        print(f"kernel,json,{json_path},written")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    main(quick=args.quick, json_path=args.json)
