"""Benchmark harness entry point — one module per paper table/figure.

  E1 convergence.py     Figures 1-6 + Table I (M-AVG vs K-AVG, 3 models)
  E2 mu_p_sweep.py      Figures 9-12 / Lemma 6 (optimal mu grows with P)
  E3 k_sweep.py         Lemmas 5 & 7 (optimal K > 1; momentum shrinks K)
  E4 baselines.py       section IV baselines (Downpour, EAMSGD, sync)
  K  kernel_bench.py    fused block-momentum + flash-attention kernels
  C  comm_bench.py      meta-communication compression (repro.comm)
  T  topology_bench.py  meta-mixing topologies x comm (repro.topology)
  L  elastic_bench.py    elastic membership / hetero-K / time-varying gossip
  A  async_bench.py      async bounded-staleness server vs the barrier
  X  chaos_bench.py      fault injection + supervised recovery (repro.chaos)
  B  robust_bench.py     Byzantine-tolerant aggregation accept (repro.robust)
  P  pack_bench.py      packed flat meta-plane parity / launches (repro.pack)
  R  roofline_table.py  section Dry-run / Roofline aggregation

Prints ``name,...`` CSV lines. ``--quick`` shrinks steps/seeds (default
here so `python -m benchmarks.run` finishes on CPU in ~15 min); pass
``--full`` for the EXPERIMENTS.md-grade numbers.

Every suite's result rows are also appended to a per-suite trajectory
store ``<bench-dir>/BENCH_<suite>.json`` (obs.baseline) — the cross-run
history ``tools/bench_compare.py`` gates against the committed baselines
in ``benchmarks/expected/``. ``--bench-dir ''`` disables the append.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="explicit form of the default (smoke-sized "
                         "suites); mutually exclusive with --full")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: convergence mu_p k baselines kernel comm topology elastic async chaos robust pack roofline")
    ap.add_argument("--bench-dir", default="bench_out",
                    help="directory of the BENCH_<suite>.json trajectory "
                         "stores ('' = don't append)")
    args = ap.parse_args()
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    quick = not args.full

    from benchmarks import (
        ablations,
        async_bench,
        baselines,
        chaos_bench,
        comm_bench,
        convergence,
        k_sweep,
        kernel_bench,
        mu_p_sweep,
        elastic_bench,
        pack_bench,
        robust_bench,
        roofline_table,
        topology_bench,
    )

    suites = {
        "kernel": lambda: kernel_bench.main(quick=quick),
        "comm": lambda: comm_bench.main(quick=quick),
        "topology": lambda: topology_bench.main(quick=quick),
        "elastic": lambda: elastic_bench.main(quick=quick),
        "async": lambda: async_bench.main(quick=quick),
        "chaos": lambda: chaos_bench.main(quick=quick),
        "robust": lambda: robust_bench.main(quick=quick),
        "pack": lambda: pack_bench.main(quick=quick),
        "convergence": lambda: convergence.main(quick=quick),
        "baselines": lambda: baselines.main(quick=quick),
        "k": lambda: k_sweep.main(quick=quick),
        "mu_p": lambda: mu_p_sweep.main(quick=quick),
        "ablations": lambda: ablations.main(quick=quick),
        "roofline": roofline_table.main,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if k in args.only}

    failed = []
    for name, fn in suites.items():
        print(f"\n===== bench:{name} =====", flush=True)
        t0 = time.time()
        try:
            rows = fn()
            # sub-benchmarks report per-cell verdicts as ``ok`` fields in
            # their returned rows; a failing smoke cell must fail the
            # aggregate run even if the suite didn't raise
            bad = [r for r in (rows or []) if isinstance(r, dict)
                   and r.get("ok") is False]
            if bad:
                raise SystemExit(f"{len(bad)} cell(s) not ok")
            if args.bench_dir and rows:
                from repro.obs.baseline import (
                    append_trajectory, trajectory_path,
                )

                # the envelope convention of benchmarks/common.write_rows:
                # bench-local "kind" taxonomies ride as "row_kind"
                recs = []
                for r in rows:
                    if not isinstance(r, dict):
                        continue
                    rec = dict(r)
                    if rec.get("kind") not in (None, "row"):
                        rec["row_kind"] = rec.pop("kind")
                    recs.append({"kind": "row", **rec})
                path = trajectory_path(args.bench_dir, name)
                append_trajectory(path, name, recs)
                print(f"bench,{name},trajectory,{path}")
            print(f"bench,{name},{(time.time() - t0) * 1e6:.0f},ok")
        except (Exception, SystemExit) as e:
            # SystemExit is how benches signal failed cells from main();
            # catch it so one failing suite doesn't mask the rest, then
            # exit non-zero below
            if not isinstance(e, SystemExit):
                traceback.print_exc()
            failed.append(name)
            print(f"bench,{name},{(time.time() - t0) * 1e6:.0f},FAILED ({e})")
    if failed:
        sys.exit(f"FAILED suites: {failed}")


if __name__ == "__main__":
    main()
